//! Chaos suite: randomized request interleavings driven through a real
//! `Server` with deterministic fault injection armed at every site (pool
//! lease denial, prefill-chunk error, decode-step error, prefix-entry
//! corruption — see `util::faults`).
//!
//! Each case mixes the four hazards the lifecycle hardening must absorb:
//! injected faults, client cancels at random ticks, per-request tick
//! deadlines, and submit churn (staggered arrivals, never one batch). The
//! properties checked are the DESIGN.md §6 serving invariants under fire:
//!
//! 1. the server never panics and every submitted request reaches exactly
//!    one terminal state with a well-formed event stream;
//! 2. `Server::check_invariants` holds after EVERY tick, not just at drain
//!    (page books balance, id sets stay disjoint, bookkeeping maps track
//!    exactly the in-flight population);
//! 3. after drain, every leased pool page is pinned by the prefix index —
//!    zero lease leaks, no matter which faults fired;
//! 4. the same seed replays the same outcomes bit-for-bit.
//!
//! Runs on the artifact-free reference engine, so this is tier-1.

use std::collections::HashMap;

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::events::{by_request, validate_stream, Event};
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::{FinishReason, Request};
use mixkvq::harness::workloads;
use mixkvq::model::config::{Meta, ModelConfig};
use mixkvq::model::sampler::Sampling;
use mixkvq::quant::methods::Method;
use mixkvq::util::faults::FaultPlan;
use mixkvq::util::rng::Pcg32;

/// Two-layer build so prefill/decode stay cheap enough for a sweep.
fn small_meta() -> Meta {
    let mut meta = Meta::default_build();
    meta.model = ModelConfig { n_layers: 2, ..meta.model };
    for v in &mut meta.variants {
        v.layers.truncate(2);
        while v.layers.len() < 2 {
            let last = *v.layers.last().unwrap();
            v.layers.push(last);
        }
    }
    meta
}

fn small_engine() -> Engine {
    Engine::new_reference(small_meta(), 11, Method::bf16(), 32).unwrap()
}

/// Pages the prefix tree legitimately pins after all sessions retire —
/// the only pages allowed to remain leased at drain.
fn pinned_pages(server: &Server) -> usize {
    server.engine.prefix_tree().map(|ix| ix.borrow().pages_pinned()).unwrap_or(0)
}

fn gen_request(rng: &mut Pcg32, id: u64) -> Request {
    let ctx = 16 + rng.below(32) as usize;
    Request {
        id,
        prompt: workloads::gen_passkey(rng, ctx).prompt,
        max_new_tokens: 2 + rng.below(5) as usize,
        sampling: Sampling::Greedy,
        method: None,
        tenant: rng.below(3),
        // a quarter of the load carries a tick deadline tight enough that
        // fault-induced retries can blow it — deadline × fault interaction
        deadline_ticks: (rng.below(4) == 0).then(|| 10 + rng.below(30) as u64),
    }
}

/// Drive one seeded chaos case to drain; panics on any invariant breach.
/// Returns (all events in emission order, per-request max_new budgets).
fn run_case(server: &mut Server, seed: u64, n: usize) -> (Vec<Event>, HashMap<u64, usize>) {
    let mut rng = Pcg32::seeded(seed);
    let mut pending: Vec<Request> = (0..n).map(|i| gen_request(&mut rng, i as u64)).collect();
    pending.reverse(); // pop() submits in id order
    let max_new: HashMap<u64, usize> =
        pending.iter().map(|r| (r.id, r.max_new_tokens)).collect();
    let mut submitted: Vec<u64> = Vec::new();
    let mut events = Vec::new();
    let mut guard = 0;
    while !pending.is_empty() || server.has_work() {
        // churn: 0–2 staggered arrivals per tick, never one up-front batch
        for _ in 0..rng.below(3) {
            if let Some(r) = pending.pop() {
                submitted.push(r.id);
                server.submit(r).unwrap();
            }
        }
        // ~10% of ticks cancel a random request; cancelling an already
        // terminal id must be a harmless no-op (cancel returns false)
        if !submitted.is_empty() && rng.below(10) == 0 {
            let id = submitted[rng.below(submitted.len() as u32) as usize];
            server.cancel(id);
        }
        server.tick().unwrap();
        // the tentpole claim: books balance after EVERY tick under fire
        if let Err(e) = server.check_invariants() {
            panic!("seed {seed} tick {guard}: invariant violated: {e:#}");
        }
        events.extend(server.drain_events());
        guard += 1;
        assert!(guard < 10_000, "seed {seed}: chaos case failed to drain");
    }
    events.extend(server.drain_events());
    (events, max_new)
}

/// Hazard sweep: faults × cancels × deadlines × churn across seeds, with
/// the invariant audit after every tick and a leak audit at drain.
#[test]
fn chaos_interleavings_drain_clean_across_seeds() {
    for case in 0..6u64 {
        let seed = 9000 + case;
        let mut server = Server::new(
            small_engine(),
            ServerConfig {
                seed,
                faults: Some(FaultPlan::uniform(seed, 0.15)),
                max_prefills_per_cycle: 2,
                ..ServerConfig::default()
            },
        );
        let n = 10 + (case as usize % 3) * 3;
        let (events, max_new) = run_case(&mut server, seed, n);

        // every request terminal, every stream well-formed
        let streams = by_request(&events);
        assert_eq!(streams.len(), n, "seed {seed}: missing request streams");
        for (id, stream) in &streams {
            if let Err(e) = validate_stream(stream, max_new[id]) {
                panic!("seed {seed} req {id}: malformed stream: {e}");
            }
            assert!(
                matches!(stream.last(), Some(Event::Finished { .. })),
                "seed {seed} req {id}: no terminal event"
            );
        }
        // zero lease leaks: only prefix-pinned pages may remain leased
        assert_eq!(
            server.pool.leased(),
            pinned_pages(&server),
            "seed {seed}: leaked pages after drain"
        );
        // the soak must actually have been a soak — faults fired
        let injected: u64 = server.metrics.faults_injected.iter().sum();
        assert!(injected > 0, "seed {seed}: chaos case injected no faults");
    }
}

/// The hazard sweep at `workers = 4`: faults × cancels × deadlines × churn
/// with the per-tick `check_invariants` audit (inside `run_case`) — and the
/// whole failure story must match the single-threaded run bit for bit,
/// because fault draws are keyed to (request, ordinal), never to a thread
/// schedule.
#[test]
fn chaos_sweep_at_four_workers_matches_single_threaded() {
    let run_at = |workers: usize| {
        let mut server = Server::new(
            small_engine(),
            ServerConfig {
                seed: 4242,
                faults: Some(FaultPlan::uniform(4242, 0.15)),
                max_prefills_per_cycle: 2,
                workers,
                ..ServerConfig::default()
            },
        );
        let n = 14;
        let (events, max_new) = run_case(&mut server, 4242, n);
        let streams = by_request(&events);
        assert_eq!(streams.len(), n, "workers={workers}: missing request streams");
        for (id, stream) in &streams {
            validate_stream(stream, max_new[id])
                .unwrap_or_else(|e| panic!("workers={workers} req {id}: {e}"));
        }
        assert_eq!(
            server.pool.leased(),
            pinned_pages(&server),
            "workers={workers}: leaked pages after drain"
        );
        (events, server.metrics.faults_injected, server.metrics.faults_drawn)
    };
    let (e1, i1, d1) = run_at(1);
    let (e4, i4, d4) = run_at(4);
    assert!(i4.iter().sum::<u64>() > 0, "chaos sweep injected no faults");
    assert_eq!(e1, e4, "workers=4 chaos sweep diverged from workers=1");
    assert_eq!(i1, i4, "injected-fault counts diverged between widths");
    assert_eq!(d1, d4, "fault-draw counts diverged between widths");
}

/// `run_case`, except the server is snapshotted at the `kill_at` tick
/// boundary, torn down entirely, and rebuilt from the bytes — the
/// submission loop (the "client population") survives the crash and keeps
/// driving the replica.
fn run_case_with_kill(
    cfg: &ServerConfig,
    seed: u64,
    n: usize,
    kill_at: usize,
) -> (Vec<Event>, HashMap<u64, usize>, Server) {
    let mut server = Server::new(small_engine(), cfg.clone());
    let mut rng = Pcg32::seeded(seed);
    let mut pending: Vec<Request> = (0..n).map(|i| gen_request(&mut rng, i as u64)).collect();
    pending.reverse();
    let max_new: HashMap<u64, usize> =
        pending.iter().map(|r| (r.id, r.max_new_tokens)).collect();
    let mut submitted: Vec<u64> = Vec::new();
    let mut events = Vec::new();
    let mut guard = 0;
    let mut killed = false;
    while !pending.is_empty() || server.has_work() {
        // the crash: at the tick boundary the log is drained, so the
        // replica's event stream continues the original's seamlessly
        if !killed && guard >= kill_at {
            killed = true;
            let mut buf: Vec<u8> = Vec::new();
            server.snapshot(&mut buf).unwrap_or_else(|e| {
                panic!("seed {seed} tick {guard}: snapshot failed: {e}")
            });
            drop(server);
            server = Server::restore(small_engine(), cfg.clone(), buf.as_slice())
                .unwrap_or_else(|e| panic!("seed {seed} tick {guard}: restore failed: {e}"));
            server.check_invariants().unwrap();
        }
        for _ in 0..rng.below(3) {
            if let Some(r) = pending.pop() {
                submitted.push(r.id);
                server.submit(r).unwrap();
            }
        }
        if !submitted.is_empty() && rng.below(10) == 0 {
            let id = submitted[rng.below(submitted.len() as u32) as usize];
            server.cancel(id);
        }
        server.tick().unwrap();
        if let Err(e) = server.check_invariants() {
            panic!("seed {seed} tick {guard}: invariant violated: {e:#}");
        }
        events.extend(server.drain_events());
        guard += 1;
        assert!(guard < 10_000, "seed {seed}: killed chaos case failed to drain");
    }
    events.extend(server.drain_events());
    (events, max_new, server)
}

/// Snapshot-mid-chaos: the full hazard sweep (faults × cancels × deadlines
/// × churn) with a snapshot/teardown/restore dropped at several mid-run
/// tick boundaries — each killed run must replay the uninterrupted run's
/// event stream AND fault story bit for bit, and still drain leak-free.
#[test]
fn snapshot_restore_mid_chaos_replays_identically() {
    // serving-path sites armed; snapshot sites quiet so the equivalence
    // snapshot itself is not torn by the background chaos rate
    let cfg = ServerConfig {
        seed: 5150,
        faults: Some(FaultPlan::serving_uniform(5150, 0.15)),
        max_prefills_per_cycle: 2,
        ..ServerConfig::default()
    };
    let n = 12;
    let mut baseline = Server::new(small_engine(), cfg.clone());
    let (e1, max_new) = run_case(&mut baseline, 5150, n);
    let i1 = baseline.metrics.faults_injected;
    assert!(i1.iter().sum::<u64>() > 0, "sweep injected no faults");

    for kill_at in [1usize, 4, 9] {
        let (e2, _, replica) = run_case_with_kill(&cfg, 5150, n, kill_at);
        assert_eq!(
            e1, e2,
            "kill at tick {kill_at}: restored run diverged from uninterrupted"
        );
        assert_eq!(
            i1, replica.metrics.faults_injected,
            "kill at tick {kill_at}: fault story diverged across the restore"
        );
        assert_eq!(replica.metrics.restores, 1);
        let streams = by_request(&e2);
        assert_eq!(streams.len(), n, "kill at tick {kill_at}: missing streams");
        for (id, stream) in &streams {
            validate_stream(stream, max_new[id])
                .unwrap_or_else(|e| panic!("kill {kill_at} req {id}: {e}"));
        }
        assert_eq!(
            replica.pool.leased(),
            pinned_pages(&replica),
            "kill at tick {kill_at}: leaked pages after drain"
        );
    }
}

/// Same seed, same fault plan, same arrivals ⇒ bit-identical event streams
/// and bit-identical per-site fault counts across two fresh servers.
#[test]
fn same_seed_chaos_replays_bit_identical_outcomes() {
    let run = || {
        let mut server = Server::new(
            small_engine(),
            ServerConfig {
                seed: 77,
                faults: Some(FaultPlan::uniform(77, 0.2)),
                ..ServerConfig::default()
            },
        );
        let (events, _) = run_case(&mut server, 77, 12);
        (events, server.metrics.faults_injected, server.metrics.faults_drawn)
    };
    let (ea, ia, da) = run();
    let (eb, ib, db) = run();
    assert_eq!(ea, eb, "same-seed chaos runs diverged in event streams");
    assert_eq!(ia, ib, "same-seed chaos runs diverged in injected faults");
    assert_eq!(da, db, "same-seed chaos runs diverged in fault draws");
}

/// Bounded queue backpressure: with `max_queue = 2` and no ticks between
/// submits, the third and later submissions retire `Rejected` at submit —
/// deterministically, with well-formed two-event streams.
#[test]
fn bounded_queue_rejects_deterministically_at_submit() {
    let mut server = Server::new(
        small_engine(),
        ServerConfig { max_queue: Some(2), ..ServerConfig::default() },
    );
    let mut rng = Pcg32::seeded(55);
    let n = 8usize;
    let mut max_new = HashMap::new();
    for i in 0..n {
        let mut req = gen_request(&mut rng, i as u64);
        req.deadline_ticks = None;
        max_new.insert(req.id, req.max_new_tokens);
        server.submit(req).unwrap();
    }
    assert_eq!(server.metrics.queue_rejections, (n - 2) as u64);
    let mut events = Vec::new();
    let mut guard = 0;
    while server.has_work() {
        server.tick().unwrap();
        server.check_invariants().unwrap();
        events.extend(server.drain_events());
        guard += 1;
        assert!(guard < 10_000, "bounded-queue drain stalled");
    }
    events.extend(server.drain_events());
    let streams = by_request(&events);
    assert_eq!(streams.len(), n);
    let mut rejected = 0;
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
        if let Some(Event::Finished { reason: FinishReason::Rejected, .. }) = stream.last() {
            rejected += 1;
        }
    }
    assert_eq!(rejected, n - 2, "every over-quota submit must retire Rejected");
    assert_eq!(server.pool.leased(), pinned_pages(&server));
}

/// A one-tick deadline expires while still queued: `enforce_deadlines`
/// runs before admission each tick, so every request retires
/// `DeadlineExceeded` without ever touching the pool.
#[test]
fn tight_deadlines_retire_every_queued_request() {
    let mut server = Server::new(small_engine(), ServerConfig::default());
    let mut rng = Pcg32::seeded(66);
    let n = 6usize;
    let mut max_new = HashMap::new();
    for i in 0..n {
        let mut req = gen_request(&mut rng, i as u64);
        req.deadline_ticks = Some(1);
        max_new.insert(req.id, req.max_new_tokens);
        server.submit(req).unwrap();
    }
    server.tick().unwrap();
    server.check_invariants().unwrap();
    assert!(!server.has_work(), "one-tick deadlines must clear the queue in one tick");
    let events = server.drain_events();
    let streams = by_request(&events);
    assert_eq!(streams.len(), n);
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
        assert!(
            matches!(
                stream.last(),
                Some(Event::Finished { reason: FinishReason::DeadlineExceeded, .. })
            ),
            "req {id}: expected DeadlineExceeded terminal"
        );
    }
    assert_eq!(server.metrics.deadline_shed, n as u64);
    assert_eq!(server.pool.leased(), pinned_pages(&server));
}
