//! Cross-request prefix sharing invariants over the radix tree (no
//! artifacts needed):
//!
//! * **bit-identity property**: K requests adopting registered prompts at
//!   DIFFERENT tree depths (refcounted copy-on-write pages) and then
//!   diverging — per-request decode appends, flushes, sliding-window
//!   eviction, mid-flight cancel — must stay bitwise equal to private
//!   caches fed the same data at every step: page contents, channel
//!   plans, |Q| state, residual rows;
//! * **deduped page budget**: while K requests share a prefix, the pool
//!   holds each shared group ONCE (`~1/K`× private mode) plus each
//!   request's private divergence tail — never more;
//! * **refcount discipline**: LRU shedding only ever removes tails and
//!   leaf nodes; an interior node (children or anchored tails) is never
//!   shed while a descendant is pinned, so every resident chain stays
//!   intact from depth 1 down (`RadixTree::audit` after every shed);
//! * **no leaks**: after every drain (drops, cancels, tree clear)
//!   `pool.leased() == 0`;
//! * **seam discipline**: evicting shared pages drops only the local
//!   table reference; co-tenants and the tree keep the bytes alive.

use mixkvq::kvcache::cache::{ContiguousHead, RequestCache};
use mixkvq::kvcache::eviction::CachePolicy;
use mixkvq::kvcache::pool::{prompt_chain_key, KvPool};
use mixkvq::kvcache::radix::{PrefixMatch, PrefixProbe, RadixTree};
use mixkvq::model::config::{CacheConfig, ModelConfig};
use mixkvq::quant::methods::Method;
use mixkvq::quant::window::TierSpec;
use mixkvq::util::rng::Pcg32;

/// Head-major `[h][t][d]` per-layer K/V + per-channel |Q| stats — the
/// legacy `load_prefill` layout.
fn rand_kv(
    rng: &mut Pcg32,
    mc: &ModelConfig,
    t: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = mc.n_kv_heads * t * mc.d_head;
    let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let qa = (0..mc.n_layers)
        .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
        .collect();
    (k, v, qa)
}

/// Token-major `[t, Hkv*dh]` per-layer K/V + |Q| stats — the chunked
/// `store_prefill_layer_from` layout (what the blocked forward produces).
fn rand_kv_tokmajor(
    rng: &mut Pcg32,
    mc: &ModelConfig,
    t: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let stride = mc.n_kv_heads * mc.d_head;
    let k = (0..mc.n_layers).map(|_| (0..t * stride).map(|_| rng.normal()).collect()).collect();
    let v = (0..mc.n_layers).map(|_| (0..t * stride).map(|_| rng.normal()).collect()).collect();
    let qa = (0..mc.n_layers).map(|_| (0..stride).map(|_| rng.f32() + 0.01).collect()).collect();
    (k, v, qa)
}

fn full_hit(tree: &mut RadixTree, seed: u64, prompt: &[i32], group: usize) -> PrefixMatch {
    // max_groups 0: full-tail adoption only, the partial walk stays off
    match tree.lookup(seed, prompt, group, 0) {
        PrefixProbe::Full(m) => m,
        PrefixProbe::Partial(_) => panic!("expected full prefix hit, got partial"),
        PrefixProbe::Miss => panic!("expected full prefix hit, got miss"),
    }
}

fn partial_hit(
    tree: &mut RadixTree,
    seed: u64,
    prompt: &[i32],
    group: usize,
    max_groups: usize,
) -> PrefixMatch {
    match tree.lookup(seed, prompt, group, max_groups) {
        PrefixProbe::Partial(m) => m,
        PrefixProbe::Full(_) => panic!("expected partial prefix hit, got full"),
        PrefixProbe::Miss => panic!("expected partial prefix hit, got miss"),
    }
}

/// Frozen-plan seam resume at the cache level: quantize rows `[seam, t)`
/// of a token-major prompt into private tail pages under the installed
/// plan, then seal the cursors — what `PrefillRun::new_resumed` drives in
/// serving, minus the attention compute.
fn resume_tail(
    c: &mut RequestCache,
    mc: &ModelConfig,
    k: &[Vec<f32>],
    v: &[Vec<f32>],
    qa: &[Vec<f32>],
    t: usize,
    seam: usize,
) {
    c.begin_prefill_from(t, seam).unwrap();
    let d = mc.d_head;
    let mut kbuf = vec![0.0f32; (t - seam) * d];
    let mut vbuf = vec![0.0f32; (t - seam) * d];
    for l in 0..mc.n_layers {
        c.store_prefill_layer_from(l, &k[l], &v[l], &qa[l], t, seam, &mut kbuf, &mut vbuf)
            .unwrap();
    }
    c.finish_prefill(t);
}

fn snapshot(cache: &RequestCache, mc: &ModelConfig) -> Vec<ContiguousHead> {
    (0..mc.n_layers)
        .flat_map(|l| (0..mc.n_kv_heads).map(move |h| (l, h)))
        .map(|(l, h)| cache.heads[l][h].contiguous())
        .collect()
}

fn assert_mirrors(shared: &RequestCache, private: &RequestCache, mc: &ModelConfig, ctx: &str) {
    assert_eq!(shared.qlen, private.qlen, "{ctx}: qlen");
    assert_eq!(shared.pos, private.pos, "{ctx}: pos");
    assert_eq!(shared.rlen(), private.rlen(), "{ctx}: rlen");
    assert_eq!(shared.evicted_tokens, private.evicted_tokens, "{ctx}: evicted");
    for l in 0..mc.n_layers {
        for h in 0..mc.n_kv_heads {
            let (a, b) = (&shared.heads[l][h], &private.heads[l][h]);
            assert_eq!(a.idx, b.idx, "{ctx}: l{l}h{h} plan");
            assert_eq!(a.contiguous(), b.contiguous(), "{ctx}: l{l}h{h} pages");
            assert_eq!(a.res.keys(), b.res.keys(), "{ctx}: l{l}h{h} res keys");
            assert_eq!(a.res.values(), b.res.values(), "{ctx}: l{l}h{h} res values");
            assert_eq!(a.qstats.sum_abs, b.qstats.sum_abs, "{ctx}: l{l}h{h} qstats");
        }
    }
}

/// The headline property: K sharers with divergent decode tails under
/// append/flush/evict/cancel churn stay bit-identical to K private caches,
/// the pool never exceeds the deduped budget (prefix once + private
/// tails), and everything drains to zero leases.
#[test]
fn k_sharers_stay_bit_identical_to_private_caches_under_churn() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig { capacity: 256, residual: 64, ..CacheConfig::default_build() };
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let r_limit = 32;
    let k_req = 3usize;
    let method = Method::mixkvq("mix30");

    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(512));
    pool.prewarm(512);
    let mut tree = RadixTree::new(256, pool.page_deploy_bytes());

    // one shared prompt: 160 tokens = 128 quantized (4 groups/head) + 32
    // residual; a producer registers it, K consumers adopt it
    let mut seed_rng = Pcg32::seeded(1009);
    let t0 = 160;
    let (k0, v0, qa0) = rand_kv(&mut seed_rng, &mc, t0);
    let prompt0: Vec<i32> = (0..t0 as i32).collect();
    let mut producer = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    producer.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert!(producer.register_prefix(&mut tree, 0xfeed, &prompt0, &[0.25, 0.75]));
    let prefix_pages = pool.leased();
    assert_eq!(prefix_pages, (128 / cc.group) * mc.n_layers * mc.n_kv_heads);
    assert_eq!(tree.node_count(), 128 / cc.group, "one node per shared group");
    tree.audit().unwrap();
    drop(producer);
    assert_eq!(pool.leased(), prefix_pages, "tree pins the prefix alone");

    let mut shared: Vec<Option<RequestCache>> = Vec::new();
    let mut private: Vec<Option<RequestCache>> = Vec::new();
    let mut tail_rngs: Vec<Pcg32> = Vec::new();
    for r in 0..k_req {
        let mut s = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
        let m = full_hit(&mut tree, 0xfeed, &prompt0, cc.group);
        s.install_prefix(&m).unwrap();
        drop(m); // the probe's lease clones must not distort pool accounting
        // request 1 diverges in POLICY too: sliding-window eviction that
        // will eventually splice shared pages out of its own table
        if r == 1 {
            s.policy = CachePolicy::SlidingWindow { sink: 32, evict: 32 };
        }
        let mut p = RequestCache::new(&mc, &cc, &specs, method.clone(), r_limit);
        p.load_prefill(&k0, &v0, &qa0, t0).unwrap();
        if r == 1 {
            p.policy = CachePolicy::SlidingWindow { sink: 32, evict: 32 };
        }
        assert_mirrors(&s, &p, &mc, &format!("install r{r}"));
        shared.push(Some(s));
        private.push(Some(p));
        tail_rngs.push(Pcg32::seeded(7000 + r as u64));
    }
    assert_eq!(pool.leased(), prefix_pages, "K installs lease ZERO new pages");

    let mut max_leased = pool.leased();
    for step in 0..220 {
        for r in 0..k_req {
            let (Some(s), Some(p)) = (&mut shared[r], &mut private[r]) else { continue };
            // divergent tails: each request's decode stream is distinct
            let (kn, vn, qn) = rand_kv(&mut tail_rngs[r], &mc, 1);
            match (s.append(&kn, &vn, &qn), p.append(&kn, &vn, &qn)) {
                (Ok(()), Ok(())) => {}
                (Err(_), Err(_)) => {
                    // both exhaust identically (Stop policy fills up)
                    continue;
                }
                (a, b) => panic!("r{r} step {step}: shared {a:?} vs private {b:?} diverged"),
            }
            if step % 10 == r {
                assert_mirrors(s, p, &mc, &format!("step {step} r{r}"));
            }
        }
        // deduped page budget: prefix once + every live request's private
        // divergence tail — never a page more
        let tails: usize = shared
            .iter()
            .flatten()
            .map(RequestCache::private_pages)
            .sum();
        assert_eq!(
            pool.leased(),
            prefix_pages + tails,
            "step {step}: pool must hold prefix ONCE plus private tails"
        );
        max_leased = max_leased.max(pool.leased());
        // cancel churn: request 2 retires mid-flight
        if step == 120 {
            let before = pool.leased();
            let dropped_tail = shared[2].as_ref().unwrap().private_pages();
            shared[2] = None;
            private[2] = None;
            assert_eq!(
                pool.leased(),
                before - dropped_tail,
                "cancel returns ONLY the private tail (prefix stays shared)"
            );
        }
    }

    // the eviction-policy sharer must have spliced shared pages out of its
    // OWN table without disturbing anyone else
    let evictor = shared[1].as_ref().unwrap();
    assert!(evictor.evicted_tokens > 0, "sliding window must have evicted");
    assert!(
        evictor.shared_prefix_tokens < 128,
        "eviction must consume the shared seam counter"
    );
    let survivor = shared[0].as_ref().unwrap();
    let survivor_snap = snapshot(survivor, &mc);
    assert_eq!(
        survivor_snap,
        snapshot(private[0].as_ref().unwrap(), &mc),
        "co-tenant unaffected by another sharer's eviction"
    );

    // drain: drop all sharers → only the tree pin remains → clear → zero
    shared.clear();
    private.clear();
    assert_eq!(pool.leased(), prefix_pages, "after drops only the tree pins pages");
    tree.clear();
    assert_eq!(pool.leased(), 0, "no leaks after the tree lets go");
    assert!(max_leased <= 512, "budget never exceeded");
}

/// Sharers adopting at DIFFERENT tree depths stay bit-identical to private
/// caches: one full hit on the original registration (depth 4 anchor), one
/// full hit on a frozen-plan follower's extension (shared depth 1–2 plus
/// its own depth 3–4 branch). The follower itself exercises the partial-hit
/// path end to end: probe → install at the seam → seam-resumed store under
/// the adopted plan → registration extending the chain with ONLY its
/// divergent suffix. The deduped budget counts every shared group once.
#[test]
fn sharers_at_different_depths_stay_bit_identical_to_private_caches() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig { capacity: 256, residual: 64, ..CacheConfig::default_build() };
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let r_limit = 32;
    let method = Method::mixkvq("mix30");
    let seed = 0xbeef_u64;
    let per_group = mc.n_layers * mc.n_kv_heads;

    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(512));
    pool.prewarm(512);
    let mut tree = RadixTree::new(256, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(2027);

    // producer A: 160 tokens (4 quantized groups + 32 residual)
    let t0 = 160;
    let prompt_a: Vec<i32> = (0..t0 as i32).collect();
    let (k0, v0, qa0) = rand_kv(&mut rng, &mc, t0);
    let mut producer_a = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    producer_a.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert!(producer_a.register_prefix(&mut tree, seed, &prompt_a, &[0.5]));
    assert_eq!(tree.pages_pinned(), 4 * per_group);

    // follower C: shares A's first two groups (64 tokens), diverges after.
    // Partial probe → install at the seam → frozen-plan resume → register:
    // the chain gains ONLY the two divergent groups.
    let seam = 2 * cc.group;
    let mut prompt_c: Vec<i32> = prompt_a[..seam].to_vec();
    prompt_c.extend(9000..9000 + (t0 - seam) as i32);
    let (kc, vc, qac) = rand_kv_tokmajor(&mut rng, &mc, t0);
    let mut consumer_c = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    let cap = RadixTree::partial_walk_groups(128, t0, cc.group);
    let m = partial_hit(&mut tree, seed, &prompt_c, cc.group, cap);
    assert_eq!(m.t, seam, "deepest verified match is the shared two groups");
    assert_eq!(m.qt, seam);
    consumer_c.install_prefix(&m).unwrap();
    drop(m);
    resume_tail(&mut consumer_c, &mc, &kc, &vc, &qac, t0, seam);
    assert!(consumer_c.register_prefix(&mut tree, seed, &prompt_c, &[0.75]));
    assert_eq!(
        tree.pages_pinned(),
        6 * per_group,
        "follower extends the chain with its divergent suffix only"
    );
    assert_eq!(tree.node_count(), 6);
    assert_eq!(tree.len(), 2);
    tree.audit().unwrap();
    assert_eq!(pool.leased(), 6 * per_group, "every shared group held once");

    // sharer on A (full hit at depth 4) mirrors a fresh private prefill;
    // sharer on C (full hit through the shared depth 1–2 prefix plus C's
    // own branch) mirrors the follower that computed that state
    let mut s_a = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    let m = full_hit(&mut tree, seed, &prompt_a, cc.group);
    s_a.install_prefix(&m).unwrap();
    drop(m);
    let mut private_a = RequestCache::new(&mc, &cc, &specs, method.clone(), r_limit);
    private_a.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert_mirrors(&s_a, &private_a, &mc, "depth-4 sharer install");

    let mut s_c = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    let m = full_hit(&mut tree, seed, &prompt_c, cc.group);
    s_c.install_prefix(&m).unwrap();
    drop(m);
    assert_mirrors(&s_c, &consumer_c, &mc, "branch sharer install");
    assert_eq!(pool.leased(), 6 * per_group, "installs lease ZERO new pages");

    // divergent decode churn: each sharer mirrors its private twin while
    // the pool never exceeds shared-once + private tails
    let mut rng_a = Pcg32::seeded(3001);
    let mut rng_c = Pcg32::seeded(3002);
    for step in 0..100 {
        let (ka, va, qa) = rand_kv(&mut rng_a, &mc, 1);
        match (s_a.append(&ka, &va, &qa), private_a.append(&ka, &va, &qa)) {
            (Ok(()), Ok(())) | (Err(_), Err(_)) => {}
            (a, b) => panic!("step {step}: depth-4 sharer {a:?} vs private {b:?} diverged"),
        }
        let (kc1, vc1, qc1) = rand_kv(&mut rng_c, &mc, 1);
        match (s_c.append(&kc1, &vc1, &qc1), consumer_c.append(&kc1, &vc1, &qc1)) {
            (Ok(()), Ok(())) | (Err(_), Err(_)) => {}
            (a, b) => panic!("step {step}: branch sharer {a:?} vs follower {b:?} diverged"),
        }
        if step % 10 == 0 {
            assert_mirrors(&s_a, &private_a, &mc, &format!("step {step} depth-4"));
            assert_mirrors(&s_c, &consumer_c, &mc, &format!("step {step} branch"));
        }
        let tails = s_a.private_pages()
            + s_c.private_pages()
            + producer_a.private_pages()
            + consumer_c.private_pages();
        assert_eq!(
            pool.leased(),
            tree.pages_pinned() + tails,
            "step {step}: shared groups once plus private tails"
        );
    }
    assert_mirrors(&s_a, &private_a, &mc, "depth-4 end");
    assert_mirrors(&s_c, &consumer_c, &mc, "branch end");

    // drain to zero
    drop(s_a);
    drop(s_c);
    drop(private_a);
    drop(producer_a);
    drop(consumer_c);
    assert_eq!(pool.leased(), tree.pages_pinned(), "only the tree pins pages");
    tree.clear();
    assert_eq!(pool.leased(), 0, "no leaks after the tree lets go");
}

/// Refcount discipline under LRU pressure: shedding erodes chains from
/// the deep end — tails first, then leaf nodes — and an interior node is
/// NEVER removed while a descendant (child node or anchored tail) is
/// still resident. The structural audit holds after every single shed,
/// and the last surviving node is the depth-1 root of the shared chain,
/// still serving partial hits.
#[test]
fn interior_nodes_survive_until_every_dependent_sheds() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig { capacity: 256, residual: 64, ..CacheConfig::default_build() };
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let r_limit = 32;
    let method = Method::mixkvq("mix30");
    let seed = 0xabc_u64;
    let per_group = mc.n_layers * mc.n_kv_heads;

    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(512));
    pool.prewarm(512);
    let mut tree = RadixTree::new(256, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(4099);

    // chain A: 4 groups; follower C branches after group 2
    let t0 = 160;
    let prompt_a: Vec<i32> = (0..t0 as i32).collect();
    let (k0, v0, qa0) = rand_kv(&mut rng, &mc, t0);
    let mut producer_a = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    producer_a.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert!(producer_a.register_prefix(&mut tree, seed, &prompt_a, &[0.5]));

    let seam = 2 * cc.group;
    let mut prompt_c: Vec<i32> = prompt_a[..seam].to_vec();
    prompt_c.extend(5000..5000 + (t0 - seam) as i32);
    let (kc, vc, qac) = rand_kv_tokmajor(&mut rng, &mc, t0);
    let mut consumer_c = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    let cap = RadixTree::partial_walk_groups(128, t0, cc.group);
    let m = partial_hit(&mut tree, seed, &prompt_c, cc.group, cap);
    consumer_c.install_prefix(&m).unwrap();
    drop(m);
    resume_tail(&mut consumer_c, &mc, &kc, &vc, &qac, t0, seam);
    assert!(consumer_c.register_prefix(&mut tree, seed, &prompt_c, &[0.75]));
    assert_eq!(tree.node_count(), 6);
    assert_eq!(tree.len(), 2);
    assert_eq!(tree.pages_pinned(), 6 * per_group);
    tree.audit().unwrap();

    // retire every cache: the tree alone keeps the chains alive
    drop(producer_a);
    drop(consumer_c);
    assert_eq!(pool.leased(), 6 * per_group);

    // first shed takes the LRU TAIL — the anchor and every interior node
    // above it survive untouched even though the tail was the coldest
    // entity in the whole tree
    assert!(tree.shed_lru());
    assert_eq!(tree.len(), 1, "LRU tail shed first");
    assert_eq!(tree.node_count(), 6, "no node shed while its chain is pinned");
    tree.audit().unwrap();

    // erode until a single node remains, auditing after EVERY shed: an
    // interior removed ahead of a descendant would orphan that descendant
    // and fail the audit's parent/child integrity checks
    while tree.node_count() > 1 {
        assert!(tree.shed_lru(), "tree still has sheddable state");
        tree.audit().unwrap();
        assert_eq!(
            pool.leased(),
            tree.pages_pinned(),
            "every shed returns its pages to the pool immediately"
        );
    }
    // the survivor is the depth-1 root — it still serves partial hits for
    // any prompt sharing the first group
    let mut probe: Vec<i32> = prompt_a[..cc.group].to_vec();
    probe.extend(7000..7000 + (t0 - cc.group) as i32);
    let m = partial_hit(&mut tree, seed, &probe, cc.group, cap);
    assert_eq!(m.t, cc.group, "depth-1 root still answers one-group matches");
    drop(m);

    // final drain: everything sheds, zero leases remain
    while tree.shed_lru() {
        tree.audit().unwrap();
    }
    assert!(tree.is_empty());
    assert_eq!(tree.pages_pinned(), 0);
    assert_eq!(pool.leased(), 0, "no leaks after the full erosion");
}

/// A prompt shorter than the residual limit registers a zero-page entry —
/// consumers still skip the prefill (residual + |Q| state adopted) and
/// plan their channels privately at the first flush, bit-identical to
/// private mode.
#[test]
fn residual_only_prompt_shares_compute_not_pages() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
    pool.prewarm(64);
    let mut tree = RadixTree::new(64, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(1013);
    let t0 = 24; // < r_limit = 32: zero pages, residual only
    let (k0, v0, qa0) = rand_kv(&mut rng, &mc, t0);
    let mut producer = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
    producer.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    let prompt0: Vec<i32> = (0..t0 as i32).collect();
    assert!(producer.register_prefix(&mut tree, 9, &prompt0, &[1.0]));
    assert_eq!(tree.pages_pinned(), 0);
    assert_eq!(tree.node_count(), 0, "a residual-only tail anchors no node");
    tree.audit().unwrap();

    let mut s = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
    let m = full_hit(&mut tree, 9, &prompt0, cc.group);
    s.install_prefix(&m).unwrap();
    drop(m);
    let mut p = RequestCache::new(&mc, &cc, &specs, Method::kivi("kv2"), 32);
    p.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert_mirrors(&s, &p, &mc, "residual-only install");
    // drive both through the first private flush: plans appear, identical
    let mut tail = Pcg32::seeded(1014);
    for step in 0..40 {
        let (kn, vn, qn) = rand_kv(&mut tail, &mc, 1);
        s.append(&kn, &vn, &qn).unwrap();
        p.append(&kn, &vn, &qn).unwrap();
        if step % 8 == 0 {
            assert_mirrors(&s, &p, &mc, &format!("residual-only step {step}"));
        }
    }
    assert!(s.qlen > 0 && s.heads[0][0].planned);
    assert_eq!(s.shared_pages(), 0, "divergence pages are private");
    assert_mirrors(&s, &p, &mc, "residual-only end");
}

/// Two different prompts never collide: distinct chain keys, distinct
/// tails, and the tree sheds LRU under its page cap while co-tenant
/// references keep evicted entries' pages alive until their holders
/// retire.
#[test]
fn distinct_prompts_get_distinct_entries_and_lru_respects_holders() {
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec];
    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
    pool.prewarm(64);
    // cap: exactly one 96-token prompt's pages (64 quantized = 2 groups x
    // 2 heads = 4 pages) — the second registration must shed the first
    let mut tree = RadixTree::new(4, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(1021);

    let (ka, va, qaa) = rand_kv(&mut rng, &mc, 96);
    let prompt_a: Vec<i32> = (0..96).collect();
    let prompt_b: Vec<i32> = (1000..1096).collect();
    let key_a = prompt_chain_key(100, &prompt_a, cc.group);
    let key_b = prompt_chain_key(200, &prompt_b, cc.group);
    let mut a = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    a.load_prefill(&ka, &va, &qaa, 96).unwrap();
    assert!(a.register_prefix(&mut tree, 100, &prompt_a, &[0.0]));

    // a consumer holds prompt A's pages
    let mut holder = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    let m = full_hit(&mut tree, 100, &prompt_a, cc.group);
    holder.install_prefix(&m).unwrap();
    drop(m);
    let a_pages = holder.leased_pages();
    assert_eq!(pool.leased(), a_pages);

    let (kb, vb, qab) = rand_kv(&mut rng, &mc, 96);
    let mut b = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    b.load_prefill(&kb, &vb, &qab, 96).unwrap();
    assert!(b.register_prefix(&mut tree, 200, &prompt_b, &[0.0]));
    // A's whole chain (tail + both nodes) was shed for the cap, but the
    // holder (and producer a) keep its pages alive — shedding breaks
    // retention, never correctness
    assert!(!tree.contains(key_a));
    assert!(tree.contains(key_b));
    tree.audit().unwrap();
    assert_eq!(pool.leased(), 2 * a_pages, "A pages alive via holders, B pinned");
    let before = snapshot(&holder, &mc);
    drop(a);
    assert_eq!(snapshot(&holder, &mc), before, "holder's bytes untouched by shed");
    drop(holder);
    assert_eq!(pool.leased(), a_pages, "only B's pinned pages remain");
    drop(b);
    tree.clear();
    assert_eq!(pool.leased(), 0);
}
