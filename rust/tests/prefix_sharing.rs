//! Cross-request prefix sharing invariants (no artifacts needed):
//!
//! * **bit-identity property**: K requests adopting one registered prompt
//!   (refcounted copy-on-write pages) and then diverging — per-request
//!   decode appends, flushes, sliding-window eviction, mid-flight cancel —
//!   must stay bitwise equal to K private caches fed the same data at every
//!   step: page contents, channel plans, |Q| state, residual rows;
//! * **deduped page budget**: while K requests share a prefix, the pool
//!   holds prefix pages ONCE (`~1/K`× private mode) plus each request's
//!   private divergence tail — never more;
//! * **no leaks**: after every drain (drops, cancels, index clear)
//!   `pool.leased() == 0`;
//! * **seam discipline**: evicting shared pages drops only the local
//!   table reference; co-tenants and the index keep the bytes alive.

use mixkvq::kvcache::cache::{ContiguousHead, RequestCache};
use mixkvq::kvcache::eviction::CachePolicy;
use mixkvq::kvcache::pool::{KvPool, PrefixIndex};
use mixkvq::model::config::{CacheConfig, ModelConfig};
use mixkvq::quant::methods::Method;
use mixkvq::quant::window::TierSpec;
use mixkvq::util::rng::Pcg32;

fn rand_kv(
    rng: &mut Pcg32,
    mc: &ModelConfig,
    t: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = mc.n_kv_heads * t * mc.d_head;
    let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let qa = (0..mc.n_layers)
        .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
        .collect();
    (k, v, qa)
}

fn snapshot(cache: &RequestCache, mc: &ModelConfig) -> Vec<ContiguousHead> {
    (0..mc.n_layers)
        .flat_map(|l| (0..mc.n_kv_heads).map(move |h| (l, h)))
        .map(|(l, h)| cache.heads[l][h].contiguous())
        .collect()
}

fn assert_mirrors(shared: &RequestCache, private: &RequestCache, mc: &ModelConfig, ctx: &str) {
    assert_eq!(shared.qlen, private.qlen, "{ctx}: qlen");
    assert_eq!(shared.pos, private.pos, "{ctx}: pos");
    assert_eq!(shared.rlen(), private.rlen(), "{ctx}: rlen");
    assert_eq!(shared.evicted_tokens, private.evicted_tokens, "{ctx}: evicted");
    for l in 0..mc.n_layers {
        for h in 0..mc.n_kv_heads {
            let (a, b) = (&shared.heads[l][h], &private.heads[l][h]);
            assert_eq!(a.idx, b.idx, "{ctx}: l{l}h{h} plan");
            assert_eq!(a.contiguous(), b.contiguous(), "{ctx}: l{l}h{h} pages");
            assert_eq!(a.res.keys(), b.res.keys(), "{ctx}: l{l}h{h} res keys");
            assert_eq!(a.res.values(), b.res.values(), "{ctx}: l{l}h{h} res values");
            assert_eq!(a.qstats.sum_abs, b.qstats.sum_abs, "{ctx}: l{l}h{h} qstats");
        }
    }
}

/// The headline property: K sharers with divergent decode tails under
/// append/flush/evict/cancel churn stay bit-identical to K private caches,
/// the pool never exceeds the deduped budget (prefix once + private
/// tails), and everything drains to zero leases.
#[test]
fn k_sharers_stay_bit_identical_to_private_caches_under_churn() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig { capacity: 256, residual: 64, ..CacheConfig::default_build() };
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let r_limit = 32;
    let k_req = 3usize;
    let method = Method::mixkvq("mix30");

    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(512));
    pool.prewarm(512);
    let mut index = PrefixIndex::new(256, pool.page_deploy_bytes());

    // one shared prompt: 160 tokens = 128 quantized (4 groups/head) + 32
    // residual; a producer registers it, K consumers adopt it
    let mut seed_rng = Pcg32::seeded(1009);
    let t0 = 160;
    let (k0, v0, qa0) = rand_kv(&mut seed_rng, &mc, t0);
    let prompt0: Vec<i32> = (0..t0 as i32).collect();
    let mut producer = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
    producer.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert!(producer.register_prefix(&mut index, 0xfeed, &prompt0, &[0.25, 0.75]));
    let prefix_pages = pool.leased();
    assert_eq!(prefix_pages, (128 / cc.group) * mc.n_layers * mc.n_kv_heads);
    drop(producer);
    assert_eq!(pool.leased(), prefix_pages, "index pins the prefix alone");

    let mut shared: Vec<Option<RequestCache>> = Vec::new();
    let mut private: Vec<Option<RequestCache>> = Vec::new();
    let mut tail_rngs: Vec<Pcg32> = Vec::new();
    for r in 0..k_req {
        let mut s = RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), r_limit);
        s.install_prefix(index.lookup(0xfeed, &prompt0).unwrap()).unwrap();
        // request 1 diverges in POLICY too: sliding-window eviction that
        // will eventually splice shared pages out of its own table
        if r == 1 {
            s.policy = CachePolicy::SlidingWindow { sink: 32, evict: 32 };
        }
        let mut p = RequestCache::new(&mc, &cc, &specs, method.clone(), r_limit);
        p.load_prefill(&k0, &v0, &qa0, t0).unwrap();
        if r == 1 {
            p.policy = CachePolicy::SlidingWindow { sink: 32, evict: 32 };
        }
        assert_mirrors(&s, &p, &mc, &format!("install r{r}"));
        shared.push(Some(s));
        private.push(Some(p));
        tail_rngs.push(Pcg32::seeded(7000 + r as u64));
    }
    assert_eq!(pool.leased(), prefix_pages, "K installs lease ZERO new pages");

    let mut max_leased = pool.leased();
    for step in 0..220 {
        for r in 0..k_req {
            let (Some(s), Some(p)) = (&mut shared[r], &mut private[r]) else { continue };
            // divergent tails: each request's decode stream is distinct
            let (kn, vn, qn) = rand_kv(&mut tail_rngs[r], &mc, 1);
            match (s.append(&kn, &vn, &qn), p.append(&kn, &vn, &qn)) {
                (Ok(()), Ok(())) => {}
                (Err(_), Err(_)) => {
                    // both exhaust identically (Stop policy fills up)
                    continue;
                }
                (a, b) => panic!("r{r} step {step}: shared {a:?} vs private {b:?} diverged"),
            }
            if step % 10 == r {
                assert_mirrors(s, p, &mc, &format!("step {step} r{r}"));
            }
        }
        // deduped page budget: prefix once + every live request's private
        // divergence tail — never a page more
        let tails: usize = shared
            .iter()
            .flatten()
            .map(RequestCache::private_pages)
            .sum();
        assert_eq!(
            pool.leased(),
            prefix_pages + tails,
            "step {step}: pool must hold prefix ONCE plus private tails"
        );
        max_leased = max_leased.max(pool.leased());
        // cancel churn: request 2 retires mid-flight
        if step == 120 {
            let before = pool.leased();
            let dropped_tail = shared[2].as_ref().unwrap().private_pages();
            shared[2] = None;
            private[2] = None;
            assert_eq!(
                pool.leased(),
                before - dropped_tail,
                "cancel returns ONLY the private tail (prefix stays shared)"
            );
        }
    }

    // the eviction-policy sharer must have spliced shared pages out of its
    // OWN table without disturbing anyone else
    let evictor = shared[1].as_ref().unwrap();
    assert!(evictor.evicted_tokens > 0, "sliding window must have evicted");
    assert!(
        evictor.shared_prefix_tokens < 128,
        "eviction must consume the shared seam counter"
    );
    let survivor = shared[0].as_ref().unwrap();
    let survivor_snap = snapshot(survivor, &mc);
    assert_eq!(
        survivor_snap,
        snapshot(private[0].as_ref().unwrap(), &mc),
        "co-tenant unaffected by another sharer's eviction"
    );

    // drain: drop all sharers → only the index pin remains → clear → zero
    shared.clear();
    private.clear();
    assert_eq!(pool.leased(), prefix_pages, "after drops only the index pins pages");
    index.clear();
    assert_eq!(pool.leased(), 0, "no leaks after the index lets go");
    assert!(max_leased <= 512, "budget never exceeded");
}

/// A prompt shorter than the residual limit registers a zero-page entry —
/// consumers still skip the prefill (residual + |Q| state adopted) and
/// plan their channels privately at the first flush, bit-identical to
/// private mode.
#[test]
fn residual_only_prompt_shares_compute_not_pages() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
    pool.prewarm(64);
    let mut index = PrefixIndex::new(64, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(1013);
    let t0 = 24; // < r_limit = 32: zero pages, residual only
    let (k0, v0, qa0) = rand_kv(&mut rng, &mc, t0);
    let mut producer = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
    producer.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    let prompt0: Vec<i32> = (0..t0 as i32).collect();
    assert!(producer.register_prefix(&mut index, 9, &prompt0, &[1.0]));
    assert_eq!(index.pages_pinned(), 0);

    let mut s = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
    s.install_prefix(index.lookup(9, &prompt0).unwrap()).unwrap();
    let mut p = RequestCache::new(&mc, &cc, &specs, Method::kivi("kv2"), 32);
    p.load_prefill(&k0, &v0, &qa0, t0).unwrap();
    assert_mirrors(&s, &p, &mc, "residual-only install");
    // drive both through the first private flush: plans appear, identical
    let mut tail = Pcg32::seeded(1014);
    for step in 0..40 {
        let (kn, vn, qn) = rand_kv(&mut tail, &mc, 1);
        s.append(&kn, &vn, &qn).unwrap();
        p.append(&kn, &vn, &qn).unwrap();
        if step % 8 == 0 {
            assert_mirrors(&s, &p, &mc, &format!("residual-only step {step}"));
        }
    }
    assert!(s.qlen > 0 && s.heads[0][0].planned);
    assert_eq!(s.shared_pages(), 0, "divergence pages are private");
    assert_mirrors(&s, &p, &mc, "residual-only end");
}

/// Two different prompts never collide: distinct keys, distinct entries,
/// and the index sheds LRU under its page cap while co-tenant references
/// keep evicted entries' pages alive until their holders retire.
#[test]
fn distinct_prompts_get_distinct_entries_and_lru_respects_holders() {
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec];
    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
    pool.prewarm(64);
    // cap: exactly one 96-token prompt's pages (64 quantized = 2 groups x
    // 2 heads = 4 pages) — the second registration must shed the first
    let mut index = PrefixIndex::new(4, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(1021);

    let (ka, va, qaa) = rand_kv(&mut rng, &mc, 96);
    let prompt_a: Vec<i32> = (0..96).collect();
    let prompt_b: Vec<i32> = (1000..1096).collect();
    let mut a = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    a.load_prefill(&ka, &va, &qaa, 96).unwrap();
    assert!(a.register_prefix(&mut index, 100, &prompt_a, &[0.0]));

    // a consumer holds prompt A's pages
    let mut holder = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    holder.install_prefix(index.lookup(100, &prompt_a).unwrap()).unwrap();
    let a_pages = holder.leased_pages();
    assert_eq!(pool.leased(), a_pages);

    let (kb, vb, qab) = rand_kv(&mut rng, &mc, 96);
    let mut b = RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    b.load_prefill(&kb, &vb, &qab, 96).unwrap();
    assert!(b.register_prefix(&mut index, 200, &prompt_b, &[0.0]));
    // A's entry was shed for the cap, but the holder (and producer a) keep
    // its pages alive — shedding breaks retention, never correctness
    assert!(!index.contains(100));
    assert!(index.contains(200));
    assert_eq!(pool.leased(), 2 * a_pages, "A pages alive via holders, B pinned");
    let before = snapshot(&holder, &mc);
    drop(a);
    assert_eq!(snapshot(&holder, &mc), before, "holder's bytes untouched by shed");
    drop(holder);
    assert_eq!(pool.leased(), a_pages, "only B's pinned pages remain");
    drop(b);
    index.clear();
    assert_eq!(pool.leased(), 0);
}
