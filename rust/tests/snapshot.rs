//! Crash-safety suite: deterministic snapshot/restore of a live `Server`
//! with integrity-checked KV pages.
//!
//! The contract under test (lib.rs "Crash recovery & snapshot ABI"):
//!
//! 1. **Equivalence** — killing the server at an arbitrary tick boundary,
//!    restoring from the snapshot bytes, and draining produces the exact
//!    event stream of the uninterrupted same-seed run, across methods,
//!    worker widths {1, 4}, and chaos on/off (`harness::traffic` level and
//!    raw `Server` level both) — including a kill with a populated prefix
//!    radix tree and in-flight frozen-plan partial-hit prefills;
//! 2. **Degradation, not abortion** — a snapshot whose every KV page took
//!    a bit flip still restores: each corrupt page is quarantined and only
//!    its owning request retires `Error`; queued (page-less) requests ride
//!    through and complete;
//! 3. **Torn writes fail cleanly** — an injected mid-stream write fault
//!    makes `snapshot` return `Err` and leaves the live server serving;
//! 4. **Truncation never panics** — every prefix of a valid snapshot is a
//!    descriptive `Err` from `restore`, not a slice panic or an abort.
//!
//! Runs on the artifact-free reference engine, so this is tier-1.

use std::collections::HashMap;

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::events::{by_request, validate_stream, Event};
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::{FinishReason, Request};
use mixkvq::harness::traffic::{
    deterministic_pair, run, run_with_kill, Arrival, TrafficConfig,
};
use mixkvq::harness::workloads;
use mixkvq::model::config::{Meta, ModelConfig};
use mixkvq::model::sampler::Sampling;
use mixkvq::quant::methods::Method;
use mixkvq::util::faults::{FaultPlan, FaultSite};
use mixkvq::util::rng::Pcg32;

/// Two-layer build so the sweep stays cheap.
fn small_meta() -> Meta {
    let mut meta = Meta::default_build();
    meta.model = ModelConfig { n_layers: 2, ..meta.model };
    for v in &mut meta.variants {
        v.layers.truncate(2);
        while v.layers.len() < 2 {
            let last = *v.layers.last().unwrap();
            v.layers.push(last);
        }
    }
    meta
}

fn small_engine() -> Engine {
    Engine::new_reference(small_meta(), 11, Method::bf16(), 32).unwrap()
}

fn small_cfg(workers: usize, chaos: f64) -> TrafficConfig {
    TrafficConfig {
        seed: 1717,
        sessions: 24,
        tenants: 3,
        arrival: Arrival::PoissonBurst {
            rate: 4.0,
            burst_every: 10,
            burst_len: 3,
            burst_rate: 12.0,
        },
        max_new: 5,
        prompt_pool: 4,
        prompt_lo: 24,
        prompt_hi: 64,
        chaos,
        workers,
        max_prefills_per_cycle: 2,
        ..TrafficConfig::default()
    }
}

fn gen_request(rng: &mut Pcg32, id: u64) -> Request {
    let ctx = 16 + rng.below(32) as usize;
    Request {
        id,
        prompt: workloads::gen_passkey(rng, ctx).prompt,
        max_new_tokens: 2 + rng.below(5) as usize,
        sampling: Sampling::Greedy,
        method: None,
        tenant: rng.below(3),
        deadline_ticks: None,
    }
}

/// Submit `n` requests and tick until pages are actually leased — the
/// snapshot under test must carry live KV state, not an idle server.
fn warm_server(server: &mut Server, seed: u64, n: usize) -> HashMap<u64, usize> {
    let mut rng = Pcg32::seeded(seed);
    let mut max_new = HashMap::new();
    for i in 0..n {
        let req = gen_request(&mut rng, i as u64);
        max_new.insert(req.id, req.max_new_tokens);
        server.submit(req).unwrap();
    }
    let mut guard = 0;
    while server.pool.leased() == 0 {
        server.tick().unwrap();
        guard += 1;
        assert!(guard < 100, "server never leased a page");
    }
    server.check_invariants().unwrap();
    max_new
}

/// Tick to drain, auditing invariants every tick; returns all events.
fn drain(server: &mut Server) -> Vec<Event> {
    let mut events = server.drain_events();
    let mut guard = 0;
    while server.has_work() {
        server.tick().unwrap();
        server.check_invariants().unwrap();
        events.extend(server.drain_events());
        guard += 1;
        assert!(guard < 10_000, "drain stalled");
    }
    events.extend(server.drain_events());
    events
}

/// Equivalence at the harness level: kill-at-tick → restore → drain must
/// reproduce the uninterrupted run's fingerprint bit for bit, across
/// method mixes × worker widths {1, 4} × chaos on/off.
#[test]
fn kill_and_restore_matches_uninterrupted_across_configs() {
    let mixes: [&[&str]; 2] = [&[], &["mixkvq-mix225", "kivi-kv2"]];
    for mix in mixes {
        for workers in [1usize, 4] {
            for chaos in [0.0, 0.1] {
                let mut cfg = small_cfg(workers, chaos);
                cfg.method_mix = mix.iter().map(|s| s.parse().unwrap()).collect();
                let label = format!("mix={mix:?} workers={workers} chaos={chaos}");
                let mk = || Engine::new_reference(small_meta(), 11, Method::bf16(), 32);
                let clean = run(mk().unwrap(), &cfg).unwrap();
                let (restored, stats) = run_with_kill(&mk, &cfg, 3).unwrap();
                assert!(stats.snapshot_bytes > 0, "{label}: kill tick never reached");
                assert!(
                    deterministic_pair(&clean, &restored),
                    "{label}: killed-and-restored run drifted \
                     (fingerprint {:016x} vs {:016x})",
                    clean.fingerprint,
                    restored.fingerprint
                );
                assert_eq!(
                    clean.faults_injected, restored.faults_injected,
                    "{label}: fault story diverged across the restore"
                );
                assert_eq!(restored.leaked_pages, 0, "{label}: leaked pages");
            }
        }
    }
}

/// Equivalence at the raw `Server` level: after the snapshot point both
/// the original (uninterrupted) server and the restored replica receive
/// zero further input — their drained event streams must be identical.
#[test]
fn restored_server_replays_the_original_event_stream() {
    let cfg = ServerConfig { seed: 31, max_prefills_per_cycle: 2, ..ServerConfig::default() };
    let mut server = Server::new(small_engine(), cfg.clone());
    let max_new = warm_server(&mut server, 31, 10);
    let pre = server.drain_events(); // both tails start from an empty log

    let mut buf: Vec<u8> = Vec::new();
    let bytes = server.snapshot(&mut buf).unwrap();
    assert_eq!(bytes as usize, buf.len());
    assert_eq!(server.metrics.snapshots, 1);

    let tail_live = drain(&mut server);
    drop(server); // the "crash"

    let mut replica = Server::restore(small_engine(), cfg, buf.as_slice()).unwrap();
    replica.check_invariants().unwrap();
    assert_eq!(replica.metrics.restores, 1);
    assert_eq!(replica.metrics.pages_quarantined, 0);
    assert_eq!(replica.scrub(), 0, "clean restore must scrub clean");
    let tail_replica = drain(&mut replica);
    assert_eq!(
        tail_live, tail_replica,
        "restored server diverged from the uninterrupted original"
    );

    // the combined stream is well-formed per request
    let mut events = pre;
    events.extend(tail_replica);
    let streams = by_request(&events);
    assert_eq!(streams.len(), max_new.len());
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
    }
}

/// Degradation, not abortion: with `SnapshotCorrupt` armed at rate 1.0
/// EVERY serialized page takes a bit flip. The restore must still succeed
/// — each corrupt page quarantined, only its owning (admitted) request
/// retired `Error` — while queued page-less requests complete normally.
#[test]
fn fully_corrupt_snapshot_degrades_per_request_never_aborts() {
    let cfg = ServerConfig {
        seed: 47,
        faults: Some(
            FaultPlan::uniform(47, 0.0).with_rate(FaultSite::SnapshotCorrupt, 1.0),
        ),
        max_prefills_per_cycle: 1,
        ..ServerConfig::default()
    };
    let mut server = Server::new(small_engine(), cfg.clone());
    let n = 10;
    let max_new = warm_server(&mut server, 47, n);
    server.drain_events();

    let mut buf: Vec<u8> = Vec::new();
    server.snapshot(&mut buf).unwrap();
    drop(server);

    let mut replica = Server::restore(small_engine(), cfg, buf.as_slice()).unwrap();
    replica.check_invariants().unwrap();
    assert!(
        replica.metrics.pages_quarantined > 0,
        "rate-1.0 corruption must quarantine every restored page"
    );
    assert!(
        replica.metrics.restore_retired > 0,
        "page-owning requests must retire at restore"
    );
    let events = drain(&mut replica);
    let streams = by_request(&events);
    let mut errored = 0;
    let mut completed = 0;
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
        match stream.last() {
            Some(Event::Finished { reason: FinishReason::Error, .. }) => errored += 1,
            Some(Event::Finished { .. }) => completed += 1,
            other => panic!("req {id}: no terminal event, got {other:?}"),
        }
    }
    assert_eq!(errored as u64, replica.metrics.restore_retired);
    assert!(
        completed > 0,
        "queued page-less requests must survive a fully corrupt snapshot"
    );
    replica.check_invariants().unwrap();
}

/// A seeded partial corruption rate quarantines a strict subset and stays
/// reproducible: same seed, same snapshot, same casualty list.
#[test]
fn partial_corruption_is_deterministic() {
    let attempt = || {
        let cfg = ServerConfig {
            seed: 53,
            faults: Some(
                FaultPlan::uniform(53, 0.0).with_rate(FaultSite::SnapshotCorrupt, 0.4),
            ),
            max_prefills_per_cycle: 1,
            ..ServerConfig::default()
        };
        let mut server = Server::new(small_engine(), cfg.clone());
        warm_server(&mut server, 53, 10);
        server.drain_events();
        let mut buf: Vec<u8> = Vec::new();
        server.snapshot(&mut buf).unwrap();
        let mut replica = Server::restore(small_engine(), cfg, buf.as_slice()).unwrap();
        replica.check_invariants().unwrap();
        let events = drain(&mut replica);
        (replica.metrics.pages_quarantined, replica.metrics.restore_retired, events)
    };
    let (q1, r1, e1) = attempt();
    let (q2, r2, e2) = attempt();
    assert_eq!(q1, q2, "quarantine count must replay bit-for-bit");
    assert_eq!(r1, r2, "casualty count must replay bit-for-bit");
    assert_eq!(e1, e2, "post-restore event streams must replay bit-for-bit");
}

/// Torn writes: with `SnapshotWrite` armed at 1.0 the snapshot attempt
/// errors mid-stream — and the LIVE server keeps serving as if nothing
/// happened (the operator keeps the previous snapshot file).
#[test]
fn torn_snapshot_write_errors_and_leaves_the_server_serving() {
    let cfg = ServerConfig {
        seed: 61,
        faults: Some(
            FaultPlan::uniform(61, 0.0).with_rate(FaultSite::SnapshotWrite, 1.0),
        ),
        ..ServerConfig::default()
    };
    let mut server = Server::new(small_engine(), cfg);
    let max_new = warm_server(&mut server, 61, 8);
    let mut buf: Vec<u8> = Vec::new();
    let err = server.snapshot(&mut buf).unwrap_err();
    assert!(
        err.to_string().contains("torn"),
        "torn-write error must say what happened: {err}"
    );
    // serving continues: drain clean, every stream terminal
    server.check_invariants().unwrap();
    let events = drain(&mut server);
    let streams = by_request(&events);
    assert_eq!(streams.len(), max_new.len());
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
        assert!(matches!(stream.last(), Some(Event::Finished { .. })));
    }
}

/// Every truncation of a valid snapshot is a descriptive `Err`, never a
/// panic — the restore path must survive arbitrarily torn files.
#[test]
fn truncated_snapshots_error_never_panic() {
    let cfg = ServerConfig { seed: 67, ..ServerConfig::default() };
    let mut server = Server::new(small_engine(), cfg.clone());
    warm_server(&mut server, 67, 6);
    let mut buf: Vec<u8> = Vec::new();
    server.snapshot(&mut buf).unwrap();
    drop(server);

    // all of the header region, a spread across the body, the final byte
    let mut cuts: Vec<usize> = (0..buf.len().min(64)).collect();
    for k in 1..=16usize {
        cuts.push(buf.len() * k / 17);
    }
    cuts.push(buf.len().saturating_sub(1));
    cuts.sort_unstable();
    cuts.dedup();
    for cut in cuts {
        let r = Server::restore(small_engine(), cfg.clone(), &buf[..cut]);
        assert!(r.is_err(), "restore from {cut}/{} bytes must fail", buf.len());
    }
}

/// The radix-tree roundtrip: kill a server with a POPULATED prefix tree
/// (a registered shared prompt) and IN-FLIGHT partial-hit prefills, at
/// worker widths {1, 4}. The restore must rebuild the tree exactly —
/// entries, nodes, pinned pages, hit/partial-hit counters — pass the
/// structural audit, and the drained event stream must match the
/// uninterrupted server's bit for bit.
#[test]
fn populated_tree_and_in_flight_partial_hits_survive_the_kill() {
    for workers in [1usize, 4] {
        let cfg = ServerConfig {
            seed: 83,
            max_prefills_per_cycle: 2,
            // one chunk per tick keeps wave-2 prefills in flight at the
            // kill point — the snapshot must carry resumed-run state
            prefill_chunks_per_tick: 1,
            workers,
            ..ServerConfig::default()
        };
        let mut server = Server::new(small_engine(), cfg.clone());
        let mk_req = |id: u64, prompt: Vec<i32>, max_new: usize| Request {
            id,
            prompt,
            max_new_tokens: max_new,
            sampling: Sampling::Greedy,
            method: None,
            tenant: 0,
            deadline_ticks: None,
        };
        // wave 1: the producer — 96 tokens = 2 quantized groups + 32
        // residual; drain it so its prefill registers in the tree
        let prefix: Vec<i32> = (0..96).map(|i| (i * 7 % 126) as i32 + 1).collect();
        let mut max_new: HashMap<u64, usize> = HashMap::new();
        max_new.insert(0, 2);
        server.submit(mk_req(0, prefix.clone(), 2)).unwrap();
        let mut guard = 0;
        while server.has_work() {
            server.tick().unwrap();
            guard += 1;
            assert!(guard < 1000, "workers={workers}: producer never drained");
        }
        let tree = server.engine.prefix_tree().expect("tree on by default").clone();
        assert_eq!(tree.borrow().len(), 1, "producer prompt must register");
        assert!(tree.borrow().pages_pinned() > 0);

        // wave 2: four sharers diverging after the shared two groups (the
        // frozen-plan partial-hit path) plus one exact repeat (full hit)
        for r in 1..=4u64 {
            let mut p = prefix[..64].to_vec();
            p.extend((0..32).map(|i| ((r as i32 * 13 + i) % 126) + 1));
            max_new.insert(r, 3);
            server.submit(mk_req(r, p, 3)).unwrap();
        }
        max_new.insert(5, 3);
        server.submit(mk_req(5, prefix.clone(), 3)).unwrap();
        server.tick().unwrap();
        server.tick().unwrap();
        let before = tree.borrow().stats();
        assert!(
            before.partial_hits > 0,
            "workers={workers}: wave 2 must record partial hits before the kill"
        );
        assert!(
            server.prefills_in_flight() > 0,
            "workers={workers}: the kill point must have prefills in flight"
        );

        // the event log is not part of the snapshot — drain it so the
        // live tail and the replica tail start from the same empty log
        let pre = server.drain_events();
        let mut buf: Vec<u8> = Vec::new();
        server.snapshot(&mut buf).unwrap();
        let tail_live = drain(&mut server);
        drop(server); // the "crash"

        let mut replica = Server::restore(small_engine(), cfg, buf.as_slice()).unwrap();
        replica.check_invariants().unwrap();
        let rtree = replica.engine.prefix_tree().expect("restored tree").clone();
        {
            let t = rtree.borrow();
            t.audit().unwrap();
            let after = t.stats();
            assert_eq!(after.entries, before.entries, "workers={workers}: entries");
            assert_eq!(after.nodes, before.nodes, "workers={workers}: nodes");
            assert_eq!(
                after.pages_pinned, before.pages_pinned,
                "workers={workers}: pinned pages"
            );
            assert_eq!(after.hits, before.hits, "workers={workers}: hit counter");
            assert_eq!(
                after.partial_hits, before.partial_hits,
                "workers={workers}: partial-hit counter"
            );
        }
        let tail_replica = drain(&mut replica);
        assert_eq!(
            tail_live, tail_replica,
            "workers={workers}: restored server diverged from the original"
        );
        let mut events = pre;
        events.extend(tail_replica);
        let streams = by_request(&events);
        assert_eq!(streams.len(), max_new.len(), "workers={workers}: stream count");
        for (id, stream) in &streams {
            validate_stream(stream, max_new[id]).unwrap();
        }
        // the replayed sharers drained: only the tree's deliberate
        // retention may remain leased
        assert_eq!(replica.pool.leased(), rtree.borrow().pages_pinned());
    }
}

/// Geometry guard: a snapshot taken under one server geometry must refuse
/// to load into a server built differently, naming the field.
#[test]
fn geometry_mismatch_is_refused_by_name() {
    let cfg = ServerConfig { seed: 71, ..ServerConfig::default() };
    let mut server = Server::new(small_engine(), cfg.clone());
    warm_server(&mut server, 71, 4);
    let mut buf: Vec<u8> = Vec::new();
    server.snapshot(&mut buf).unwrap();
    drop(server);

    // same model, different residual budget — a geometry field
    let narrow = Engine::new_reference(small_meta(), 11, Method::bf16(), 16).unwrap();
    let err = Server::restore(narrow, cfg, buf.as_slice()).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("geometry") && msg.contains("r_limit"),
        "geometry refusal must name the field: {msg}"
    );
}
