//! Integration tests over the real AOT artifacts: HLO executables vs the
//! pure-Rust reference (DESIGN.md invariant #8), plus end-to-end serving.
//!
//! These need `make artifacts` to have run; they skip (with a notice) when
//! artifacts/ is absent so plain `cargo test` works in a fresh checkout.

use std::path::{Path, PathBuf};

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::events::{by_request, validate_stream, RequestStatus};
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::{FinishReason, Request};
use mixkvq::harness::accuracy;
use mixkvq::harness::perplexity;
use mixkvq::harness::refdriver::RefDriver;
use mixkvq::harness::workloads::{self, suite, TaskKind};
use mixkvq::model::config::Meta;
use mixkvq::model::reference::RefModel;
use mixkvq::model::sampler::Sampling;
use mixkvq::model::tokenizer;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::rng::Pcg32;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("meta.json").exists() && p.join("decode_mix30.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => return,
        }
    };
}

#[test]
fn prefill_matches_reference_forward() {
    let dir = need_artifacts!();
    let meta = Meta::load(&dir).unwrap();
    let weights = Weights::load(&dir, &meta.model).unwrap();
    let refm = RefModel::new(meta.model.clone(), &weights);
    let mut engine = Engine::new(&dir, Method::bf16(), 128).unwrap();
    let mut rng = Pcg32::seeded(1);
    let task = workloads::gen_kvlookup(&mut rng, 6);
    let pre = engine.prefill(&task.prompt).unwrap();
    let (_, ref_pre) = refm.forward_full(&task.prompt);
    let max_err = pre
        .last_logits
        .iter()
        .zip(&ref_pre.last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "HLO vs reference logits diverge: {max_err}");
    // K/V agreement, layer 0 head 0
    let t = task.prompt.len();
    let dh = meta.model.d_head;
    let kerr = pre.k[0][..t * dh]
        .iter()
        .zip(&ref_pre.k[0][..t * dh])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(kerr < 1e-3, "prefill K mismatch {kerr}");
    let qerr = pre.qabs[0]
        .iter()
        .zip(&ref_pre.qabs[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(qerr < 1e-3, "prefill qabs mismatch {qerr}");
}

#[test]
fn hlo_decode_matches_reference_driver_quantized() {
    let dir = need_artifacts!();
    let meta = Meta::load(&dir).unwrap();
    let weights = Weights::load(&dir, &meta.model).unwrap();
    for method in [Method::bf16(), Method::mixkvq("mix30"), Method::kivi("kv2")] {
        let spec = meta.variant(&method.variant).unwrap().layers.clone();
        let driver = RefDriver::new(
            meta.model.clone(),
            meta.cache.clone(),
            &weights,
            spec,
            method.clone(),
            32,
        );
        let mut engine = Engine::new(&dir, method.clone(), 32).unwrap();
        let mut rng = Pcg32::seeded(7);
        let task = workloads::gen_passkey(&mut rng, 120); // long enough to quantize
        // HLO path
        let pre = engine.prefill(&task.prompt).unwrap();
        let mut hlo_cache = engine.quantize_prefill(&pre).unwrap();
        assert!(hlo_cache.qlen > 0, "window must quantize ({})", method.name);
        // reference path
        let (mut ref_cache, ref_last) = driver.prefill(&task.prompt).unwrap();
        assert_eq!(hlo_cache.qlen, ref_cache.qlen);
        let last_err = pre
            .last_logits
            .iter()
            .zip(&ref_last)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(last_err < 1e-2, "{}: prefill logits {last_err}", method.name);
        // 3 teacher-forced steps
        let mut cursor = task.prompt.len();
        for _ in 0..3 {
            let tok = task.gold[cursor];
            let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> =
                (0..engine.meta.cache.decode_batch).map(|_| None).collect();
            slots[0] = Some((&mut hlo_cache, tok));
            let hlo_logits = engine.decode_step(&mut slots).unwrap()[0].clone().unwrap();
            let ref_logits = driver.step(&mut ref_cache, tok).unwrap();
            let err = hlo_logits
                .iter()
                .zip(&ref_logits)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 2e-2, "{}: decode logits diverge {err}", method.name);
            cursor += 1;
        }
    }
}

#[test]
fn batched_decode_slots_are_independent() {
    // Batch isolation: a request decoded alone must get identical logits
    // when co-scheduled with other requests in the same batch.
    let dir = need_artifacts!();
    let mut engine = Engine::new(&dir, Method::mixkvq("mix30"), 32).unwrap();
    let mut rng = Pcg32::seeded(11);
    let t1 = workloads::gen_kvlookup(&mut rng, 5);
    let t2 = workloads::gen_copy(&mut rng, 6);
    let b = engine.meta.cache.decode_batch;

    let pre1 = engine.prefill(&t1.prompt).unwrap();
    let mut alone = engine.quantize_prefill(&pre1).unwrap();
    let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> = (0..b).map(|_| None).collect();
    slots[0] = Some((&mut alone, t1.gold[t1.prompt.len()]));
    let logits_alone = engine.decode_step(&mut slots).unwrap()[0].clone().unwrap();

    let pre1b = engine.prefill(&t1.prompt).unwrap();
    let pre2 = engine.prefill(&t2.prompt).unwrap();
    let mut c1 = engine.quantize_prefill(&pre1b).unwrap();
    let mut c2 = engine.quantize_prefill(&pre2).unwrap();
    let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> = (0..b).map(|_| None).collect();
    slots[0] = Some((&mut c1, t1.gold[t1.prompt.len()]));
    slots[3] = Some((&mut c2, t2.gold[t2.prompt.len()]));
    let both = engine.decode_step(&mut slots).unwrap();
    let logits_b0 = both[0].clone().unwrap();
    let err = logits_alone
        .iter()
        .zip(&logits_b0)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "slot interference: {err}");
}

#[test]
fn accuracy_harness_runs_and_bf16_beats_2bit_on_retrieval() {
    let dir = need_artifacts!();
    let mut engine = Engine::new(&dir, Method::bf16(), 128).unwrap();
    let tasks = suite(TaskKind::Passkey, 12, 5, true);
    let rep_bf16 = accuracy::evaluate(&mut engine, &tasks).unwrap();
    engine.set_method(Method::kvquant("kv2")).unwrap();
    let rep_kv2 = accuracy::evaluate(&mut engine, &tasks).unwrap();
    // the trained model must retrieve at full precision; global-scale 2-bit
    // must not be better (typically far worse)
    assert!(rep_bf16.token_acc() >= rep_kv2.token_acc());
    assert_eq!(rep_bf16.tasks, 12);
}

#[test]
fn perplexity_orders_by_precision() {
    let dir = need_artifacts!();
    let seqs = perplexity::corpus(4, 160, 3);
    let mut engine = Engine::new(&dir, Method::bf16(), 32).unwrap();
    let ppl_bf16 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    engine.set_method(Method::kivi("kv2")).unwrap();
    let ppl_kivi2 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    engine.set_method(Method::kvquant("kv2")).unwrap();
    let ppl_kvq2 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    assert!(ppl_bf16.is_finite() && ppl_kivi2.is_finite() && ppl_kvq2.is_finite());
    // grouped 2-bit may jitter around BF16 on a small corpus, but global-
    // scale 2-bit (KVQuant) must be decisively worse than full precision.
    assert!(
        ppl_kvq2 > ppl_bf16,
        "global-scale 2-bit PPL ({ppl_kvq2:.3}) should exceed BF16 ({ppl_bf16:.3})"
    );
    // and grouped scales must beat global scales at the same bit-width
    assert!(
        ppl_kivi2 < ppl_kvq2 * 1.05,
        "KIVI grouped 2-bit ({ppl_kivi2:.3}) should not be much worse than KVQuant global ({ppl_kvq2:.3})"
    );
}

/// The `Server::run` compatibility shim is token-for-token equivalent to
/// the batch driver semantics under a fixed seed: the same trace driven
/// through the manual submit/tick/poll frontend produces identical token
/// streams and finish reasons, and the event streams are well-formed.
#[test]
fn run_shim_matches_frontend_token_for_token() {
    let dir = need_artifacts!();
    let make_server = || {
        let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
        Server::new(engine, ServerConfig::default())
    };
    let trace = |seed: u64| {
        let mut rng = Pcg32::seeded(seed);
        workloads::sharegpt_trace(&mut rng, 6, 12)
    };
    // offline batch shim
    let mut batch = make_server();
    let completed = batch.run(trace(21)).unwrap();
    assert_eq!(completed.len(), 6);
    // manual frontend: submit everything, tick until drained
    let mut frontend = make_server();
    let reqs = trace(21);
    let max_new: Vec<(u64, usize)> = reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();
    let ids: Vec<u64> = reqs.into_iter().map(|r| frontend.submit(r).unwrap()).collect();
    while frontend.has_work() {
        frontend.tick().unwrap();
    }
    for id in ids {
        let want = completed.iter().find(|c| c.id == id).unwrap();
        match frontend.poll(id) {
            RequestStatus::Finished { reason, tokens } => {
                assert_eq!(tokens, want.tokens, "request {id}: token streams diverge");
                assert_eq!(reason, want.reason, "request {id}");
            }
            other => panic!("request {id} not finished: {other:?}"),
        }
    }
    // lifecycle: one well-formed stream per request
    let events = frontend.drain_events();
    let grouped = by_request(&events);
    assert_eq!(grouped.len(), 6);
    for (id, stream) in grouped {
        let mn = max_new.iter().find(|(i, _)| *i == id).unwrap().1;
        validate_stream(&stream, mn).unwrap_or_else(|e| panic!("request {id}: {e}"));
    }
}

/// Two tenants with *different* `MethodSpec`s served concurrently by one
/// `Server`: per-request routing builds each cache under its own method and
/// the batcher decodes them as per-variant sub-batches in the same tick.
#[test]
fn two_method_specs_served_concurrently() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(31);
    let mut ids = Vec::new();
    for (i, method) in [None, Some(MethodSpec::Bf16)].into_iter().enumerate() {
        let task = workloads::gen_passkey(&mut rng, 100);
        ids.push(
            server
                .submit(Request {
                    id: i as u64,
                    prompt: task.prompt,
                    max_new_tokens: 8,
                    sampling: Sampling::Greedy,
                    method,
                    tenant: 0,
                    deadline_ticks: None,
                })
                .unwrap(),
        );
    }
    // one tick admits both (max_prefills_per_cycle = 2) — they are live at
    // the same time on different decode variants
    server.tick().unwrap();
    for &id in &ids {
        assert!(
            matches!(server.poll(id), RequestStatus::Running { .. } | RequestStatus::Finished { .. }),
            "request {id} should be admitted after the first tick"
        );
    }
    let live = server.batcher.variant_groups();
    if live.len() == 2 {
        assert_ne!(live[0].variant, live[1].variant, "distinct decode variants co-scheduled");
    }
    while server.has_work() {
        server.tick().unwrap();
    }
    let methods: Vec<&str> = server.metrics.completed.iter().map(|c| c.method.as_str()).collect();
    assert!(methods.contains(&"mixkvq-mix225"), "{methods:?}");
    assert!(methods.contains(&"bf16"), "{methods:?}");
    for c in &server.metrics.completed {
        assert!(!c.tokens.is_empty());
        assert!(c.ttft_ms.is_some());
    }
    let events = server.drain_events();
    for (id, stream) in by_request(&events) {
        validate_stream(&stream, 8).unwrap_or_else(|e| panic!("request {id}: {e}"));
    }
}

/// Satellite fix: a 1-token budget records the first sampled token and
/// reports `MaxTokens` (Eos only when the token actually is EOS).
#[test]
fn one_token_budget_records_token_and_reason() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::bf16(), 32).unwrap();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(41);
    let task = workloads::gen_kvlookup(&mut rng, 6);
    let completed = server
        .run(vec![Request {
            id: 0,
            prompt: task.prompt,
            max_new_tokens: 1,
            sampling: Sampling::Greedy,
            method: None,
            tenant: 0,
            deadline_ticks: None,
        }])
        .unwrap();
    assert_eq!(completed.len(), 1);
    let c = &completed[0];
    assert_eq!(c.tokens.len(), 1, "the first sampled token must be recorded");
    if c.tokens[0] == tokenizer::EOS {
        assert_eq!(c.reason, FinishReason::Eos);
    } else {
        assert_eq!(c.reason, FinishReason::MaxTokens);
    }
    assert!(c.ttft_ms.is_some());
}

/// Cancellation: a queued request cancels to a terminal record with no
/// tokens (excluded from TTFT percentiles); oversized prompts reject at
/// submit.
#[test]
fn cancel_and_reject_paths() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let max_ctx = *engine.meta.cache.prefill_buckets.iter().max().unwrap();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(51);
    let mk = |id: u64, prompt: Vec<i32>| Request {
        id,
        prompt,
        max_new_tokens: 6,
        sampling: Sampling::Greedy,
        method: None,
        tenant: 0,
        deadline_ticks: None,
    };
    // oversized prompt → rejected at submit, terminal immediately
    let big = mk(7, vec![1; max_ctx + 1]);
    server.submit(big).unwrap();
    assert!(matches!(
        server.poll(7),
        RequestStatus::Finished { reason: FinishReason::Rejected, .. }
    ));
    assert_eq!(server.metrics.rejected, 1);
    // queued cancel before any tick
    let t1 = workloads::gen_passkey(&mut rng, 80);
    let t2 = workloads::gen_passkey(&mut rng, 80);
    server.submit(mk(0, t1.prompt)).unwrap();
    server.submit(mk(1, t2.prompt)).unwrap();
    // duplicate in-flight id is a hard error, not a silent drop
    assert!(server.submit(mk(0, vec![1, 2])).is_err());
    assert!(server.cancel(1));
    assert!(!server.cancel(1), "already terminal");
    match server.poll(1) {
        RequestStatus::Finished { reason, tokens } => {
            assert_eq!(reason, FinishReason::Cancelled);
            assert!(tokens.is_empty());
        }
        other => panic!("{other:?}"),
    }
    while server.has_work() {
        server.tick().unwrap();
    }
    // first poll observes the full terminal record; the second only the
    // retired stub (reason + token count) — the record was evicted
    let (reason0, n0) = match server.poll(0) {
        RequestStatus::Finished { reason, tokens } => (reason, tokens.len()),
        other => panic!("{other:?}"),
    };
    assert!(matches!(reason0, FinishReason::Eos | FinishReason::MaxTokens));
    match server.poll(0) {
        RequestStatus::Retired { reason, n_tokens } => {
            assert_eq!(reason, reason0);
            assert_eq!(n_tokens, n0);
        }
        other => panic!("late poll must see the stub, got {other:?}"),
    }
    assert_eq!(server.metrics.cancelled, 1);
    // cancelled/rejected records carry no TTFT and don't skew percentiles
    let cancelled = server.metrics.completed.iter().find(|c| c.id == 1).unwrap();
    assert!(cancelled.ttft_ms.is_none());
    let events = server.drain_events();
    for (id, stream) in by_request(&events) {
        validate_stream(&stream, 6).unwrap_or_else(|e| panic!("request {id}: {e}"));
    }
    assert_eq!(server.poll(99), RequestStatus::Unknown);
}

#[test]
fn server_end_to_end_completes_all_requests() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(13);
    let trace = workloads::sharegpt_trace(&mut rng, 6, 12);
    let n = trace.len();
    let completed = server.run(trace).unwrap();
    assert_eq!(completed.len(), n);
    assert!(completed.iter().all(|c| !c.tokens.is_empty()));
    assert!(server.metrics.peak_mem_bytes > 0);
    let b = mixkvq::coordinator::metrics::breakdown(&server.engine.timers);
    assert!(b.model_exec_pct > 0.0);
}

/// Paged-pool serving: a deliberately tiny page budget forces parks (due
/// flushes that cannot lease) and possibly preemptions, yet every request
/// still reaches a well-formed terminal state and the pool drains to zero
/// leases afterwards — no slot ever errors a tick.
#[test]
fn pool_pressure_parks_and_drains_cleanly() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let mut server = Server::new(
        engine,
        ServerConfig {
            // a few hundred KB: enough to admit, tight enough to contend
            memory_budget_bytes: 384 << 10,
            max_prefills_per_cycle: 2,
            seed: 7,
            reserve_pages: Some(4),
            ..ServerConfig::default()
        },
    );
    let mut rng = Pcg32::seeded(17);
    let trace = workloads::sharegpt_trace(&mut rng, 8, 64);
    let n = trace.len();
    let completed = server.run(trace).unwrap();
    assert_eq!(completed.len(), n, "every request must reach a terminal state");
    // after the trace only the prefix tree's deliberate retention may
    // remain leased — every request-held page must have returned
    let pinned = server
        .engine
        .prefix_tree()
        .map(|ix| ix.borrow().pages_pinned())
        .unwrap_or(0);
    assert_eq!(
        server.pool.leased(),
        pinned,
        "pool must drain to exactly the prefix-tree retention"
    );
    assert!(
        server.metrics.pool_high_water > 0,
        "trace must have exercised the pool"
    );
    // every park episode ends in exactly one of: a resume (pages freed) or
    // a preemption (the only way a parked session can finish in this
    // trace) — nothing cancels here, so the counts must balance exactly
    assert_eq!(
        server.metrics.pool_parks,
        server.metrics.pool_resumes + server.metrics.pool_preemptions,
        "every parked slot must resume or be shed"
    );
}

/// Occupancy-based admission on the live server: with a budget the old
/// worst-case reservation would cap at ~2 concurrent requests, short
/// prompts must reach at least twice that concurrency (bounded by slots).
#[test]
fn server_occupancy_admission_beats_worst_case() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let worst = mixkvq::kvcache::accountant::MemoryAccountant::worst_case_request_bytes(
        &engine.meta.model,
        &engine.meta.cache,
        &engine.variant.layers,
    );
    let budget = 2 * worst;
    let batch = engine.meta.cache.decode_batch;
    let mut server = Server::new(
        engine,
        ServerConfig {
            memory_budget_bytes: budget,
            max_prefills_per_cycle: batch,
            seed: 5,
            reserve_pages: None,
            ..ServerConfig::default()
        },
    );
    let worst_case_batch = budget / worst; // == 2 under the old admission
    let mut rng = Pcg32::seeded(23);
    for i in 0..batch as u64 {
        // short prompts: tiny page footprints, long enough decodes that
        // they overlap in the batch
        let task = workloads::gen_kvlookup(&mut rng, 4);
        server
            .submit(Request {
                id: i,
                prompt: task.prompt,
                max_new_tokens: 24,
                sampling: Sampling::Greedy,
                method: None,
                tenant: 0,
                deadline_ticks: None,
            })
            .unwrap();
    }
    while server.has_work() {
        server.tick().unwrap();
    }
    assert!(
        server.metrics.max_concurrent >= 2 * worst_case_batch,
        "occupancy admission reached {} concurrent, worst-case allowed {}",
        server.metrics.max_concurrent,
        worst_case_batch
    );
    // drained up to the prefix tree's deliberate retention (see
    // pool_pressure_parks_and_drains_cleanly)
    let pinned = server
        .engine
        .prefix_tree()
        .map(|ix| ix.borrow().pages_pinned())
        .unwrap_or(0);
    assert_eq!(server.pool.leased(), pinned);
}
