//! Integration tests over the real AOT artifacts: HLO executables vs the
//! pure-Rust reference (DESIGN.md invariant #8), plus end-to-end serving.
//!
//! These need `make artifacts` to have run; they skip (with a notice) when
//! artifacts/ is absent so plain `cargo test` works in a fresh checkout.

use std::path::{Path, PathBuf};

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::harness::accuracy;
use mixkvq::harness::perplexity;
use mixkvq::harness::refdriver::RefDriver;
use mixkvq::harness::workloads::{self, suite, TaskKind};
use mixkvq::model::config::Meta;
use mixkvq::model::reference::RefModel;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::Method;
use mixkvq::util::rng::Pcg32;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("meta.json").exists() && p.join("decode_mix30.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => return,
        }
    };
}

#[test]
fn prefill_matches_reference_forward() {
    let dir = need_artifacts!();
    let meta = Meta::load(&dir).unwrap();
    let weights = Weights::load(&dir, &meta.model).unwrap();
    let refm = RefModel::new(meta.model.clone(), &weights);
    let mut engine = Engine::new(&dir, Method::bf16(), 128).unwrap();
    let mut rng = Pcg32::seeded(1);
    let task = workloads::gen_kvlookup(&mut rng, 6);
    let pre = engine.prefill(&task.prompt).unwrap();
    let (_, ref_pre) = refm.forward_full(&task.prompt);
    let max_err = pre
        .last_logits
        .iter()
        .zip(&ref_pre.last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "HLO vs reference logits diverge: {max_err}");
    // K/V agreement, layer 0 head 0
    let t = task.prompt.len();
    let dh = meta.model.d_head;
    let kerr = pre.k[0][..t * dh]
        .iter()
        .zip(&ref_pre.k[0][..t * dh])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(kerr < 1e-3, "prefill K mismatch {kerr}");
    let qerr = pre.qabs[0]
        .iter()
        .zip(&ref_pre.qabs[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(qerr < 1e-3, "prefill qabs mismatch {qerr}");
}

#[test]
fn hlo_decode_matches_reference_driver_quantized() {
    let dir = need_artifacts!();
    let meta = Meta::load(&dir).unwrap();
    let weights = Weights::load(&dir, &meta.model).unwrap();
    for method in [Method::bf16(), Method::mixkvq("mix30"), Method::kivi("kv2")] {
        let spec = meta.variant(&method.variant).unwrap().layers.clone();
        let driver = RefDriver::new(
            meta.model.clone(),
            meta.cache.clone(),
            &weights,
            spec,
            method.clone(),
            32,
        );
        let mut engine = Engine::new(&dir, method.clone(), 32).unwrap();
        let mut rng = Pcg32::seeded(7);
        let task = workloads::gen_passkey(&mut rng, 120); // long enough to quantize
        // HLO path
        let pre = engine.prefill(&task.prompt).unwrap();
        let mut hlo_cache = engine.admit_prefill(&pre).unwrap();
        assert!(hlo_cache.qlen > 0, "window must quantize ({})", method.name);
        // reference path
        let (mut ref_cache, ref_last) = driver.prefill(&task.prompt).unwrap();
        assert_eq!(hlo_cache.qlen, ref_cache.qlen);
        let last_err = pre
            .last_logits
            .iter()
            .zip(&ref_last)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(last_err < 1e-2, "{}: prefill logits {last_err}", method.name);
        // 3 teacher-forced steps
        let mut cursor = task.prompt.len();
        for _ in 0..3 {
            let tok = task.gold[cursor];
            let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> =
                (0..engine.meta.cache.decode_batch).map(|_| None).collect();
            slots[0] = Some((&mut hlo_cache, tok));
            let hlo_logits = engine.decode_step(&mut slots).unwrap()[0].clone().unwrap();
            let ref_logits = driver.step(&mut ref_cache, tok).unwrap();
            let err = hlo_logits
                .iter()
                .zip(&ref_logits)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 2e-2, "{}: decode logits diverge {err}", method.name);
            cursor += 1;
        }
    }
}

#[test]
fn batched_decode_slots_are_independent() {
    // Batch isolation: a request decoded alone must get identical logits
    // when co-scheduled with other requests in the same batch.
    let dir = need_artifacts!();
    let mut engine = Engine::new(&dir, Method::mixkvq("mix30"), 32).unwrap();
    let mut rng = Pcg32::seeded(11);
    let t1 = workloads::gen_kvlookup(&mut rng, 5);
    let t2 = workloads::gen_copy(&mut rng, 6);
    let b = engine.meta.cache.decode_batch;

    let pre1 = engine.prefill(&t1.prompt).unwrap();
    let mut alone = engine.admit_prefill(&pre1).unwrap();
    let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> = (0..b).map(|_| None).collect();
    slots[0] = Some((&mut alone, t1.gold[t1.prompt.len()]));
    let logits_alone = engine.decode_step(&mut slots).unwrap()[0].clone().unwrap();

    let pre1b = engine.prefill(&t1.prompt).unwrap();
    let pre2 = engine.prefill(&t2.prompt).unwrap();
    let mut c1 = engine.admit_prefill(&pre1b).unwrap();
    let mut c2 = engine.admit_prefill(&pre2).unwrap();
    let mut slots: Vec<Option<(&mut mixkvq::kvcache::cache::RequestCache, i32)>> = (0..b).map(|_| None).collect();
    slots[0] = Some((&mut c1, t1.gold[t1.prompt.len()]));
    slots[3] = Some((&mut c2, t2.gold[t2.prompt.len()]));
    let both = engine.decode_step(&mut slots).unwrap();
    let logits_b0 = both[0].clone().unwrap();
    let err = logits_alone
        .iter()
        .zip(&logits_b0)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "slot interference: {err}");
}

#[test]
fn accuracy_harness_runs_and_bf16_beats_2bit_on_retrieval() {
    let dir = need_artifacts!();
    let mut engine = Engine::new(&dir, Method::bf16(), 128).unwrap();
    let tasks = suite(TaskKind::Passkey, 12, 5, true);
    let rep_bf16 = accuracy::evaluate(&mut engine, &tasks).unwrap();
    engine.set_method(Method::kvquant("kv2")).unwrap();
    let rep_kv2 = accuracy::evaluate(&mut engine, &tasks).unwrap();
    // the trained model must retrieve at full precision; global-scale 2-bit
    // must not be better (typically far worse)
    assert!(rep_bf16.token_acc() >= rep_kv2.token_acc());
    assert_eq!(rep_bf16.tasks, 12);
}

#[test]
fn perplexity_orders_by_precision() {
    let dir = need_artifacts!();
    let seqs = perplexity::corpus(4, 160, 3);
    let mut engine = Engine::new(&dir, Method::bf16(), 32).unwrap();
    let ppl_bf16 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    engine.set_method(Method::kivi("kv2")).unwrap();
    let ppl_kivi2 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    engine.set_method(Method::kvquant("kv2")).unwrap();
    let ppl_kvq2 = perplexity::evaluate(&mut engine, &seqs).unwrap().ppl();
    assert!(ppl_bf16.is_finite() && ppl_kivi2.is_finite() && ppl_kvq2.is_finite());
    // grouped 2-bit may jitter around BF16 on a small corpus, but global-
    // scale 2-bit (KVQuant) must be decisively worse than full precision.
    assert!(
        ppl_kvq2 > ppl_bf16,
        "global-scale 2-bit PPL ({ppl_kvq2:.3}) should exceed BF16 ({ppl_bf16:.3})"
    );
    // and grouped scales must beat global scales at the same bit-width
    assert!(
        ppl_kivi2 < ppl_kvq2 * 1.05,
        "KIVI grouped 2-bit ({ppl_kivi2:.3}) should not be much worse than KVQuant global ({ppl_kvq2:.3})"
    );
}

#[test]
fn server_end_to_end_completes_all_requests() {
    let dir = need_artifacts!();
    let engine = Engine::new(&dir, Method::mixkvq("mix225"), 32).unwrap();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(13);
    let trace = workloads::sharegpt_trace(&mut rng, 6, 12);
    let n = trace.len();
    let completed = server.run(trace).unwrap();
    assert_eq!(completed.len(), n);
    assert!(completed.iter().all(|c| !c.tokens.is_empty()));
    assert!(server.metrics.peak_mem_bytes > 0);
    let b = mixkvq::coordinator::metrics::breakdown(&server.engine.timers);
    assert!(b.model_exec_pct > 0.0);
}
