//! Fused packed-code decode vs the dequantize-then-attend oracle:
//!
//! * equivalence property: ≤1e-4 logit agreement across the FULL
//!   `MethodSpec::all()` roster (every tier split, v_bits ∈ {2,4,16},
//!   grouped and global scales, rotation, clipping, layer-wise specs);
//! * steady-state zero-alloc: a counting global allocator proves a
//!   non-flushing fused decode step performs zero heap allocations;
//! * the same zero-alloc bar for a cache leasing from a shared pre-warmed
//!   `KvPool` — the serving storage configuration.
//!
//! The tests serialize on a shared lock so the allocation counter is not
//! polluted by a concurrently running test in this binary. The counting
//! allocator itself lives in tests/common (shared with the paged-cache
//! suite, which gates the shared-pool decode path the same way).

use std::sync::Mutex;

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::model::config::Meta;
use mixkvq::model::reference::DecodeScratch;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::MethodSpec;
use mixkvq::util::rng::Pcg32;

mod common;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

static SERIAL: Mutex<()> = Mutex::new(());

/// The fused path must match the oracle to ≤1e-4 logits for every
/// constructible method — quantized window populated, residual populated,
/// across several decode steps (cache advanced by the fused path).
#[test]
fn fused_matches_oracle_across_full_method_roster() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 17);
    let specs = MethodSpec::all();
    assert_eq!(specs.len(), 17, "roster drifted — update this test");
    for spec in specs {
        let method = spec.build();
        let layers = meta.variant(&method.variant).unwrap().layers.clone();
        let driver =
            RefDriver::new(mc.clone(), meta.cache.clone(), &weights, layers, method, 32);
        let mut rng = Pcg32::seeded(1700 + spec.variant().len() as u64);
        // long enough that the quantized window is populated (> r_limit)
        let prompt: Vec<i32> = (0..72).map(|_| rng.range(1, 127) as i32).collect();
        let (mut cache, _) = driver.prefill(&prompt).unwrap();
        assert!(cache.qlen >= 64, "{spec:?}: window must quantize");
        assert!(cache.rlen() > 0, "{spec:?}: residual must be populated");
        for step in 0..6 {
            let tok = rng.range(1, 127) as i32;
            let fused = driver.decode_logits_fused(&cache, tok);
            let oracle = driver.decode_logits_legacy(&cache, tok);
            let err = fused
                .iter()
                .zip(&oracle)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                err <= 1e-4,
                "{spec:?} step {step}: fused/oracle logits diverge by {err}"
            );
            assert!(fused.iter().all(|x| x.is_finite()), "{spec:?}: non-finite logits");
            driver.step(&mut cache, tok).unwrap();
        }
    }
}

/// Steady-state zero-alloc: once the scratch is warm, a decode step that
/// does not trigger a quantization flush performs zero heap allocations —
/// no dequant buffers, no per-step vectors, no powf, nothing.
#[test]
fn steady_state_fused_step_allocates_nothing() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 23);
    let method = MethodSpec::MixKvq { op: mixkvq::quant::methods::MixOp::Mix30 }.build();
    let layers = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = 32;
    let driver = RefDriver::new(mc.clone(), meta.cache.clone(), &weights, layers, method, r_limit);
    let mut rng = Pcg32::seeded(29);
    let prompt: Vec<i32> = (0..72).map(|_| rng.range(1, 127) as i32).collect();
    let (mut cache, _) = driver.prefill(&prompt).unwrap();
    assert!(cache.qlen > 0);
    let mut scratch =
        DecodeScratch::new(&mc, meta.cache.capacity + meta.cache.residual + 1);
    // warm the path once (first step may lazily touch anything)
    driver.step_with(&mut cache, 5, &mut scratch).unwrap();
    let mut measured = 0u64;
    let mut steps = 0u64;
    for _ in 0..16 {
        let tok = rng.range(1, 127) as i32;
        if cache.rlen() + 1 >= r_limit {
            // this step would flush-quantize (allocations are expected
            // there) — advance past it without measuring
            driver.step_with(&mut cache, tok, &mut scratch).unwrap();
            continue;
        }
        let before = common::alloc_count();
        driver.step_with(&mut cache, tok, &mut scratch).unwrap();
        let after = common::alloc_count();
        measured += after - before;
        steps += 1;
    }
    assert!(steps >= 8, "not enough non-flushing steps measured");
    assert_eq!(
        measured, 0,
        "steady-state fused decode allocated {measured} times over {steps} steps"
    );
}

/// Same zero-alloc bar on the SERVING storage configuration: the cache
/// leases its pages from a shared, bounded, pre-warmed pool (kvcache::pool)
/// — page provenance must not add a single steady-state allocation (pool
/// leases are excluded by pre-warming; flush steps, which lease, are
/// skipped the same way as above).
#[test]
fn steady_state_paged_pool_step_allocates_nothing() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 31);
    let method = MethodSpec::MixKvq { op: mixkvq::quant::methods::MixOp::Mix30 }.build();
    let layers = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = 32;
    let driver = RefDriver::new(mc.clone(), meta.cache.clone(), &weights, layers.clone(), method, r_limit);
    let pool = mixkvq::kvcache::pool::KvPool::for_specs(
        layers.iter(),
        mc.d_head,
        meta.cache.group,
        Some(256),
    );
    pool.prewarm(256);
    let mut rng = Pcg32::seeded(37);
    let prompt: Vec<i32> = (0..72).map(|_| rng.range(1, 127) as i32).collect();
    let (mut cache, _) = driver.prefill_pooled(&pool, &prompt).unwrap();
    assert!(cache.qlen > 0);
    assert!(cache.leased_pages() > 0, "pooled cache must hold leases");
    let mut scratch =
        DecodeScratch::new(&mc, meta.cache.capacity + meta.cache.residual + 1);
    driver.step_with(&mut cache, 5, &mut scratch).unwrap();
    let mut measured = 0u64;
    let mut steps = 0u64;
    for _ in 0..16 {
        let tok = rng.range(1, 127) as i32;
        if cache.rlen() + 1 >= r_limit {
            driver.step_with(&mut cache, tok, &mut scratch).unwrap();
            continue;
        }
        let before = common::alloc_count();
        driver.step_with(&mut cache, tok, &mut scratch).unwrap();
        let after = common::alloc_count();
        measured += after - before;
        steps += 1;
    }
    assert!(steps >= 8, "not enough non-flushing steps measured");
    assert_eq!(
        measured, 0,
        "paged-pool steady-state decode allocated {measured} times over {steps} steps"
    );
    drop(cache);
    assert_eq!(pool.leased(), 0, "no lease leak after retirement");
}

/// Zero-alloc over a SHARED prefix: a cache that adopted another request's
/// registered prompt (refcounted read-only pages from the prefix index)
/// must decode at exactly the same steady-state cost — streaming through an
/// `Rc`-held page is pointer-chasing, not allocating. This is the serving
/// shape of cross-request prefix sharing, held to the same release gate.
#[test]
fn steady_state_shared_prefix_decode_allocates_nothing() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 41);
    let method = MethodSpec::MixKvq { op: mixkvq::quant::methods::MixOp::Mix30 }.build();
    let layers = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = 32;
    let driver = RefDriver::new(
        mc.clone(),
        meta.cache.clone(),
        &weights,
        layers.clone(),
        method.clone(),
        r_limit,
    );
    let pool = mixkvq::kvcache::pool::KvPool::for_specs(
        layers.iter(),
        mc.d_head,
        meta.cache.group,
        Some(256),
    );
    pool.prewarm(256);
    let mut index = mixkvq::kvcache::radix::RadixTree::new(128, pool.page_deploy_bytes());
    let mut rng = Pcg32::seeded(43);
    let prompt: Vec<i32> = (0..72).map(|_| rng.range(1, 127) as i32).collect();
    let (mut producer, last) = driver.prefill_pooled(&pool, &prompt).unwrap();
    assert!(producer.register_prefix(&mut index, 0xabcd, &prompt, &last));
    let pinned = pool.leased();
    let mut cache = mixkvq::kvcache::cache::RequestCache::new_in(
        &pool,
        &mc,
        &meta.cache,
        &layers,
        method,
        r_limit,
    );
    let m = match index.lookup(0xabcd, &prompt, meta.cache.group, 0) {
        mixkvq::kvcache::radix::PrefixProbe::Full(m) => m,
        _ => panic!("expected full prefix hit"),
    };
    cache.install_prefix(&m).unwrap();
    drop(m);
    assert!(cache.shared_pages() > 0, "the window must be shared");
    assert_eq!(cache.private_pages(), 0);
    assert_eq!(pool.leased(), pinned, "the install must lease nothing");
    // fused decode over the shared window matches the oracle bit-for-bit
    // semantics-wise (same pages, different provenance)
    let tok = rng.range(1, 127) as i32;
    assert_eq!(
        driver.decode_logits_fused(&cache, tok),
        driver.decode_logits_fused(&producer, tok),
        "shared and producer caches must decode identically"
    );
    let mut scratch =
        DecodeScratch::new(&mc, meta.cache.capacity + meta.cache.residual + 1);
    driver.step_with(&mut cache, 5, &mut scratch).unwrap();
    let mut measured = 0u64;
    let mut steps = 0u64;
    for _ in 0..16 {
        let tok = rng.range(1, 127) as i32;
        if cache.rlen() + 1 >= r_limit {
            driver.step_with(&mut cache, tok, &mut scratch).unwrap();
            continue;
        }
        let before = common::alloc_count();
        driver.step_with(&mut cache, tok, &mut scratch).unwrap();
        let after = common::alloc_count();
        measured += after - before;
        steps += 1;
    }
    assert!(steps >= 8, "not enough non-flushing steps measured");
    assert_eq!(
        measured, 0,
        "shared-prefix steady-state decode allocated {measured} times over {steps} steps"
    );
    drop(cache);
    drop(producer);
    assert_eq!(pool.leased(), index.pages_pinned(), "only the index pin remains");
    index.clear();
    assert_eq!(pool.leased(), 0, "no lease leak after the index lets go");
}
