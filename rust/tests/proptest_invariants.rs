//! Property-test suite for the DESIGN.md §6 invariants, swept with seeded
//! randomness across module boundaries (the single-module properties live
//! next to their modules; these exercise the composition).

use std::collections::HashMap;
use std::time::Instant;

use mixkvq::coordinator::batcher::Batcher;
use mixkvq::coordinator::events::{by_request, validate_stream, Event, EventLog};
use mixkvq::coordinator::session::{FinishReason, Request, Session};
use mixkvq::kvcache::accountant;
use mixkvq::kvcache::cache::RequestCache;
use mixkvq::model::config::{CacheConfig, ModelConfig};
use mixkvq::model::sampler::Sampling;
use mixkvq::model::tokenizer::EOS;
use mixkvq::quant::methods::Method;
use mixkvq::quant::salience;
use mixkvq::quant::window::TierSpec;
use mixkvq::util::rng::Pcg32;

fn rand_kv(
    rng: &mut Pcg32,
    mc: &ModelConfig,
    t: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = mc.n_kv_heads * t * mc.d_head;
    let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let qa = (0..mc.n_layers)
        .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
        .collect();
    (k, v, qa)
}

/// Invariant #1+#2 through the full cache: store→dequant stays within the
/// per-element bound implied by the stored scales, for random tier specs.
#[test]
fn cache_roundtrip_error_bounded_over_random_specs() {
    let mut rng = Pcg32::seeded(1001);
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    for case in 0..20 {
        // random packable tier split of d_head = 32
        let n16 = [0usize, 2, 4][rng.below(3) as usize];
        let mut n4 = 2 * rng.below(8) as usize;
        if (n16 + n4) % 4 != 0 {
            n4 += 2;
        }
        let n2 = 32 - n16 - n4;
        let v_bits = [2usize, 4, 16][rng.below(3) as usize];
        let spec = TierSpec { n16, n4, n2, v_bits };
        let method = if case % 2 == 0 { Method::mixkvq("mix30") } else { Method::kivi("kv2") };
        let mut cache = RequestCache::new(&mc, &cc, &[spec], method, 32);
        let t = 96;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        let q = cache.qlen;
        assert!(q >= 64, "case {case}");
        let d = mc.d_head;
        let back = cache.heads[0][0].dequant_keys(q);
        for tok in 0..q {
            for ch in 0..d {
                let err = (back[tok * d + ch] - k[0][tok * d + ch]).abs();
                // worst case at 2-bit for a ~N(0,1) 32-sample group: s/2 ≈
                // range/6 ≈ 1.2; give slack for tail draws
                assert!(err < 2.5, "case {case}: err {err}");
            }
        }
        // invariant #5: residual tail is bit-exact
        let rl = cache.rlen();
        let res = cache.heads[0][0].res.keys();
        assert_eq!(res, &k[0][q * d..(q + rl) * d]);
    }
}

/// Invariant #3: effective-bits accounting is exact arithmetic over the grid.
#[test]
fn effective_bits_exact_over_grid() {
    for (n16, n4, n2) in mixkvq::harness::pareto::tier_grid(32) {
        let eb = salience::effective_key_bits(n16, n4, n2);
        let want = (16 * n16 + 4 * n4 + 2 * n2) as f64 / 32.0;
        assert_eq!(eb, want);
        for v_bits in [2usize, 4, 16] {
            let spec = TierSpec { n16, n4, n2, v_bits };
            let bpt = accountant::bytes_per_token(&spec, 32, 32);
            // reconstruct by components
            let key = 2.0 * n16 as f64 + n4 as f64 / 2.0 + n2 as f64 / 4.0
                + 4.0 * (n4 + n2) as f64 / 32.0;
            let val = if v_bits == 16 { 64.0 } else { 32.0 * v_bits as f64 / 8.0 + 4.0 };
            assert!((bpt - key - val).abs() < 1e-9);
        }
    }
}

/// Invariant #7: RequestCache::bytes_used equals the sum over heads of the
/// per-head accounting at every point of a request's life.
#[test]
fn accountant_matches_component_sum_during_decode() {
    let mut rng = Pcg32::seeded(1002);
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let mut cache = RequestCache::new(&mc, &cc, &[spec; 2], Method::mixkvq("mix225"), 32);
    let (k, v, qa) = rand_kv(&mut rng, &mc, 64);
    cache.load_prefill(&k, &v, &qa, 64).unwrap();
    for _ in 0..80 {
        let total: usize = cache
            .heads
            .iter()
            .flat_map(|r| r.iter())
            .map(|h| h.bytes_used(cache.qlen))
            .sum();
        assert_eq!(cache.bytes_used(), total);
        assert!(cache.bytes_used() < cache.bytes_fp16_equiv());
        let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
        cache.append(&kn, &vn, &qn).unwrap();
    }
}

/// Invariant #6: FIFO batcher never starves — with random finish patterns,
/// every enqueued request is eventually admitted in arrival order.
#[test]
fn batcher_fifo_no_starvation() {
    let mut rng = Pcg32::seeded(1003);
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    for _ in 0..30 {
        let slots = 1 + rng.below(4) as usize;
        let n = 5 + rng.below(20) as usize;
        let mut b = Batcher::new(slots);
        for id in 0..n as u64 {
            b.enqueue(Request {
                id,
                prompt: vec![1],
                max_new_tokens: 4,
                sampling: Sampling::Greedy,
                method: None,
                tenant: 0,
                deadline_ticks: None,
            });
        }
        let mut admitted = Vec::new();
        let mut guard = 0;
        while (b.has_work() || b.live() > 0) && guard < 10_000 {
            guard += 1;
            while let Some((slot, req)) = b.next_admission() {
                admitted.push(req.id);
                let cache = RequestCache::new(
                    &mc,
                    &cc,
                    &[TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }],
                    Method::bf16(),
                    32,
                );
                b.install(slot, Session::new(req, cache, 5, std::time::Instant::now()));
            }
            // randomly finish live sessions
            for s in b.slots.iter_mut().flatten() {
                if rng.f32() < 0.5 {
                    s.finish(FinishReason::Eos);
                }
            }
            b.reap();
            if admitted.len() == n && b.live() == 0 {
                break;
            }
        }
        let want: Vec<u64> = (0..n as u64).collect();
        assert_eq!(admitted, want, "admission must be FIFO and complete");
    }
}

/// Serving-API invariant: every per-request lifecycle stream is well-formed
/// — exactly one `Queued`, at most one `Admitted`, `FirstToken` before all
/// `Token`s, generated count within `max_new_tokens`, exactly one terminal
/// `Finished` — under randomized admission/finish/cancel schedules driven
/// through the same Batcher + EventLog discipline `Server::tick` uses
/// (the engine-backed path is checked by the integration tests).
#[test]
fn event_streams_well_formed_under_random_schedules() {
    let mut rng = Pcg32::seeded(1005);
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let mk_cache = || {
        RequestCache::new(
            &mc,
            &cc,
            &[TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }],
            Method::bf16(),
            32,
        )
    };
    for case in 0..25 {
        let slots = 1 + rng.below(4) as usize;
        let n = 3 + rng.below(12) as usize;
        let mut b = Batcher::new(slots);
        let mut log = EventLog::default();
        let mut max_new: HashMap<u64, usize> = HashMap::new();
        for id in 0..n as u64 {
            let mn = 1 + rng.below(6) as usize;
            max_new.insert(id, mn);
            log.queued(id);
            b.enqueue(Request {
                id,
                prompt: vec![1],
                max_new_tokens: mn,
                sampling: Sampling::Greedy,
                method: None,
                tenant: 0,
                deadline_ticks: None,
            });
        }
        let mut guard = 0;
        while b.has_work() && guard < 10_000 {
            guard += 1;
            // --- admissions (mirrors Server::admit) ----------------------
            while let Some((slot, req)) = b.next_admission() {
                let id = req.id;
                let mn = req.max_new_tokens;
                let first = if rng.f32() < 0.15 { EOS } else { 7 };
                let mut sess = Session::new(req, mk_cache(), first, Instant::now());
                log.admitted(id, "bf16");
                log.first_token(id, first);
                if first == EOS {
                    sess.finish(FinishReason::Eos);
                    log.finished(id, FinishReason::Eos, sess.generated.len());
                    continue;
                }
                if mn <= 1 {
                    sess.finish(FinishReason::MaxTokens);
                    log.finished(id, FinishReason::MaxTokens, sess.generated.len());
                    continue;
                }
                b.install(slot, sess);
            }
            // --- random cancellation (queued, then live) -----------------
            if rng.f32() < 0.15 {
                if let Some(id) = b.waiting.front().map(|r| r.id) {
                    b.remove_waiting(id).unwrap();
                    log.finished(id, FinishReason::Cancelled, 0);
                }
            }
            if rng.f32() < 0.1 {
                for s in b.slots.iter_mut() {
                    let live = s.as_ref().map(|x| !x.is_finished()).unwrap_or(false);
                    if live {
                        let mut sess = s.take().unwrap();
                        sess.finish(FinishReason::Cancelled);
                        log.finished(sess.request.id, FinishReason::Cancelled, sess.generated.len());
                        break;
                    }
                }
            }
            // --- one decode step: each live session samples a token ------
            for s in b.slots.iter_mut().flatten() {
                if s.is_finished() {
                    continue;
                }
                let tok = if rng.f32() < 0.3 { EOS } else { 9 };
                let id = s.request.id;
                s.push_token(tok);
                log.token(id, tok);
            }
            for sess in b.reap() {
                log.finished(sess.request.id, sess.finish_reason().unwrap(), sess.generated.len());
            }
        }
        assert!(guard < 10_000, "case {case}: schedule did not drain");
        let events = log.drain();
        let grouped = by_request(&events);
        assert_eq!(grouped.len(), n, "case {case}: every request has a stream");
        for (id, stream) in grouped {
            validate_stream(&stream, max_new[&id])
                .unwrap_or_else(|e| panic!("case {case} request {id}: {e}\n{stream:#?}"));
            assert!(
                matches!(stream.last(), Some(Event::Finished { .. })),
                "case {case} request {id}: no terminal event"
            );
        }
    }
}

/// Invariant #4 at the composition level: tier membership is monotone in
/// the salience score — the top-A_d channel is always in the first tier.
#[test]
fn top_salience_channel_lands_in_top_tier() {
    let mut rng = Pcg32::seeded(1004);
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    for _ in 0..10 {
        let mut cache = RequestCache::new(&mc, &cc, &[spec], Method::mixkvq("mix30"), 32);
        let t = 64;
        let (mut k, v, mut qa) = rand_kv(&mut rng, &mc, t);
        // make channel 9 both high-range and high-importance on head 0
        let d = mc.d_head;
        for tok in 0..t {
            k[0][tok * d + 9] *= 15.0;
        }
        qa[0][9] = 50.0;
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        let head = &cache.heads[0][0];
        assert!(head.idx[..spec.n16].contains(&9), "idx={:?}", &head.idx[..4]);
    }
}
