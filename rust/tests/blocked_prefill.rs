//! Chunked GEMM-blocked prefill vs the `forward_full` oracle:
//!
//! * equivalence property: ≤1e-4 last-logit agreement across the FULL
//!   `MethodSpec::all()` roster (every tier split, v_bits ∈ {2,4,16},
//!   grouped and global scales, rotation, clipping, layer-wise specs),
//!   including an unaligned prompt length — prefill attention runs over
//!   the layer's own f32 K/V, so the agreement holds for every
//!   quantization method, not just bf16;
//! * paged↔contiguous bit-identity after chunked admission: the SAME
//!   prompt chunk-prefilled into a private-pool cache and a shared
//!   prewarmed-pool cache must store bit-identical pages (and release
//!   every lease on retirement);
//! * steady-state zero-alloc: once the run's arena is warm, a mid-layer
//!   (layer, chunk) unit performs zero heap allocations (counting global
//!   allocator, same gate as the fused-decode suite);
//! * resumability: advancing one chunk at a time is bit-identical to one
//!   uninterrupted run — the serving tick's budgeted interleaving cannot
//!   change results.

use std::sync::Mutex;

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::kvcache::cache::RequestCache;
use mixkvq::kvcache::pool::KvPool;
use mixkvq::model::config::Meta;
use mixkvq::model::reference::{PrefillRun, RefModel};
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::rng::Pcg32;

mod common;

#[global_allocator]
static GLOBAL: common::CountingAlloc = common::CountingAlloc;

static SERIAL: Mutex<()> = Mutex::new(());

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

/// Chunked prefill must agree with the full-materialization oracle for
/// every constructible method, and the pooled/private chunked caches must
/// be bit-identical page for page.
#[test]
fn chunked_prefill_matches_oracle_across_full_method_roster() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 41);
    let specs = MethodSpec::all();
    assert_eq!(specs.len(), 17, "roster drifted — update this test");
    for spec in specs {
        let method = spec.build();
        let layers = meta.variant(&method.variant).unwrap().layers.clone();
        let driver =
            RefDriver::new(mc.clone(), meta.cache.clone(), &weights, layers.clone(), method, 32);
        let mut rng = Pcg32::seeded(4100 + spec.variant().len() as u64);
        // long enough to quantize (> r_limit), unaligned on purpose
        let t = 70;
        let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();
        let (cache, last) = driver.prefill(&prompt).unwrap();
        assert!(cache.qlen >= 32, "{spec:?}: window must quantize");
        // --- oracle agreement (continuous path: no quantization feeds
        // the prefill logits, so 1e-4 holds for 2-bit methods too) -------
        let (_, pre) = driver.model.forward_full(&prompt);
        let err = max_abs_diff(&last, &pre.last_logits);
        assert!(err <= 1e-4, "{spec:?}: chunked/oracle logits diverge by {err}");
        assert!(last.iter().all(|x| x.is_finite()), "{spec:?}: non-finite logits");
        // --- admission shape matches the legacy load_prefill path -------
        let (lcache, llast) = driver.prefill_legacy(&prompt).unwrap();
        assert_eq!(cache.qlen, lcache.qlen, "{spec:?}");
        assert_eq!(cache.rlen(), lcache.rlen(), "{spec:?}");
        assert_eq!(cache.pos, lcache.pos, "{spec:?}");
        assert_eq!(cache.leased_pages(), lcache.leased_pages(), "{spec:?}");
        assert!(max_abs_diff(&last, &llast) <= 1e-4, "{spec:?}");
        // --- paged↔contiguous bit-identity after chunked admission:
        // shared prewarmed pool vs private pool, same prompt ------------
        let pages = cache.leased_pages() + cache.pages_per_flush();
        let pool = KvPool::for_specs(layers.iter(), mc.d_head, meta.cache.group, Some(pages));
        pool.prewarm(pages);
        let (pcache, plast) = driver.prefill_pooled(&pool, &prompt).unwrap();
        assert_eq!(plast, last, "{spec:?}: pooled chunked prefill must be bit-identical");
        for (lrow, prow) in cache.heads.iter().zip(&pcache.heads) {
            for (a, b) in lrow.iter().zip(prow) {
                assert_eq!(a.idx, b.idx, "{spec:?}: channel plans differ");
                assert_eq!(a.contiguous(), b.contiguous(), "{spec:?}: pages differ");
                assert_eq!(a.res.keys(), b.res.keys(), "{spec:?}: residuals differ");
                assert_eq!(a.res.values(), b.res.values(), "{spec:?}");
            }
        }
        drop(pcache);
        assert_eq!(pool.leased(), 0, "{spec:?}: lease leak after retirement");
    }
}

/// Once the arena is warm, a mid-layer chunk unit allocates nothing; and
/// budgeted single-chunk stepping is bit-identical to an uninterrupted
/// run (the serving tick's interleaving is invisible to the result).
#[test]
fn steady_state_prefill_chunk_allocates_nothing_and_resumes_exactly() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let cc = meta.cache.clone();
    let weights = Weights::random(&mc, 43);
    let model = RefModel::new(mc.clone(), &weights);
    let layers = meta.variant("mix30").unwrap().layers.clone();
    let mut rng = Pcg32::seeded(47);
    let t = 192;
    let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();

    let mut cache =
        RequestCache::new(&mc, &cc, &layers, Method::mixkvq("mix30"), 32);
    let mut run = PrefillRun::new(&mc, t, cc.group);
    let per_layer = run.chunks_per_layer();
    assert!(per_layer >= 3, "need mid-layer chunks to measure");
    // warm up through all of layer 0 (embedding, arena first touches, the
    // quantization sink's first gather) …
    for _ in 0..per_layer {
        run.advance(&model, &prompt, &mut cache, 1).unwrap();
    }
    // … then a layer-1 chunk that closes no layer must allocate nothing
    let before = common::alloc_count();
    run.advance(&model, &prompt, &mut cache, 1).unwrap();
    let after = common::alloc_count();
    assert_eq!(
        after - before,
        0,
        "steady-state prefill chunk allocated {} times",
        after - before
    );
    while !run.advance(&model, &prompt, &mut cache, 1).unwrap() {}
    assert_eq!(run.chunks_done(), run.total_chunks(mc.n_layers));

    // resumability: the single-chunk-stepped cache and logits are
    // bit-identical to an uninterrupted run over the same prompt
    let mut cache_oneshot =
        RequestCache::new(&mc, &cc, &layers, Method::mixkvq("mix30"), 32);
    let mut oneshot = PrefillRun::new(&mc, t, cc.group);
    assert!(oneshot
        .advance(&model, &prompt, &mut cache_oneshot, usize::MAX)
        .unwrap());
    assert_eq!(run.last_logits(), oneshot.last_logits());
    assert_eq!(cache.qlen, cache_oneshot.qlen);
    assert_eq!(cache.rlen(), cache_oneshot.rlen());
    for (arow, brow) in cache.heads.iter().zip(&cache_oneshot.heads) {
        for (a, b) in arow.iter().zip(brow) {
            assert_eq!(a.contiguous(), b.contiguous());
            assert_eq!(a.dequant_keys(cache.qlen), b.dequant_keys(cache_oneshot.qlen));
        }
    }
}

/// The chunked cache must decode exactly like a cache admitted through the
/// legacy bulk path would: the fused decode over it stays finite and the
/// steady-state step count/positions line up.
#[test]
fn chunked_admission_feeds_fused_decode() {
    let _guard = SERIAL.lock().unwrap();
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 53);
    let layers = meta.variant("mix30").unwrap().layers.clone();
    let driver = RefDriver::new(
        mc.clone(),
        meta.cache.clone(),
        &weights,
        layers,
        Method::mixkvq("mix30"),
        32,
    );
    let mut rng = Pcg32::seeded(59);
    let prompt: Vec<i32> = (0..100).map(|_| rng.range(1, 127) as i32).collect();
    let (mut cache, _) = driver.prefill(&prompt).unwrap();
    assert!(cache.qlen >= 64);
    for step in 0..4 {
        let tok = rng.range(1, 127) as i32;
        let fused = driver.decode_logits_fused(&cache, tok);
        let oracle = driver.decode_logits_legacy(&cache, tok);
        let err = max_abs_diff(&fused, &oracle);
        assert!(err <= 1e-4, "step {step}: fused/oracle diverge by {err} on chunked cache");
        driver.step(&mut cache, tok).unwrap();
    }
    assert_eq!(cache.pos, prompt.len() + 4);
}
