//! Worker-pool bit-identity property suite — the tentpole gate for the
//! fixed-size worker pool (crate docs, "Threading model").
//!
//! The pool's contract is not "statistically close", it is *byte-identical*:
//! at every worker count the engine must produce the same logits bits, the
//! same event streams, and the same deterministic metrics, because every
//! reduction merges in fixed slot/group/head order (never completion order)
//! and every fault draw is keyed to (request, ordinal), never to a thread
//! schedule. These properties hold across the FULL `MethodSpec::all()`
//! roster — every tier split, v_bits choice, rotation, and clipping.
//!
//! Runs on the artifact-free reference engine, so this is tier-1.

use std::collections::HashMap;

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::events::{by_request, validate_stream, Event};
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::Request;
use mixkvq::harness::refdriver::RefDriver;
use mixkvq::harness::workloads;
use mixkvq::model::config::{Meta, ModelConfig};
use mixkvq::model::reference::DecodeScratch;
use mixkvq::model::sampler::Sampling;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::rng::Pcg32;
use mixkvq::util::workers::WorkerPool;

/// Two-layer build so a 17-spec × 2-width server sweep stays cheap.
fn small_meta() -> Meta {
    let mut meta = Meta::default_build();
    meta.model = ModelConfig { n_layers: 2, ..meta.model };
    for v in &mut meta.variants {
        v.layers.truncate(2);
        while v.layers.len() < 2 {
            let last = *v.layers.last().unwrap();
            v.layers.push(last);
        }
    }
    meta
}

fn small_engine() -> Engine {
    Engine::new_reference(small_meta(), 11, Method::bf16(), 32).unwrap()
}

/// Boundary (c): the per-kv-head fan-out (`decode_step_into_mt`) must
/// reproduce the single-threaded `decode_step_into` logits BIT for BIT —
/// same f32 words, not merely within tolerance — for every constructible
/// method, with the quantized window and the residual both populated.
#[test]
fn sharded_decode_logits_bit_identical_across_roster() {
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let weights = Weights::random(&mc, 17);
    let max_scores = meta.cache.capacity + meta.cache.residual + 1;
    let specs = MethodSpec::all();
    assert_eq!(specs.len(), 17, "roster drifted — update this test");
    for spec in specs {
        let method = spec.build();
        let layers = meta.variant(&method.variant).unwrap().layers.clone();
        let driver =
            RefDriver::new(mc.clone(), meta.cache.clone(), &weights, layers, method, 32);
        let mut pool = WorkerPool::new(4, &mc, max_scores);
        assert_eq!(pool.size(), 4);
        let mut seq = DecodeScratch::new(&mc, max_scores);
        let mut par = DecodeScratch::new(&mc, max_scores);
        let mut rng = Pcg32::seeded(4200 + spec.variant().len() as u64);
        // long enough that the quantized window is populated (> r_limit)
        let prompt: Vec<i32> = (0..72).map(|_| rng.range(1, 127) as i32).collect();
        let (mut cache, _) = driver.prefill(&prompt).unwrap();
        assert!(cache.qlen >= 64, "{spec:?}: window must quantize");
        assert!(cache.rlen() > 0, "{spec:?}: residual must be populated");
        for step in 0..4 {
            let tok = rng.range(1, 127) as i32;
            driver.model.decode_step_into(tok, &cache, &mut seq);
            driver.model.decode_step_into_mt(tok, &cache, &mut par, &mut pool);
            assert_eq!(seq.logits.len(), par.logits.len());
            for (i, (a, b)) in seq.logits.iter().zip(&par.logits).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{spec:?} step {step}: logit {i} drifted ({a} vs {b})"
                );
            }
            driver.step(&mut cache, tok).unwrap();
        }
    }
}

fn gen_request(rng: &mut Pcg32, id: u64, spec: MethodSpec) -> Request {
    let ctx = 24 + rng.below(24) as usize;
    Request {
        id,
        prompt: workloads::gen_passkey(rng, ctx).prompt,
        max_new_tokens: 2 + rng.below(4) as usize,
        sampling: Sampling::Greedy,
        method: Some(spec),
        tenant: rng.below(2),
        deadline_ticks: None,
    }
}

/// Deterministic serving outcome of one width: the full event stream plus
/// every wall-clock-free metric the tick loop advances.
#[allow(clippy::type_complexity)]
fn run_at(spec: MethodSpec, workers: usize) -> (Vec<Event>, Vec<(&'static str, u64)>) {
    let mut server = Server::new(
        small_engine(),
        ServerConfig { seed: 33, workers, ..ServerConfig::default() },
    );
    let mut rng = Pcg32::seeded(1234);
    let n = 6usize;
    let mut max_new = HashMap::new();
    for i in 0..n {
        let req = gen_request(&mut rng, i as u64, spec);
        max_new.insert(req.id, req.max_new_tokens);
        server.submit(req).unwrap();
    }
    let mut events = Vec::new();
    let mut guard = 0;
    while server.has_work() {
        server.tick().unwrap();
        server.check_invariants().unwrap();
        events.extend(server.drain_events());
        guard += 1;
        assert!(guard < 10_000, "{spec:?} workers={workers}: drain stalled");
    }
    events.extend(server.drain_events());
    let streams = by_request(&events);
    assert_eq!(streams.len(), n, "{spec:?} workers={workers}: missing streams");
    for (id, stream) in &streams {
        validate_stream(stream, max_new[id]).unwrap();
    }
    let m = &server.metrics;
    let t = &server.engine.timers;
    let fingerprint = vec![
        ("completed", m.completed.total() as u64),
        ("generated", m.total_generated() as u64),
        ("prompt", m.total_prompt() as u64),
        ("decode_steps", m.decode_steps),
        ("live_slot_steps", m.live_slot_steps),
        ("slot_steps", m.slot_steps),
        ("max_concurrent", m.max_concurrent as u64),
        ("rejected", m.rejected),
        ("decode_errors", m.decode_errors),
        ("pool_high_water", m.pool_high_water as u64),
        ("pool_parks", m.pool_parks),
        ("prefill_parks", m.prefill_parks),
        ("prefix_hits", m.prefix_hits),
        ("prefix_misses", m.prefix_misses),
        ("peak_mem", m.peak_mem_bytes as u64),
        ("quantize_events", t.quantize_events),
        ("prefill_chunks", t.prefill_chunks),
        ("prefill_tokens", t.prefill_tokens),
        ("engine_decode_steps", t.decode_steps),
    ];
    (events, fingerprint)
}

/// Boundaries (a) + (b) end to end: for every method in the roster, a
/// served workload at `workers = 1` and `workers = 4` must agree on the
/// byte-exact event stream (ids, tokens, reasons, order) and on every
/// deterministic metric the server books.
#[test]
fn server_outcomes_identical_at_any_worker_count_across_roster() {
    for spec in MethodSpec::all() {
        let (e1, m1) = run_at(spec, 1);
        let (e4, m4) = run_at(spec, 4);
        assert_eq!(e1, e4, "{spec:?}: event streams diverged between widths");
        for ((k, a), (_, b)) in m1.iter().zip(&m4) {
            assert_eq!(a, b, "{spec:?}: metric {k} diverged between widths");
        }
    }
}

/// Width must also not perturb the *scheduler RNG*: a lone request (no
/// batching at all) still routes through the parallel prefill path and the
/// per-head decode fan-out, and every width must reproduce the width-1
/// event stream exactly — including odd widths that split heads unevenly.
#[test]
fn single_request_is_width_invariant() {
    let run_w = |workers: usize| -> Vec<Event> {
        let mut server = Server::new(
            small_engine(),
            ServerConfig { seed: 5, workers, ..ServerConfig::default() },
        );
        let mut rng = Pcg32::seeded(9);
        server.submit(gen_request(&mut rng, 0, MethodSpec::Bf16)).unwrap();
        let mut events = Vec::new();
        let mut guard = 0;
        while server.has_work() {
            server.tick().unwrap();
            server.check_invariants().unwrap();
            events.extend(server.drain_events());
            guard += 1;
            assert!(guard < 10_000);
        }
        events.extend(server.drain_events());
        events
    };
    let base = run_w(1);
    for workers in [2usize, 4, 7] {
        assert_eq!(base, run_w(workers), "workers={workers} diverged on a lone request");
    }
}
