//! Paged KV-pool invariants (no artifacts needed):
//!
//! * **bit-identity property**: under randomized append/flush/evict
//!   interleavings, the paged cache reads back exactly what the pre-pool
//!   contiguous layout would hold — a per-head mirror maintained with the
//!   old flat-buffer semantics (append = extend, evict = row shift) must
//!   stay bitwise equal to `HeadState::contiguous()` at every step;
//! * **occupancy admission**: under the same `MemoryAccountant` byte
//!   budget, occupancy-based admission accepts ≥2× more concurrent short
//!   requests than worst-case reservation (the headline of the refactor);
//! * **pool exhaustion**: a due flush on an exhausted pool defers (tokens
//!   ride the residual, `flush_deferrals` counts the park) and resumes once
//!   pages free up; no lease leaks on error or retirement paths —
//!   `pool.leased() == 0` after every drain.

use mixkvq::coordinator::scheduler::{Scheduler, SchedulerPolicy};
use mixkvq::kvcache::accountant::MemoryAccountant;
use mixkvq::kvcache::cache::{ContiguousHead, HeadState, RequestCache};
use mixkvq::kvcache::eviction::CachePolicy;
use mixkvq::kvcache::pool::{KvPool, PageLayout};
use mixkvq::model::config::{CacheConfig, ModelConfig};
use mixkvq::quant::methods::Method;
use mixkvq::quant::window::TierSpec;
use mixkvq::util::rng::Pcg32;

fn rand_kv(
    rng: &mut Pcg32,
    mc: &ModelConfig,
    t: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let n = mc.n_kv_heads * t * mc.d_head;
    let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let qa = (0..mc.n_layers)
        .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
        .collect();
    (k, v, qa)
}

/// Remove `n` rows of width `w` starting at row `from` — the old contiguous
/// layout's eviction (shift_rows) semantics, kept here as the oracle.
fn drain_rows<T>(v: &mut Vec<T>, w: usize, from: usize, n: usize) {
    v.drain(from * w..(from + n) * w);
}

/// Apply a contiguous-semantics eviction of `n` tokens after `sink` to the
/// mirror (both group-aligned, as `evict_block` asserts).
fn mirror_evict(m: &mut ContiguousHead, head: &HeadState, sink: usize, n: usize) {
    let g = head.group;
    let (n16, n4, n2) = (head.spec.n16, head.spec.n4, head.spec.n2);
    let (d, gv, vb) = (head.d, head.vgroup(), head.spec.v_bits);
    drain_rows(&mut m.k16, n16, sink, n);
    drain_rows(&mut m.k4p, n4 / 2, sink, n);
    drain_rows(&mut m.k2p, n2 / 4, sink, n);
    drain_rows(&mut m.k4s, n4, sink / g, n / g);
    drain_rows(&mut m.k4z, n4, sink / g, n / g);
    drain_rows(&mut m.k2s, n2, sink / g, n / g);
    drain_rows(&mut m.k2z, n2, sink / g, n / g);
    if vb == 16 {
        drain_rows(&mut m.vfull, d, sink, n);
    } else {
        drain_rows(&mut m.vp, d * vb / 8, sink, n);
        drain_rows(&mut m.vs, d / gv, sink, n);
        drain_rows(&mut m.vz, d / gv, sink, n);
    }
}

/// Append whatever the cache quantized beyond the mirror's horizon (the
/// contiguous semantics of a flush: extend at the tail), then demand
/// bitwise equality over the WHOLE window — any corruption of previously
/// stored groups, mis-spliced page table, or wrong scale block shows here.
fn sync_and_check(m: &mut ContiguousHead, head: &HeadState, ctx: &str) {
    let snap = head.contiguous();
    macro_rules! sync {
        ($f:ident) => {{
            assert!(snap.$f.len() >= m.$f.len(), "{ctx}: {} shrank unexpectedly", stringify!($f));
            let at = m.$f.len();
            m.$f.extend_from_slice(&snap.$f[at..]);
        }};
    }
    sync!(k16);
    sync!(k4p);
    sync!(k4s);
    sync!(k4z);
    sync!(k2p);
    sync!(k2s);
    sync!(k2z);
    sync!(vp);
    sync!(vs);
    sync!(vz);
    sync!(vfull);
    assert_eq!(*m, snap, "{ctx}: paged storage diverged from the contiguous oracle");
}

#[test]
fn paged_bit_identical_to_contiguous_under_interleavings() {
    let cases = [
        (901u64, CachePolicy::Stop),
        (902, CachePolicy::SlidingWindow { sink: 32, evict: 32 }),
        (903, CachePolicy::SlidingWindow { sink: 0, evict: 64 }),
        (904, CachePolicy::SlidingWindow { sink: 64, evict: 32 }),
    ];
    for (seed, policy) in cases {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig { capacity: 256, residual: 64, ..CacheConfig::default_build() };
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let mut cache =
            RequestCache::new(&mc, &cc, &vec![spec; 2], Method::mixkvq("mix30"), 32);
        cache.policy = policy;
        let mut rng = Pcg32::seeded(seed);
        let t0 = 96; // prefill: 64 quantized + 32 residual
        let (k, v, qa) = rand_kv(&mut rng, &mc, t0);
        cache.load_prefill(&k, &v, &qa, t0).unwrap();
        assert_eq!(cache.qlen, 64, "{seed}");
        let mut mirrors: Vec<Vec<ContiguousHead>> = cache
            .heads
            .iter()
            .map(|row| row.iter().map(|h| h.contiguous()).collect())
            .collect();
        let mut evicted_seen = cache.evicted_tokens;
        let sink = match policy {
            CachePolicy::SlidingWindow { sink, .. } => sink,
            CachePolicy::Stop => 0,
        };
        for step in 0..400 {
            // occasionally force explicit eviction rounds on top of the
            // flush-triggered ones (rare enough that the window still fills
            // and the flush-path eviction fires too)
            if step % 181 == 180 {
                let n = cache.evict_for(policy, 64);
                if n > 0 {
                    for (l, row) in mirrors.iter_mut().enumerate() {
                        for (h, m) in row.iter_mut().enumerate() {
                            mirror_evict(m, &cache.heads[l][h], sink, n);
                        }
                    }
                }
            }
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            if cache.append(&kn, &vn, &qn).is_err() {
                assert!(matches!(policy, CachePolicy::Stop), "only Stop may exhaust");
                break;
            }
            let evicted_now = cache.evicted_tokens - evicted_seen;
            evicted_seen = cache.evicted_tokens;
            for (l, row) in mirrors.iter_mut().enumerate() {
                for (h, m) in row.iter_mut().enumerate() {
                    if evicted_now > 0 {
                        mirror_evict(m, &cache.heads[l][h], sink, evicted_now);
                    }
                    sync_and_check(m, &cache.heads[l][h], &format!("seed {seed} step {step} l{l}h{h}"));
                }
            }
        }
        // and the pool reclaims everything at retirement
        let pool = cache.pool().clone();
        drop(cache);
        assert_eq!(pool.leased(), 0, "seed {seed}: leaked leases");
    }
}

/// The headline integration property: under the SAME byte budget, admitting
/// on pool occupancy accepts ≥2× more concurrent short requests than the
/// old worst-case reservation (which charged every request full window
/// capacity C up front).
#[test]
fn occupancy_admission_doubles_short_request_concurrency() {
    let mc = ModelConfig::default_build(); // 4 layers x 2 kv-heads
    let cc = CacheConfig::default_build(); // C=512, G=32, residual 128
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; mc.n_layers];
    let r_limit = 32;
    let wc = MemoryAccountant::worst_case_request_bytes(&mc, &cc, &specs);
    let budget = 2 * wc;
    let worst_case_batch = budget / wc; // the old admission: exactly 2
    assert_eq!(worst_case_batch, 2);

    let layout = PageLayout::new(spec, mc.d_head, cc.group);
    let max_pages = budget / layout.deploy_bytes();
    let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(max_pages));
    pool.prewarm(max_pages);
    // reserve: four flushes of decode headroom
    let reserve = 4 * (r_limit / cc.group) * mc.n_layers * mc.n_kv_heads;
    let mut sched = Scheduler::with_pool(
        SchedulerPolicy {
            max_prefills_per_cycle: usize::MAX,
            per_request_bytes: wc,
            reserve_pages: reserve,
        },
        budget,
        pool.clone(),
    );

    // short requests: 96-token prompts → 64 quantized tokens → 2 pages per
    // (layer, head) = 16 pages, vs 128 pages worst case
    let mut rng = Pcg32::seeded(41);
    let t = 96;
    let pages_per_req =
        (RequestCache::prefill_split(t, r_limit, cc.group, cc.capacity).0 / cc.group)
            * mc.n_layers
            * mc.n_kv_heads;
    let mut admitted = Vec::new();
    while sched.try_admit_pages(pages_per_req) {
        let mut cache =
            RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), r_limit);
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        admitted.push(cache);
        sched.observe_occupancy(0);
    }
    assert!(
        admitted.len() >= 2 * worst_case_batch,
        "occupancy admission must at least double the worst-case batch: \
         got {} vs worst-case {}",
        admitted.len(),
        worst_case_batch
    );
    // the accountant observed real occupancy, bounded by the budget
    assert!(sched.accountant.peak_bytes > 0);
    assert!(sched.accountant.peak_bytes <= budget);
    drop(admitted);
    assert_eq!(pool.leased(), 0, "retired requests must return every page");
}

/// A due flush on an exhausted shared pool defers (the token rides the
/// residual, `flush_deferrals` counts the park) and the flush lands as soon
/// as another tenant frees pages — the cache-level half of park-then-resume.
#[test]
fn flush_defers_on_exhausted_pool_then_resumes() {
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    // room for A's prefill (4 pages) + B's prefill (2 pages), nothing more
    let pool = KvPool::for_specs([&spec], mc.d_head, cc.group, Some(6));
    pool.prewarm(6);
    let mut rng = Pcg32::seeded(43);

    let mut a = RequestCache::new_in(&pool, &mc, &cc, &[spec], Method::mixkvq("mix30"), 32);
    let (k, v, qa) = rand_kv(&mut rng, &mc, 96);
    a.load_prefill(&k, &v, &qa, 96).unwrap(); // 64 quantized = 4 pages
    assert_eq!(a.leased_pages(), 4);

    let mut b = RequestCache::new_in(&pool, &mc, &cc, &[spec], Method::kivi("kv2"), 32);
    let (k, v, qa) = rand_kv(&mut rng, &mc, 64);
    b.load_prefill(&k, &v, &qa, 64).unwrap(); // 32 quantized = 2 pages
    assert_eq!(pool.leased(), 6);
    assert!(!pool.can_lease(1));

    // A's residual sits at r_limit → a flush is due, but the pool is dry:
    // the append defers and the token rides in the residual
    assert_eq!(a.rlen(), 32);
    assert_eq!(a.due_flush_pages(), 2);
    let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
    a.append(&kn, &vn, &qn).unwrap();
    assert_eq!(a.qlen, 64, "flush must defer, not fail");
    assert_eq!(a.rlen(), 33);
    assert!(a.flush_deferrals >= 1);
    assert!(pool.stats().lease_failures >= 1);

    // tenant B retires → its pages free → the next append flushes
    drop(b);
    assert!(pool.can_lease(2));
    let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
    a.append(&kn, &vn, &qn).unwrap();
    assert_eq!(a.qlen, 96, "deferred flush must land once pages free up");
    assert_eq!(a.rlen(), 2);

    drop(a);
    assert_eq!(pool.leased(), 0);
}

/// Admission paths that fail must not leak leases: an unaffordable prefill
/// errors before leasing anything, and a half-used cache dropped on an
/// error path returns everything.
#[test]
fn no_lease_leak_on_error_paths() {
    let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 0, n4: 32, n2: 0, v_bits: 4 };
    let pool = KvPool::for_specs([&spec], mc.d_head, cc.group, Some(2));
    pool.prewarm(2);
    let mut rng = Pcg32::seeded(47);
    // needs 4 pages (64 quantized tokens x 2 heads / 32-token pages)
    let mut big = RequestCache::new_in(&pool, &mc, &cc, &[spec], Method::kivi("kv4"), 32);
    let (k, v, qa) = rand_kv(&mut rng, &mc, 96);
    let err = big.load_prefill(&k, &v, &qa, 96).unwrap_err();
    assert!(format!("{err}").contains("pool exhausted"), "{err}");
    assert_eq!(pool.leased(), 0, "failed prefill must lease nothing");
    assert_eq!(pool.stats().lease_failures, 1);
    drop(big);

    // a cache that did lease, dropped mid-flight (cancel path)
    let mut small = RequestCache::new_in(&pool, &mc, &cc, &[spec], Method::kivi("kv4"), 32);
    let (k, v, qa) = rand_kv(&mut rng, &mc, 64);
    small.load_prefill(&k, &v, &qa, 64).unwrap();
    assert_eq!(pool.leased(), 2);
    drop(small);
    assert_eq!(pool.leased(), 0);
}

/// Scores/values streamed from a shared prewarmed pool are bit-identical to
/// the private-pool cache fed the same data — page provenance must not
/// change a single bit of the decode-visible state.
#[test]
fn shared_pool_cache_matches_private_pool_cache() {
    let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
    let cc = CacheConfig::default_build();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let specs = vec![spec; 2];
    let shared = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
    shared.prewarm(64);
    let mut rng = Pcg32::seeded(53);
    let t = 160;
    let (k, v, qa) = rand_kv(&mut rng, &mc, t);
    let mut private =
        RequestCache::new(&mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    let mut pooled =
        RequestCache::new_in(&shared, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
    private.load_prefill(&k, &v, &qa, t).unwrap();
    pooled.load_prefill(&k, &v, &qa, t).unwrap();
    assert_eq!(private.qlen, pooled.qlen);
    for l in 0..mc.n_layers {
        for h in 0..mc.n_kv_heads {
            assert_eq!(
                private.heads[l][h].contiguous(),
                pooled.heads[l][h].contiguous(),
                "l{l}h{h}"
            );
        }
    }
}
