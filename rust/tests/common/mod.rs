//! Shared test utilities for the integration-test binaries.
//!
//! `CountingAlloc` is the steady-state zero-alloc gate: a test binary
//! installs it as its `#[global_allocator]` and asserts that hot-path
//! decode steps do not move the counter (tests/fused_decode.rs gates both
//! the private-pool and the shared-prewarmed-pool decode paths; it runs on
//! CI, so an allocation regression fails the job).

#![allow(dead_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts every allocation (and growth realloc) routed through the global
/// allocator.
pub struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Current allocation count (monotonic; diff across a region under test).
pub fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::SeqCst)
}
