//! Tier-1 tests for the adaptive precision policy engine and the
//! production traffic harness (artifact-free: everything runs on the
//! reference engine / `RefDriver`).
//!
//! * property tests: `MemorySlo` never quotes a spec over its byte budget,
//!   every resolved spec is a member of `MethodSpec::all()`, and
//!   degradation is monotone (a tighter budget never resolves to a more
//!   expensive spec);
//! * E2E policy test: under a byte budget the most expensive spec cannot
//!   fit, a pinned-most-expensive run serves nothing while a `MemorySlo`
//!   policy run serves every session by degrading admissions;
//! * profiling bound: the full-spec measured error on the calibration
//!   corpus stays within the profile's predicted bound;
//! * scale: the traffic harness sustains >= 1000 concurrent sessions
//!   through the real `submit`/`tick`/`poll` loop with per-tenant SLO
//!   stats in the report.

use mixkvq::coordinator::engine::Engine;
use mixkvq::harness::profiling::{self, ProfileConfig};
use mixkvq::harness::traffic::{self, Arrival, TrafficConfig};
use mixkvq::model::config::{Meta, ModelConfig};
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::{KiviBits, Method, MethodSpec};
use mixkvq::quant::policy::{PrecisionPolicy, SpecCosts};
use mixkvq::util::rng::Pcg32;

/// 2-layer build-default model: fast enough for debug-mode serving tests,
/// deep enough that per-layer profiling means something.
fn small_meta() -> Meta {
    let mut meta = Meta::default_build();
    meta.model = ModelConfig { n_layers: 2, ..meta.model };
    for v in &mut meta.variants {
        v.layers.truncate(2);
        while v.layers.len() < 2 {
            let last = *v.layers.last().unwrap();
            v.layers.push(last);
        }
    }
    meta
}

fn reference_engine() -> Engine {
    Engine::new_reference(small_meta(), 11, Method::bf16(), 32).unwrap()
}

// ---------------------------------------------------------------- policy --

#[test]
fn memory_slo_never_exceeds_budget_and_stays_in_roster() {
    let costs = SpecCosts::from_meta(&Meta::default_build());
    let all = MethodSpec::all();
    let max_cost = costs.iter().map(|(_, c)| c).max().unwrap();
    let mut rng = Pcg32::seeded(2024);
    for _ in 0..200 {
        let budget = rng.below(2 * max_cost as u32 + 1) as usize;
        let policy = PrecisionPolicy::MemorySlo { budget_bytes: budget };
        for spec in policy.candidates(&costs) {
            let cost = costs.cost(spec).expect("candidate must have a cost");
            assert!(
                cost <= budget,
                "{spec} costs {cost} B over the {budget} B SLO"
            );
            assert!(all.contains(&spec), "{spec} not in MethodSpec::all()");
        }
        if let Some(spec) = policy.resolve(&costs) {
            assert!(costs.cost(spec).unwrap() <= budget);
        } else {
            // nothing fits only when the budget undercuts the cheapest spec
            let min_cost = costs.iter().map(|(_, c)| c).min().unwrap();
            assert!(budget < min_cost, "resolve returned None at {budget} B");
        }
    }
}

#[test]
fn degradation_is_monotone_in_the_budget() {
    let costs = SpecCosts::from_meta(&Meta::default_build());
    let max_cost = costs.iter().map(|(_, c)| c).max().unwrap();
    let mut prev_cost: Option<usize> = None;
    // sweep the budget upward: the resolved spec's cost may only rise
    for budget in (0..=max_cost + 1024).step_by(512) {
        let policy = PrecisionPolicy::MemorySlo { budget_bytes: budget };
        let cost = policy.resolve(&costs).map(|s| costs.cost(s).unwrap());
        if let (Some(p), Some(c)) = (prev_cost, cost) {
            assert!(
                c >= p,
                "budget {budget} resolved cheaper ({c} B) than a tighter budget did ({p} B)"
            );
        }
        if cost.is_some() {
            prev_cost = cost;
        }
    }
    // and the roster's extremes resolve as expected
    let open = PrecisionPolicy::MemorySlo { budget_bytes: usize::MAX };
    assert_eq!(open.resolve(&costs), costs.most_expensive());
}

#[test]
fn fixed_policy_resolves_to_its_pin() {
    let costs = SpecCosts::from_meta(&Meta::default_build());
    for spec in MethodSpec::all() {
        let policy = PrecisionPolicy::Fixed(spec);
        assert_eq!(policy.resolve(&costs), Some(spec));
        assert_eq!(policy.candidates(&costs), vec![spec]);
    }
}

// --------------------------------------------------------- E2E: serving --

/// Under a byte budget the most expensive spec (bf16) cannot fit, pinning
/// every request to bf16 serves nothing — while a `MemorySlo` policy run
/// degrades admissions to cheaper rungs and serves every session.
#[test]
fn tight_budget_policy_outserves_pinned_most_expensive() {
    let meta = small_meta();
    let costs = SpecCosts::from_meta(&meta);
    let most = costs.most_expensive().unwrap();
    assert_eq!(most, MethodSpec::Bf16);
    let bf16_cost = costs.cost(most).unwrap();
    let min_cost = costs.iter().map(|(_, c)| c).min().unwrap();
    assert!(min_cost < bf16_cost, "need a cost spread for this test");
    // a budget the cheapest rungs clear but bf16 does not
    let budget = bf16_cost - 1;

    let base = TrafficConfig {
        sessions: 12,
        tenants: 2,
        arrival: Arrival::PoissonBurst {
            rate: 4.0,
            burst_every: 8,
            burst_len: 2,
            burst_rate: 8.0,
        },
        max_new: 3,
        prompt_pool: 3,
        prompt_lo: 24,
        prompt_hi: 40,
        memory_budget_bytes: budget,
        ..TrafficConfig::default()
    };

    // pinned most-expensive: every request rejected at submit
    let pinned_cfg = TrafficConfig { method_mix: vec![most], ..base.clone() };
    let pinned = traffic::run(reference_engine(), &pinned_cfg).unwrap();
    let pinned_served = pinned.completed as u64 - pinned.rejected;
    assert_eq!(pinned_served, 0, "bf16 must not fit under {budget} B");

    // MemorySlo policy: unpinned requests degrade to affordable rungs
    let slo_cfg = TrafficConfig {
        policy: Some(PrecisionPolicy::MemorySlo { budget_bytes: budget }),
        ..base
    };
    let served_run = traffic::run(reference_engine(), &slo_cfg).unwrap();
    let served = served_run.completed as u64 - served_run.rejected;
    assert_eq!(served_run.completed, slo_cfg.sessions, "all sessions terminal");
    assert!(
        served > pinned_served,
        "policy run served {served}, pinned served {pinned_served}"
    );
    assert_eq!(served as usize, slo_cfg.sessions, "policy run must serve all");
}

// ------------------------------------------------------------ profiling --

/// The profile's predicted bound (summed per-layer sensitivities plus
/// compounding slack) must cover the measured full-spec error on the same
/// calibration corpus — the guarantee `LayerSensitivity` quotes from.
#[test]
fn measured_error_stays_within_the_predicted_bound() {
    let meta = small_meta();
    let w = Weights::random(&meta.model, 11);
    let cfg = ProfileConfig { seqs: 2, seq_len: 64, ..ProfileConfig::default() };
    let specs = [
        MethodSpec::Kivi { bits: KiviBits::Kv4 },
        MethodSpec::Kivi { bits: KiviBits::Kv2 },
    ];
    let prof = profiling::profile(&meta, &w, &specs, &cfg).unwrap();
    for &spec in &specs {
        let measured = profiling::measured_error(&meta, &w, spec, &prof, &cfg).unwrap();
        let bound = prof.predicted_bound(spec).unwrap();
        assert!(
            measured <= bound,
            "{spec}: measured {measured:.4} exceeds predicted bound {bound:.4}"
        );
    }
    // and a sensitivity policy built from the profile is usable end to end
    let costs = SpecCosts::from_meta(&meta);
    let policy = PrecisionPolicy::LayerSensitivity { profile: prof };
    let resolved = policy.resolve(&costs).expect("profile yields a ladder");
    assert!(MethodSpec::all().contains(&resolved));
}

// ---------------------------------------------------------------- scale --

/// The harness sustains >= 1000 concurrent sessions through the real
/// server: a hot burst submits every session within a few ticks while the
/// decode batch drains slowly, so in-flight peaks near the full count.
#[test]
fn traffic_sustains_a_thousand_concurrent_sessions() {
    let cfg = TrafficConfig {
        sessions: 1100,
        tenants: 5,
        arrival: Arrival::PoissonBurst {
            rate: 300.0,
            burst_every: 1,
            burst_len: 0,
            burst_rate: 0.0,
        },
        max_new: 3,
        prompt_pool: 3,
        prompt_lo: 24,
        prompt_hi: 40,
        ..TrafficConfig::default()
    };
    let r = traffic::run(reference_engine(), &cfg).unwrap();
    assert_eq!(r.completed, cfg.sessions, "every session must reach terminal");
    assert_eq!(r.rejected, 0, "no rejections under the default budget");
    assert!(
        r.max_in_flight >= 1000,
        "peak concurrency {} < 1000",
        r.max_in_flight
    );
    assert!(!r.tenants.is_empty());
    let served: u64 = r.tenants.iter().map(|t| t.served).sum();
    assert_eq!(served as usize, cfg.sessions);
    for t in &r.tenants {
        assert!(t.p99_ttft_ms >= t.p50_ttft_ms);
        assert!(t.p99_latency_ms >= t.p50_latency_ms);
    }
}
