//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The build environment resolves dependencies offline (no crates.io
//! access), so the crate ships the slice of anyhow it actually uses:
//! `Result`, `Error` with a context chain, the `Context` extension trait
//! for `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros.
//! `{e}` prints the outermost message, `{e:#}` the full colon-joined chain,
//! `{e:?}` the anyhow-style "Caused by" report.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-param shape as anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error carrying a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`, preserving its source chain. (Error itself
// deliberately does not implement std::error::Error, exactly like anyhow,
// so this blanket impl stays coherent with `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted `Error`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an `Error` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 7))
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3).context("ok").unwrap(), 3);
    }

    #[test]
    fn std_error_converts_with_source_chain() {
        let parse: std::result::Result<i32, _> = "x".parse::<i32>();
        let e: Error = parse.unwrap_err().into();
        assert!(format!("{e}").contains("invalid digit"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "must be positive, got {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(0).is_err());
        assert!(format!("{:#}", f(99).unwrap_err()).contains("too big"));
    }
}
