//! `bench-gate` — the CI regression gate over the artifact-free bench
//! reports. The ROADMAP's perf bars stop being aspirational here: CI runs
//! the benches, then this binary parses their JSON artifacts and **fails
//! the job** when any bar regresses:
//!
//! * `BENCH_ref_decode.json` — fused packed-code decode must stay ≥3× the
//!   legacy dequantize-then-attend path at qlen ≥ 256;
//! * `BENCH_paged_decode.json` — shared-pool paged decode overhead over the
//!   private-pool path must stay ≤ ~5% (pages change provenance, not
//!   access cost);
//! * `BENCH_prefill.json` — chunked GEMM-blocked prefill must stay ≥3× the
//!   legacy `forward_full` path at T ≥ 256, with a ≥2× smaller f32 working
//!   set;
//! * `BENCH_prefix_sharing.json` — K requests over one prompt must hold
//!   ≥2× fewer prefix pages than private mode and actually skip prefill
//!   chunks (dedup that stops deduping is a regression too).
//!
//! A missing or unparseable artifact is itself a violation: the gate exists
//! so a bench that silently stops running (or changes schema) cannot merge.
//! Run locally after `cargo bench --bench ref_decode --bench prefill
//! --bench prefix_sharing` from the artifact directory:
//!
//! ```text
//! cargo run --release --bin bench-gate [dir]
//! ```
//!
//! The thresholds are unit-tested below against synthetically degraded
//! reports, so the parser/threshold logic itself cannot rot unnoticed.

use std::path::Path;
use std::process::ExitCode;

use mixkvq::util::json::Json;

use anyhow::Result;

/// Fused decode must stay at least this many × over legacy (qlen ≥ 256).
pub const DECODE_SPEEDUP_MIN: f64 = 3.0;
/// Chunked prefill must stay at least this many × over legacy (T ≥ 256).
pub const PREFILL_SPEEDUP_MIN: f64 = 3.0;
/// Chunked prefill's f32 working set must stay at least this many × smaller.
pub const PREFILL_MEM_RATIO_MIN: f64 = 2.0;
/// Shared-pool decode may cost at most this % over the private pool.
pub const PAGED_OVERHEAD_MAX_PCT: f64 = 5.0;
/// K sharers must hold at least this many × fewer prefix pages than
/// K private copies would.
pub const PREFIX_DEDUP_MIN: f64 = 2.0;

/// Context length/prompt length at and above which the decode/prefill
/// speedup bars apply (short contexts are fixed-overhead dominated).
const LONG_CONTEXT: f64 = 256.0;

fn gate_ref_decode(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("ref_decode: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let qlen = e.get("qlen")?.as_f64()?;
        let speedup = e.get("speedup")?.as_f64()?;
        if qlen >= LONG_CONTEXT && speedup < DECODE_SPEEDUP_MIN {
            v.push(format!(
                "ref_decode: fused decode speedup {speedup:.2}x < \
                 {DECODE_SPEEDUP_MIN}x at qlen={qlen}"
            ));
        }
    }
    Ok(v)
}

fn gate_paged_decode(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("paged_decode: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let qlen = e.get("qlen")?.as_f64()?;
        let overhead = e.get("paged_overhead_pct")?.as_f64()?;
        if overhead > PAGED_OVERHEAD_MAX_PCT {
            v.push(format!(
                "paged_decode: shared-pool overhead {overhead:.2}% > \
                 {PAGED_OVERHEAD_MAX_PCT}% at qlen={qlen}"
            ));
        }
    }
    Ok(v)
}

fn gate_prefill(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("prefill: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let t = e.get("t")?.as_f64()?;
        let speedup = e.get("speedup")?.as_f64()?;
        let mem = e.get("peak_ratio")?.as_f64()?;
        if t >= LONG_CONTEXT && speedup < PREFILL_SPEEDUP_MIN {
            v.push(format!(
                "prefill: chunked speedup {speedup:.2}x < {PREFILL_SPEEDUP_MIN}x at T={t}"
            ));
        }
        if mem < PREFILL_MEM_RATIO_MIN {
            v.push(format!(
                "prefill: f32 working-set shrink {mem:.2}x < {PREFILL_MEM_RATIO_MIN}x at T={t}"
            ));
        }
    }
    Ok(v)
}

fn gate_prefix_sharing(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("prefix_sharing: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let t = e.get("t")?.as_f64()?;
        let dedup = e.get("dedup_ratio")?.as_f64()?;
        let skipped = e.get("chunks_skipped")?.as_f64()?;
        let deduped = e.get("bytes_deduped")?.as_f64()?;
        if dedup < PREFIX_DEDUP_MIN {
            v.push(format!(
                "prefix_sharing: page dedup {dedup:.2}x < {PREFIX_DEDUP_MIN}x at T={t}"
            ));
        }
        if skipped <= 0.0 {
            v.push(format!("prefix_sharing: no prefill chunks skipped at T={t}"));
        }
        if deduped <= 0.0 {
            v.push(format!("prefix_sharing: no bytes deduped at T={t}"));
        }
    }
    Ok(v)
}

type Gate = fn(&Json) -> Result<Vec<String>>;

const GATES: [(&str, Gate); 4] = [
    ("BENCH_ref_decode.json", gate_ref_decode),
    ("BENCH_paged_decode.json", gate_paged_decode),
    ("BENCH_prefill.json", gate_prefill),
    ("BENCH_prefix_sharing.json", gate_prefix_sharing),
];

/// Run every gate over `dir`, returning the full violation list (empty =
/// every bar holds).
fn run_gates(dir: &Path) -> Vec<String> {
    let mut violations = Vec::new();
    for (file, gate) in GATES {
        let path = dir.join(file);
        match std::fs::read_to_string(&path) {
            Err(e) => violations.push(format!(
                "{file}: missing ({e}) — did its bench run before the gate?"
            )),
            Ok(src) => match Json::parse(&src).and_then(|j| gate(&j)) {
                Ok(v) => violations.extend(v),
                Err(e) => violations.push(format!("{file}: bad report schema: {e}")),
            },
        }
    }
    violations
}

fn main() -> ExitCode {
    let dir = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let violations = run_gates(Path::new(&dir));
    if violations.is_empty() {
        println!(
            "bench-gate: all ROADMAP perf bars hold \
             (decode >= {DECODE_SPEEDUP_MIN}x, prefill >= {PREFILL_SPEEDUP_MIN}x, \
             f32 shrink >= {PREFILL_MEM_RATIO_MIN}x, paged overhead <= \
             {PAGED_OVERHEAD_MAX_PCT}%, prefix dedup >= {PREFIX_DEDUP_MIN}x)"
        );
        return ExitCode::SUCCESS;
    }
    eprintln!("bench-gate: {} violation(s):", violations.len());
    for v in &violations {
        eprintln!("  FAIL {v}");
    }
    ExitCode::FAILURE
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> Json {
        Json::parse(src).unwrap()
    }

    fn decode_report(speedup_256: f64, speedup_512: f64) -> String {
        format!(
            r#"{{"bench":"ref_decode","entries":[
                {{"qlen":256,"fused_ms":1.0,"legacy_ms":{},"speedup":{speedup_256}}},
                {{"qlen":512,"fused_ms":1.0,"legacy_ms":{},"speedup":{speedup_512}}}]}}"#,
            speedup_256, speedup_512
        )
    }

    #[test]
    fn healthy_decode_report_passes() {
        let v = gate_ref_decode(&parse(&decode_report(3.4, 4.1))).unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn degraded_decode_speedup_fails() {
        let v = gate_ref_decode(&parse(&decode_report(2.9, 4.1))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("qlen=256"), "{v:?}");
        // both entries degraded → both reported
        let v = gate_ref_decode(&parse(&decode_report(1.0, 2.0))).unwrap();
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn short_context_is_exempt_from_the_decode_bar() {
        let src = r#"{"entries":[{"qlen":64,"speedup":1.1}]}"#;
        assert!(gate_ref_decode(&parse(src)).unwrap().is_empty());
    }

    #[test]
    fn paged_overhead_gate() {
        let ok = r#"{"entries":[{"qlen":256,"paged_overhead_pct":1.2},
                                {"qlen":512,"paged_overhead_pct":-0.5}]}"#;
        assert!(gate_paged_decode(&parse(ok)).unwrap().is_empty());
        let bad = r#"{"entries":[{"qlen":256,"paged_overhead_pct":7.5}]}"#;
        let v = gate_paged_decode(&parse(bad)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("7.50%"), "{v:?}");
    }

    #[test]
    fn prefill_gate_checks_speed_and_memory_independently() {
        let ok = r#"{"entries":[{"t":256,"speedup":3.5,"peak_ratio":2.6},
                                {"t":512,"speedup":4.0,"peak_ratio":3.0}]}"#;
        assert!(gate_prefill(&parse(ok)).unwrap().is_empty());
        let slow = r#"{"entries":[{"t":256,"speedup":2.0,"peak_ratio":2.6}]}"#;
        assert_eq!(gate_prefill(&parse(slow)).unwrap().len(), 1);
        let fat = r#"{"entries":[{"t":256,"speedup":3.5,"peak_ratio":1.5}]}"#;
        let v = gate_prefill(&parse(fat)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("working-set"), "{v:?}");
        // the memory bar applies at every T, the speed bar only at T >= 256
        let short = r#"{"entries":[{"t":64,"speedup":1.0,"peak_ratio":1.0}]}"#;
        assert_eq!(gate_prefill(&parse(short)).unwrap().len(), 1);
    }

    #[test]
    fn prefix_sharing_gate() {
        let ok = r#"{"entries":[{"t":256,"dedup_ratio":3.8,"chunks_skipped":96,
                                 "bytes_deduped":1000000}]}"#;
        assert!(gate_prefix_sharing(&parse(ok)).unwrap().is_empty());
        let bad = r#"{"entries":[{"t":256,"dedup_ratio":1.1,"chunks_skipped":0,
                                  "bytes_deduped":0}]}"#;
        let v = gate_prefix_sharing(&parse(bad)).unwrap();
        assert_eq!(v.len(), 3, "{v:?}");
    }

    #[test]
    fn empty_entries_are_a_violation() {
        // a bench that regresses to writing no data must not pass green
        let empty = r#"{"bench":"x","entries":[]}"#;
        for gate in [
            gate_ref_decode as Gate,
            gate_paged_decode,
            gate_prefill,
            gate_prefix_sharing,
        ] {
            let v = gate(&parse(empty)).unwrap();
            assert_eq!(v.len(), 1);
            assert!(v[0].contains("NO entries"), "{v:?}");
        }
    }

    #[test]
    fn schema_drift_and_missing_files_are_violations() {
        // a renamed field must fail loudly, not silently pass
        let drifted = r#"{"entries":[{"qlen":256,"speed_up":3.5}]}"#;
        assert!(gate_ref_decode(&parse(drifted)).is_err());
        // an empty directory reports one violation per expected artifact
        let dir = std::env::temp_dir().join("mixkvq_bench_gate_empty_test");
        let _ = std::fs::create_dir_all(&dir);
        let v = run_gates(&dir);
        assert_eq!(v.len(), GATES.len());
        assert!(v.iter().all(|x| x.contains("missing")), "{v:?}");
    }

    #[test]
    fn end_to_end_pass_and_fail_over_a_real_directory() {
        let dir = std::env::temp_dir().join("mixkvq_bench_gate_e2e_test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("BENCH_ref_decode.json"), decode_report(3.2, 3.9)).unwrap();
        std::fs::write(
            dir.join("BENCH_paged_decode.json"),
            r#"{"entries":[{"qlen":256,"paged_overhead_pct":0.8}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_prefill.json"),
            r#"{"entries":[{"t":256,"speedup":3.3,"peak_ratio":2.4}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_prefix_sharing.json"),
            r#"{"entries":[{"t":256,"dedup_ratio":3.5,"chunks_skipped":96,
                            "bytes_deduped":500000}]}"#,
        )
        .unwrap();
        assert!(run_gates(&dir).is_empty());
        // degrade ONE artifact → exactly its violations surface
        std::fs::write(dir.join("BENCH_ref_decode.json"), decode_report(2.0, 3.9)).unwrap();
        let v = run_gates(&dir);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("ref_decode"), "{v:?}");
    }
}
