//! `bench-gate` — the CI regression gate over the artifact-free bench
//! reports. The ROADMAP's perf bars stop being aspirational here: CI runs
//! the benches, then this binary parses their JSON artifacts and **fails
//! the job** when any bar regresses:
//!
//! * `BENCH_ref_decode.json` — fused packed-code decode must stay ≥3× the
//!   legacy dequantize-then-attend path at qlen ≥ 256;
//! * `BENCH_paged_decode.json` — shared-pool paged decode overhead over the
//!   private-pool path must stay ≤ ~5% (pages change provenance, not
//!   access cost);
//! * `BENCH_prefill.json` — chunked GEMM-blocked prefill must stay ≥3× the
//!   legacy `forward_full` path at T ≥ 256, with a ≥2× smaller f32 working
//!   set;
//! * `BENCH_prefix_sharing.json` — K requests over one prompt must hold
//!   ≥2× fewer prefix pages than private mode and actually skip prefill
//!   chunks (dedup that stops deduping is a regression too);
//! * `BENCH_prefix_radix.json` — the shared-system-prompt radix workload:
//!   K consumers with divergent suffixes must take frozen-plan partial
//!   hits (≥2× page dedup over private mode, chunks actually skipped),
//!   the same-seed rerun must show **zero fingerprint drift** with the
//!   tree enabled, and every method whose frozen-plan default is ON must
//!   measure inside the frozen-plan error budget;
//! * `BENCH_traffic.json` — the seeded traffic smoke (`mixkvq traffic`)
//!   must finish every session, hold the p99 TTFT bar, carry per-tenant
//!   SLO stats, and show **zero same-seed drift** (the harness runs the
//!   seed twice; diverging fingerprints mean serving nondeterminism);
//! * `BENCH_chaos.json` — the seeded chaos soak (`mixkvq traffic --chaos`)
//!   must have actually injected faults, recovered every session to a
//!   terminal state, passed the cross-subsystem invariant audit on every
//!   tick, leaked zero pool pages at drain, and repeated the identical
//!   failure story on the same-seed rerun;
//! * `BENCH_parallel.json` — the worker pool must hold ≥2× tick throughput
//!   at 4 workers over the single-threaded run, with ZERO fingerprint
//!   drift between the widths (parallelism is a perf optimisation, never a
//!   semantics change);
//! * `BENCH_restore.json` — the kill-and-restore smoke (`mixkvq traffic
//!   --kill-at-tick`) must show **zero drift** between every
//!   killed-and-restored run and its uninterrupted same-seed twin, a
//!   non-empty snapshot, and a restore cost of at most ~2 ticks of
//!   service (crash recovery that loses state or stalls serving is a
//!   regression, not a feature).
//!
//! A missing or unparseable artifact is itself a violation: the gate exists
//! so a bench that silently stops running (or changes schema) cannot merge.
//! Run locally after `cargo bench --bench ref_decode --bench prefill
//! --bench prefix_sharing` from the artifact directory:
//!
//! ```text
//! cargo run --release --bin bench-gate [dir]
//! ```
//!
//! The thresholds are unit-tested below against synthetically degraded
//! reports, so the parser/threshold logic itself cannot rot unnoticed.

use std::path::Path;
use std::process::ExitCode;

use mixkvq::harness::profiling::FROZEN_PLAN_NLL_BUDGET;
use mixkvq::util::json::Json;

use anyhow::Result;

/// Fused decode must stay at least this many × over legacy (qlen ≥ 256).
pub const DECODE_SPEEDUP_MIN: f64 = 3.0;
/// Chunked prefill must stay at least this many × over legacy (T ≥ 256).
pub const PREFILL_SPEEDUP_MIN: f64 = 3.0;
/// Chunked prefill's f32 working set must stay at least this many × smaller.
pub const PREFILL_MEM_RATIO_MIN: f64 = 2.0;
/// Shared-pool decode may cost at most this % over the private pool.
pub const PAGED_OVERHEAD_MAX_PCT: f64 = 5.0;
/// K sharers must hold at least this many × fewer prefix pages than
/// K private copies would.
pub const PREFIX_DEDUP_MIN: f64 = 2.0;
/// Traffic smoke (200 sessions, reference engine): p99 TTFT may not
/// exceed this many ms. Generous on purpose — the bar catches scheduler
/// pathologies (admission livelock, queue starvation), not machine noise.
pub const TRAFFIC_P99_TTFT_MAX_MS: f64 = 5000.0;
/// The worker pool must hold at least this many × tick throughput at
/// 4 workers over the single-threaded run of the same seeded workload.
pub const PARALLEL_SCALING_MIN: f64 = 2.0;
/// Restoring from a snapshot may cost at most this many × the slowest
/// post-restore tick — crash recovery must not stall serving for longer
/// than a couple of ticks of ordinary work.
pub const RESTORE_COST_MAX_TICKS: f64 = 2.0;

/// Context length/prompt length at and above which the decode/prefill
/// speedup bars apply (short contexts are fixed-overhead dominated).
const LONG_CONTEXT: f64 = 256.0;

fn gate_ref_decode(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("ref_decode: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let qlen = e.get("qlen")?.as_f64()?;
        let speedup = e.get("speedup")?.as_f64()?;
        if qlen >= LONG_CONTEXT && speedup < DECODE_SPEEDUP_MIN {
            v.push(format!(
                "ref_decode: fused decode speedup {speedup:.2}x < \
                 {DECODE_SPEEDUP_MIN}x at qlen={qlen}"
            ));
        }
    }
    Ok(v)
}

fn gate_paged_decode(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("paged_decode: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let qlen = e.get("qlen")?.as_f64()?;
        let overhead = e.get("paged_overhead_pct")?.as_f64()?;
        if overhead > PAGED_OVERHEAD_MAX_PCT {
            v.push(format!(
                "paged_decode: shared-pool overhead {overhead:.2}% > \
                 {PAGED_OVERHEAD_MAX_PCT}% at qlen={qlen}"
            ));
        }
    }
    Ok(v)
}

fn gate_prefill(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("prefill: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let t = e.get("t")?.as_f64()?;
        let speedup = e.get("speedup")?.as_f64()?;
        let mem = e.get("peak_ratio")?.as_f64()?;
        if t >= LONG_CONTEXT && speedup < PREFILL_SPEEDUP_MIN {
            v.push(format!(
                "prefill: chunked speedup {speedup:.2}x < {PREFILL_SPEEDUP_MIN}x at T={t}"
            ));
        }
        if mem < PREFILL_MEM_RATIO_MIN {
            v.push(format!(
                "prefill: f32 working-set shrink {mem:.2}x < {PREFILL_MEM_RATIO_MIN}x at T={t}"
            ));
        }
    }
    Ok(v)
}

fn gate_prefix_sharing(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("prefix_sharing: report has NO entries — did the bench measure anything?".to_string());
    }
    for e in entries {
        let t = e.get("t")?.as_f64()?;
        let dedup = e.get("dedup_ratio")?.as_f64()?;
        let skipped = e.get("chunks_skipped")?.as_f64()?;
        let deduped = e.get("bytes_deduped")?.as_f64()?;
        if dedup < PREFIX_DEDUP_MIN {
            v.push(format!(
                "prefix_sharing: page dedup {dedup:.2}x < {PREFIX_DEDUP_MIN}x at T={t}"
            ));
        }
        if skipped <= 0.0 {
            v.push(format!("prefix_sharing: no prefill chunks skipped at T={t}"));
        }
        if deduped <= 0.0 {
            v.push(format!("prefix_sharing: no bytes deduped at T={t}"));
        }
    }
    Ok(v)
}

fn gate_prefix_radix(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("prefix_radix: report has NO entries — did the bench measure anything?".to_string());
        return Ok(v);
    }
    for e in entries {
        let t = e.get("t")?.as_f64()?;
        let matched = e.get("matched_tokens")?.as_f64()?;
        let dedup = e.get("dedup_ratio")?.as_f64()?;
        let skipped = e.get("chunks_skipped")?.as_f64()?;
        if matched <= 0.0 {
            v.push(format!(
                "prefix_radix: zero tokens matched at T={t} — partial hits \
                 were never served"
            ));
        }
        if dedup < PREFIX_DEDUP_MIN {
            v.push(format!(
                "prefix_radix: page dedup {dedup:.2}x < {PREFIX_DEDUP_MIN}x at T={t}"
            ));
        }
        if skipped <= 0.0 {
            v.push(format!("prefix_radix: no prefill chunks skipped at T={t}"));
        }
    }
    // zero same-seed drift with the tree enabled: the bench runs the whole
    // scenario twice and folds logits, admission verdicts, and lease counts
    // into the fingerprints — sharing must change cost, never semantics
    let fp = j.get("fingerprint")?.as_str()?;
    let fp2 = j.get("fingerprint_repeat")?.as_str()?;
    if !matches!(j.get("fingerprint_drift")?, Json::Bool(false)) || fp != fp2 {
        v.push(format!(
            "prefix_radix: same-seed runs diverged with the tree enabled \
             (fingerprint {fp} vs {fp2}) — prefix sharing is nondeterministic"
        ));
    }
    // frozen-plan ablation: every method served partial hits by default
    // must measure inside the error budget
    let frozen = j.get("frozen_plan")?.as_arr()?;
    if frozen.is_empty() {
        v.push("prefix_radix: report carries no frozen-plan sweep entries".to_string());
    }
    for f in frozen {
        let name = f.get("method")?.as_str()?;
        let on = matches!(f.get("default_on")?, Json::Bool(true));
        let within = matches!(f.get("within_budget")?, Json::Bool(true));
        if on && !within {
            let nll = f.get("nll_delta")?.as_f64()?;
            v.push(format!(
                "prefix_radix: default-ON method `{name}` measured frozen-plan \
                 nll delta {nll:.4} > {FROZEN_PLAN_NLL_BUDGET} nats"
            ));
        }
    }
    Ok(v)
}

fn gate_traffic(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let sessions = j.get("sessions")?.as_f64()?;
    let completed = j.get("completed")?.as_f64()?;
    if completed <= 0.0 {
        v.push("traffic: NO sessions completed — did the harness run?".to_string());
    } else if completed < sessions {
        v.push(format!(
            "traffic: only {completed} of {sessions} sessions reached a \
             terminal state (run hit its tick ceiling — scheduler stall?)"
        ));
    }
    // zero same-seed drift: the harness runs the seed twice and folds every
    // outcome (ids, reasons, token streams, tenant counters — never
    // wall-clock) into the fingerprints; any divergence fails the build
    let fp = j.get("fingerprint")?.as_str()?;
    let fp2 = j.get("fingerprint_repeat")?.as_str()?;
    if !matches!(j.get("deterministic")?, Json::Bool(true)) || fp != fp2 {
        v.push(format!(
            "traffic: same-seed runs diverged (fingerprint {fp} vs {fp2}) — \
             nondeterminism in the serving path"
        ));
    }
    let p99 = j.get("p99_ttft_ms")?.as_f64()?;
    if p99 > TRAFFIC_P99_TTFT_MAX_MS {
        v.push(format!(
            "traffic: p99 TTFT {p99:.1} ms > {TRAFFIC_P99_TTFT_MAX_MS} ms"
        ));
    }
    if j.get("tenants")?.as_arr()?.is_empty() {
        v.push("traffic: report carries no per-tenant SLO stats".to_string());
    }
    Ok(v)
}

fn gate_chaos(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    // the soak must actually have injected faults — a chaos artifact from
    // a zero-rate run would pass every robustness bar vacuously
    let rate = j.get("chaos_rate")?.as_f64()?;
    if rate <= 0.0 {
        v.push(format!(
            "chaos: artifact written with chaos_rate {rate} — the soak \
             injected nothing"
        ));
    }
    let injected: f64 = j
        .get("faults_injected")?
        .as_arr()?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0))
        .sum();
    if rate > 0.0 && injected <= 0.0 {
        v.push("chaos: no faults fired despite a nonzero rate".to_string());
    }
    // recovery machinery must be reporting (schema presence is the check;
    // zero retries at a real fault rate would mean the hooks fell off)
    let retries = j.get("prefill_retries")?.as_f64()?;
    let _recoveries = j.get("fault_recoveries")?.as_f64()?;
    let _errors = j.get("errors")?.as_f64()?;
    if injected > 0.0 && retries <= 0.0 {
        v.push("chaos: faults fired but the retry path never engaged".to_string());
    }
    let sessions = j.get("sessions")?.as_f64()?;
    let completed = j.get("completed")?.as_f64()?;
    if completed < sessions {
        v.push(format!(
            "chaos: {completed} of {sessions} sessions reached a terminal \
             state — injected faults stranded requests"
        ));
    }
    let violations = j.get("invariant_violations")?.as_f64()?;
    if violations > 0.0 {
        v.push(format!(
            "chaos: {violations} tick(s) failed the cross-subsystem \
             invariant audit"
        ));
    }
    let leaked = j.get("leaked_pages")?.as_f64()?;
    if leaked > 0.0 {
        v.push(format!("chaos: {leaked} pool pages leaked at drain"));
    }
    // fault schedules are seeded: the soak reruns the seed and the entire
    // failure story must repeat bit-for-bit
    let fp = j.get("fingerprint")?.as_str()?;
    let fp2 = j.get("fingerprint_repeat")?.as_str()?;
    if !matches!(j.get("deterministic")?, Json::Bool(true)) || fp != fp2 {
        v.push(format!(
            "chaos: same-seed soaks diverged (fingerprint {fp} vs {fp2}) — \
             nondeterministic failure handling"
        ));
    }
    Ok(v)
}

fn gate_parallel(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    let entries = j.get("entries")?.as_arr()?;
    if entries.is_empty() {
        v.push("parallel: report has NO entries — did the bench measure anything?".to_string());
        return Ok(v);
    }
    // locate the two widths the bench runs; losing either is schema drift
    let mut base: Option<(f64, String, f64)> = None; // (ticks_per_s, fp, ticks)
    let mut wide: Option<(f64, String, f64)> = None;
    for e in entries {
        let workers = e.get("workers")?.as_f64()?;
        let tps = e.get("ticks_per_s")?.as_f64()?;
        let fp = e.get("fingerprint")?.as_str()?.to_string();
        let ticks = e.get("ticks")?.as_f64()?;
        if workers == 1.0 {
            base = Some((tps, fp, ticks));
        } else if workers == 4.0 {
            wide = Some((tps, fp, ticks));
        }
    }
    let (Some(base), Some(wide)) = (base, wide) else {
        v.push(
            "parallel: report is missing the workers=1 or workers=4 entry".to_string(),
        );
        return Ok(v);
    };
    // zero drift: bit-identity is the pool's contract, so both widths must
    // report the same fingerprint AND the same tick count
    if base.1 != wide.1 || base.2 != wide.2 {
        v.push(format!(
            "parallel: workers=4 drifted from workers=1 (fingerprint {} vs {}, \
             ticks {} vs {}) — the pool changed semantics, not just speed",
            base.1, wide.1, base.2, wide.2
        ));
    }
    let scaling = wide.0 / base.0.max(1e-9);
    if scaling < PARALLEL_SCALING_MIN {
        v.push(format!(
            "parallel: tick-throughput scaling {scaling:.2}x < \
             {PARALLEL_SCALING_MIN}x at 4 workers"
        ));
    }
    Ok(v)
}

fn gate_restore(j: &Json) -> Result<Vec<String>> {
    let mut v = Vec::new();
    // the writer stamps its schema; a version we don't read is drift, and
    // judging its runs by v1 rules would be guessing
    let schema = j.get("schema")?.as_str()?;
    if schema != "restore-v1" {
        v.push(format!(
            "restore: unknown report schema `{schema}` (this gate reads restore-v1)"
        ));
        return Ok(v);
    }
    let runs = j.get("runs")?.as_arr()?;
    if runs.is_empty() {
        v.push(
            "restore: report has NO runs — did the kill-and-restore smoke run?".to_string(),
        );
        return Ok(v);
    }
    for r in runs {
        let workers = r.get("workers")?.as_f64()?;
        let bytes = r.get("snapshot_bytes")?.as_f64()?;
        let restore_ms = r.get("restore_ms")?.as_f64()?;
        let tick_ms = r.get("tick_ms")?.as_f64()?;
        let fp = r.get("fingerprint")?.as_str()?;
        let fp2 = r.get("fingerprint_restored")?.as_str()?;
        if bytes <= 0.0 {
            v.push(format!(
                "restore: empty snapshot at workers={workers} — the kill tick \
                 was never reached"
            ));
        }
        // zero drift: the killed-and-restored run must replay the exact
        // event stream of its uninterrupted twin; divergence means the
        // snapshot lost (or invented) serving state
        if !matches!(r.get("drift")?, Json::Bool(false)) || fp != fp2 {
            v.push(format!(
                "restore: killed-and-restored run drifted from its \
                 uninterrupted twin at workers={workers} (fingerprint {fp} \
                 vs {fp2}) — the snapshot lost state"
            ));
        }
        if restore_ms > RESTORE_COST_MAX_TICKS * tick_ms {
            v.push(format!(
                "restore: restore cost {restore_ms:.2} ms > \
                 {RESTORE_COST_MAX_TICKS}x the slowest post-restore tick \
                 ({tick_ms:.2} ms) at workers={workers}"
            ));
        }
    }
    if !matches!(j.get("deterministic")?, Json::Bool(true)) {
        v.push("restore: report's own deterministic verdict is false".to_string());
    }
    Ok(v)
}

type Gate = fn(&Json) -> Result<Vec<String>>;

const GATES: [(&str, Gate); 9] = [
    ("BENCH_ref_decode.json", gate_ref_decode),
    ("BENCH_paged_decode.json", gate_paged_decode),
    ("BENCH_prefill.json", gate_prefill),
    ("BENCH_prefix_sharing.json", gate_prefix_sharing),
    ("BENCH_prefix_radix.json", gate_prefix_radix),
    ("BENCH_traffic.json", gate_traffic),
    ("BENCH_chaos.json", gate_chaos),
    ("BENCH_parallel.json", gate_parallel),
    ("BENCH_restore.json", gate_restore),
];

/// Run every gate over `dir`, returning the full violation list (empty =
/// every bar holds).
fn run_gates(dir: &Path) -> Vec<String> {
    let mut violations = Vec::new();
    for (file, gate) in GATES {
        let path = dir.join(file);
        match std::fs::read_to_string(&path) {
            Err(e) => violations.push(format!(
                "{file}: missing ({e}) — did its bench run before the gate?"
            )),
            Ok(src) => match Json::parse(&src).and_then(|j| gate(&j)) {
                Ok(v) => violations.extend(v),
                Err(e) => violations.push(format!("{file}: bad report schema: {e}")),
            },
        }
    }
    violations
}

fn main() -> ExitCode {
    let dir = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let violations = run_gates(Path::new(&dir));
    if violations.is_empty() {
        println!(
            "bench-gate: all ROADMAP perf bars hold \
             (decode >= {DECODE_SPEEDUP_MIN}x, prefill >= {PREFILL_SPEEDUP_MIN}x, \
             f32 shrink >= {PREFILL_MEM_RATIO_MIN}x, paged overhead <= \
             {PAGED_OVERHEAD_MAX_PCT}%, prefix dedup >= {PREFIX_DEDUP_MIN}x, \
             radix partial-hit dedup >= {PREFIX_DEDUP_MIN}x + drift-free + \
             frozen-plan <= {FROZEN_PLAN_NLL_BUDGET} nats, \
             traffic p99 TTFT <= {TRAFFIC_P99_TTFT_MAX_MS} ms + deterministic, \
             chaos soak all-terminal + invariant-clean + leak-free, \
             parallel scaling >= {PARALLEL_SCALING_MIN}x + drift-free, \
             kill-and-restore drift-free + restore <= \
             {RESTORE_COST_MAX_TICKS}x tick)"
        );
        return ExitCode::SUCCESS;
    }
    eprintln!("bench-gate: {} violation(s):", violations.len());
    for v in &violations {
        eprintln!("  FAIL {v}");
    }
    ExitCode::FAILURE
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> Json {
        Json::parse(src).unwrap()
    }

    fn decode_report(speedup_256: f64, speedup_512: f64) -> String {
        format!(
            r#"{{"bench":"ref_decode","entries":[
                {{"qlen":256,"fused_ms":1.0,"legacy_ms":{},"speedup":{speedup_256}}},
                {{"qlen":512,"fused_ms":1.0,"legacy_ms":{},"speedup":{speedup_512}}}]}}"#,
            speedup_256, speedup_512
        )
    }

    #[test]
    fn healthy_decode_report_passes() {
        let v = gate_ref_decode(&parse(&decode_report(3.4, 4.1))).unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn degraded_decode_speedup_fails() {
        let v = gate_ref_decode(&parse(&decode_report(2.9, 4.1))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("qlen=256"), "{v:?}");
        // both entries degraded → both reported
        let v = gate_ref_decode(&parse(&decode_report(1.0, 2.0))).unwrap();
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn short_context_is_exempt_from_the_decode_bar() {
        let src = r#"{"entries":[{"qlen":64,"speedup":1.1}]}"#;
        assert!(gate_ref_decode(&parse(src)).unwrap().is_empty());
    }

    #[test]
    fn paged_overhead_gate() {
        let ok = r#"{"entries":[{"qlen":256,"paged_overhead_pct":1.2},
                                {"qlen":512,"paged_overhead_pct":-0.5}]}"#;
        assert!(gate_paged_decode(&parse(ok)).unwrap().is_empty());
        let bad = r#"{"entries":[{"qlen":256,"paged_overhead_pct":7.5}]}"#;
        let v = gate_paged_decode(&parse(bad)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("7.50%"), "{v:?}");
    }

    #[test]
    fn prefill_gate_checks_speed_and_memory_independently() {
        let ok = r#"{"entries":[{"t":256,"speedup":3.5,"peak_ratio":2.6},
                                {"t":512,"speedup":4.0,"peak_ratio":3.0}]}"#;
        assert!(gate_prefill(&parse(ok)).unwrap().is_empty());
        let slow = r#"{"entries":[{"t":256,"speedup":2.0,"peak_ratio":2.6}]}"#;
        assert_eq!(gate_prefill(&parse(slow)).unwrap().len(), 1);
        let fat = r#"{"entries":[{"t":256,"speedup":3.5,"peak_ratio":1.5}]}"#;
        let v = gate_prefill(&parse(fat)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("working-set"), "{v:?}");
        // the memory bar applies at every T, the speed bar only at T >= 256
        let short = r#"{"entries":[{"t":64,"speedup":1.0,"peak_ratio":1.0}]}"#;
        assert_eq!(gate_prefill(&parse(short)).unwrap().len(), 1);
    }

    #[test]
    fn prefix_sharing_gate() {
        let ok = r#"{"entries":[{"t":256,"dedup_ratio":3.8,"chunks_skipped":96,
                                 "bytes_deduped":1000000}]}"#;
        assert!(gate_prefix_sharing(&parse(ok)).unwrap().is_empty());
        let bad = r#"{"entries":[{"t":256,"dedup_ratio":1.1,"chunks_skipped":0,
                                  "bytes_deduped":0}]}"#;
        let v = gate_prefix_sharing(&parse(bad)).unwrap();
        assert_eq!(v.len(), 3, "{v:?}");
    }

    fn prefix_radix_report(
        dedup: f64,
        matched: f64,
        skipped: f64,
        fp2: &str,
        nll: f64,
    ) -> String {
        let within = nll <= FROZEN_PLAN_NLL_BUDGET;
        format!(
            r#"{{"bench":"prefix_radix","variant":"mix30","entries":[
                {{"t":2112,"k":4,"shared_tokens":2048,"matched_tokens":{matched},
                  "seam":{matched},"hit_resume_ms":4.0,"full_prefill_ms":60.0,
                  "resume_speedup":15.0,"pages_shared":512,
                  "pages_private_equiv":1984,"dedup_ratio":{dedup},
                  "chunks_skipped":{skipped},"bytes_deduped":4000000}}],
                "fingerprint":"0xabad1dea","fingerprint_repeat":"{fp2}",
                "fingerprint_drift":{},
                "frozen_plan":[
                  {{"method":"mixkvq-mix30","default_on":true,"logit_err":0.01,
                    "nll_delta":{nll},"within_budget":{within}}},
                  {{"method":"kvquant-kv2","default_on":false,"logit_err":2.0,
                    "nll_delta":1.7,"within_budget":false}}]}}"#,
            fp2 != "0xabad1dea"
        )
    }

    #[test]
    fn healthy_prefix_radix_report_passes() {
        let src = prefix_radix_report(3.8, 1984.0, 992.0, "0xabad1dea", 0.01);
        let v = gate_prefix_radix(&parse(&src)).unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn prefix_radix_gate_catches_every_degradation_independently() {
        // dedup below the 2x bar
        let v = gate_prefix_radix(&parse(&prefix_radix_report(
            1.3, 1984.0, 992.0, "0xabad1dea", 0.01,
        )))
        .unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("1.30x"), "{v:?}");
        // partial hits never served (and therefore nothing skipped)
        let v = gate_prefix_radix(&parse(&prefix_radix_report(
            3.8, 0.0, 0.0, "0xabad1dea", 0.01,
        )))
        .unwrap();
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("zero tokens matched"), "{v:?}");
        assert!(v[1].contains("chunks skipped"), "{v:?}");
        // same-seed fingerprint drift with the tree enabled
        let v = gate_prefix_radix(&parse(&prefix_radix_report(
            3.8, 1984.0, 992.0, "0xabad1deb", 0.01,
        )))
        .unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("diverged"), "{v:?}");
        // a default-ON method outside the frozen-plan budget (the
        // default-OFF kvquant entry is outside it in every report and must
        // never trip the bar)
        let v = gate_prefix_radix(&parse(&prefix_radix_report(
            3.8, 1984.0, 992.0, "0xabad1dea", 0.9,
        )))
        .unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("mixkvq-mix30"), "{v:?}");
        // a sweep that vanished is a regression, not a pass
        let src = prefix_radix_report(3.8, 1984.0, 992.0, "0xabad1dea", 0.01);
        let start = src.find(r#""frozen_plan""#).unwrap();
        let gutted = format!("{}\"frozen_plan\":[]}}", &src[..start]);
        let v = gate_prefix_radix(&parse(&gutted)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("no frozen-plan sweep"), "{v:?}");
        // no entries at all
        let empty = r#"{"entries":[]}"#;
        let v = gate_prefix_radix(&parse(empty)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("NO entries"), "{v:?}");
    }

    fn traffic_report(completed: f64, p99: f64, fp: &str, fp2: &str, det: bool) -> String {
        format!(
            r#"{{"schema":"traffic-v1","sessions":200,"completed":{completed},
                 "rejected":0,"ticks":120,"max_in_flight":64,
                 "p99_ttft_ms":{p99},"fingerprint":"{fp}",
                 "fingerprint_repeat":"{fp2}","deterministic":{det},
                 "tenants":[{{"tenant":0,"served":100}},{{"tenant":1,"served":100}}]}}"#
        )
    }

    #[test]
    fn healthy_traffic_report_passes() {
        let src = traffic_report(200.0, 41.5, "deadbeef", "deadbeef", true);
        assert!(gate_traffic(&parse(&src)).unwrap().is_empty());
    }

    #[test]
    fn traffic_nondeterminism_fails() {
        // diverging fingerprints fail even if the bool lies
        let src = traffic_report(200.0, 41.5, "deadbeef", "deadbee0", true);
        let v = gate_traffic(&parse(&src)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("diverged"), "{v:?}");
        // and an honest false fails too
        let src = traffic_report(200.0, 41.5, "deadbeef", "deadbeef", false);
        assert_eq!(gate_traffic(&parse(&src)).unwrap().len(), 1);
    }

    #[test]
    fn traffic_slo_and_completion_bars() {
        let slow = traffic_report(200.0, 9000.0, "aa", "aa", true);
        let v = gate_traffic(&parse(&slow)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("p99 TTFT"), "{v:?}");
        let stalled = traffic_report(150.0, 41.5, "aa", "aa", true);
        let v = gate_traffic(&parse(&stalled)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("terminal"), "{v:?}");
        let dead = traffic_report(0.0, 0.0, "aa", "aa", true);
        let v = gate_traffic(&parse(&dead)).unwrap();
        assert!(v[0].contains("NO sessions"), "{v:?}");
        let no_tenants = r#"{"sessions":10,"completed":10,"p99_ttft_ms":1.0,
            "fingerprint":"aa","fingerprint_repeat":"aa","deterministic":true,
            "tenants":[]}"#;
        let v = gate_traffic(&parse(no_tenants)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("per-tenant"), "{v:?}");
    }

    fn chaos_report(
        completed: f64,
        violations: f64,
        leaked: f64,
        injected: &str,
        retries: f64,
        det: bool,
        fp2: &str,
    ) -> String {
        format!(
            r#"{{"schema":"traffic-v1","sessions":200,"completed":{completed},
                 "rejected":0,"ticks":300,"chaos_rate":0.05,
                 "invariant_violations":{violations},"leaked_pages":{leaked},
                 "faults_injected":{injected},"prefill_retries":{retries},
                 "fault_recoveries":9,"errors":2,"deadline_retirements":0,
                 "p99_ttft_ms":50.0,"fingerprint":"feedface",
                 "fingerprint_repeat":"{fp2}","deterministic":{det},
                 "tenants":[{{"tenant":0,"served":200}}]}}"#
        )
    }

    #[test]
    fn healthy_chaos_report_passes() {
        let src = chaos_report(200.0, 0.0, 0.0, "[12,8,5,2]", 11.0, true, "feedface");
        let v = gate_chaos(&parse(&src)).unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn chaos_gate_catches_every_degradation_independently() {
        // stranded sessions
        let v = gate_chaos(&parse(&chaos_report(150.0, 0.0, 0.0, "[12,8,5,2]", 11.0, true, "feedface"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("terminal"), "{v:?}");
        // invariant violations
        let v = gate_chaos(&parse(&chaos_report(200.0, 3.0, 0.0, "[12,8,5,2]", 11.0, true, "feedface"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("invariant"), "{v:?}");
        // leaked pages
        let v = gate_chaos(&parse(&chaos_report(200.0, 0.0, 4.0, "[12,8,5,2]", 11.0, true, "feedface"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("leaked"), "{v:?}");
        // vacuous soak: nothing injected
        let v = gate_chaos(&parse(&chaos_report(200.0, 0.0, 0.0, "[0,0,0,0]", 0.0, true, "feedface"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("no faults fired"), "{v:?}");
        // faults fired but the retry machinery never engaged
        let v = gate_chaos(&parse(&chaos_report(200.0, 0.0, 0.0, "[12,8,5,2]", 0.0, true, "feedface"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("retry path"), "{v:?}");
        // nondeterministic failure story
        let v = gate_chaos(&parse(&chaos_report(200.0, 0.0, 0.0, "[12,8,5,2]", 11.0, true, "feedfacf"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("diverged"), "{v:?}");
    }

    #[test]
    fn chaos_gate_rejects_missing_recovery_counters() {
        // a report that drops the recovery counters is schema drift
        let src = r#"{"sessions":200,"completed":200,"chaos_rate":0.05,
            "invariant_violations":0,"leaked_pages":0,
            "faults_injected":[1,1,1,1],
            "fingerprint":"aa","fingerprint_repeat":"aa","deterministic":true}"#;
        assert!(gate_chaos(&parse(src)).is_err());
    }

    fn parallel_report(tps1: f64, tps4: f64, fp1: &str, fp4: &str) -> String {
        format!(
            r#"{{"bench":"parallel","entries":[
                {{"workers":1,"wall_ms":900.0,"ticks":120,"ticks_per_s":{tps1},
                  "fingerprint":"{fp1}"}},
                {{"workers":4,"wall_ms":300.0,"ticks":120,"ticks_per_s":{tps4},
                  "fingerprint":"{fp4}"}}],
                "scaling":{},"fingerprint_drift":{}}}"#,
            tps4 / tps1,
            fp1 != fp4
        )
    }

    #[test]
    fn healthy_parallel_report_passes() {
        let src = parallel_report(100.0, 280.0, "cafe0123", "cafe0123");
        assert!(gate_parallel(&parse(&src)).unwrap().is_empty());
    }

    #[test]
    fn parallel_gate_catches_scaling_and_drift_independently() {
        // below the 2x bar
        let v = gate_parallel(&parse(&parallel_report(100.0, 150.0, "aa", "aa"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("1.50x"), "{v:?}");
        // fingerprint drift between widths — even at great scaling
        let v = gate_parallel(&parse(&parallel_report(100.0, 390.0, "aa", "ab"))).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("drifted"), "{v:?}");
        // a report missing one width is schema drift, not a pass
        let one = r#"{"entries":[{"workers":1,"ticks":10,"ticks_per_s":50.0,
                       "fingerprint":"aa"}]}"#;
        let v = gate_parallel(&parse(one)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("missing"), "{v:?}");
        let empty = r#"{"entries":[]}"#;
        let v = gate_parallel(&parse(empty)).unwrap();
        assert!(v[0].contains("NO entries"), "{v:?}");
    }

    fn restore_report(
        fp1: &str,
        fp1r: &str,
        restore_ms: f64,
        tick_ms: f64,
        bytes: f64,
    ) -> String {
        let drift = fp1 != fp1r;
        format!(
            r#"{{"schema":"restore-v1","sessions":24,"runs":[
                {{"workers":1,"snapshot_bytes":{bytes},"snapshot_ms":0.8,
                  "restore_ms":{restore_ms},"tick_ms":{tick_ms},
                  "fingerprint":"{fp1}","fingerprint_restored":"{fp1r}",
                  "drift":{drift}}},
                {{"workers":4,"snapshot_bytes":{bytes},"snapshot_ms":0.8,
                  "restore_ms":{restore_ms},"tick_ms":{tick_ms},
                  "fingerprint":"0b5e55ed","fingerprint_restored":"0b5e55ed",
                  "drift":false}}],
                "deterministic":{}}}"#,
            !drift
        )
    }

    #[test]
    fn healthy_restore_report_passes() {
        let src = restore_report("c0ffee01", "c0ffee01", 3.0, 2.0, 81920.0);
        let v = gate_restore(&parse(&src)).unwrap();
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn restore_gate_catches_every_degradation_independently() {
        // drift between the killed run and its uninterrupted twin — the
        // mismatched fingerprints AND the honest drift/deterministic flags
        // each trip, but drift is reported once per run
        let v = gate_restore(&parse(&restore_report(
            "c0ffee01", "c0ffee02", 3.0, 2.0, 81920.0,
        )))
        .unwrap();
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("drifted") && v[0].contains("workers=1"), "{v:?}");
        assert!(v[1].contains("deterministic"), "{v:?}");
        // a lying drift=false with equal fingerprints but deterministic
        // honestly false still fails on the summary verdict
        let src = restore_report("aa", "aa", 3.0, 2.0, 81920.0)
            .replace(r#""deterministic":true"#, r#""deterministic":false"#);
        let v = gate_restore(&parse(&src)).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        // restore slower than 2 ticks of service (both runs trip)
        let v = gate_restore(&parse(&restore_report("aa", "aa", 9.0, 2.0, 81920.0))).unwrap();
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("restore cost"), "{v:?}");
        // empty snapshot: the kill tick was never reached
        let v = gate_restore(&parse(&restore_report("aa", "aa", 3.0, 2.0, 0.0))).unwrap();
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("empty snapshot"), "{v:?}");
        // no runs at all
        let none = r#"{"schema":"restore-v1","sessions":24,"runs":[],
                       "deterministic":true}"#;
        let v = gate_restore(&parse(none)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("NO runs"), "{v:?}");
        // a schema we don't read is drift, not a pass
        let v2 = r#"{"schema":"restore-v2","runs":[],"deterministic":true}"#;
        let v = gate_restore(&parse(v2)).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("restore-v2") && v[0].contains("restore-v1"), "{v:?}");
        // a run missing a field is schema drift → hard error
        let gutted = r#"{"schema":"restore-v1","runs":[{"workers":1}],
                         "deterministic":true}"#;
        assert!(gate_restore(&parse(gutted)).is_err());
    }

    #[test]
    fn empty_entries_are_a_violation() {
        // a bench that regresses to writing no data must not pass green
        let empty = r#"{"bench":"x","entries":[]}"#;
        for gate in [
            gate_ref_decode as Gate,
            gate_paged_decode,
            gate_prefill,
            gate_prefix_sharing,
        ] {
            let v = gate(&parse(empty)).unwrap();
            assert_eq!(v.len(), 1);
            assert!(v[0].contains("NO entries"), "{v:?}");
        }
    }

    #[test]
    fn schema_drift_and_missing_files_are_violations() {
        // a renamed field must fail loudly, not silently pass
        let drifted = r#"{"entries":[{"qlen":256,"speed_up":3.5}]}"#;
        assert!(gate_ref_decode(&parse(drifted)).is_err());
        // an empty directory reports one violation per expected artifact
        let dir = std::env::temp_dir().join("mixkvq_bench_gate_empty_test");
        let _ = std::fs::create_dir_all(&dir);
        let v = run_gates(&dir);
        assert_eq!(v.len(), GATES.len());
        assert!(v.iter().all(|x| x.contains("missing")), "{v:?}");
    }

    #[test]
    fn end_to_end_pass_and_fail_over_a_real_directory() {
        let dir = std::env::temp_dir().join("mixkvq_bench_gate_e2e_test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("BENCH_ref_decode.json"), decode_report(3.2, 3.9)).unwrap();
        std::fs::write(
            dir.join("BENCH_paged_decode.json"),
            r#"{"entries":[{"qlen":256,"paged_overhead_pct":0.8}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_prefill.json"),
            r#"{"entries":[{"t":256,"speedup":3.3,"peak_ratio":2.4}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_prefix_sharing.json"),
            r#"{"entries":[{"t":256,"dedup_ratio":3.5,"chunks_skipped":96,
                            "bytes_deduped":500000}]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_prefix_radix.json"),
            prefix_radix_report(3.8, 1984.0, 992.0, "0xabad1dea", 0.01),
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_traffic.json"),
            traffic_report(200.0, 38.2, "0123abcd", "0123abcd", true),
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_chaos.json"),
            chaos_report(200.0, 0.0, 0.0, "[12,8,5,2]", 11.0, true, "feedface"),
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_parallel.json"),
            parallel_report(100.0, 275.0, "cafe0123", "cafe0123"),
        )
        .unwrap();
        std::fs::write(
            dir.join("BENCH_restore.json"),
            restore_report("c0ffee01", "c0ffee01", 2.5, 1.8, 65536.0),
        )
        .unwrap();
        assert!(run_gates(&dir).is_empty());
        // degrade ONE artifact → exactly its violations surface
        std::fs::write(dir.join("BENCH_ref_decode.json"), decode_report(2.0, 3.9)).unwrap();
        let v = run_gates(&dir);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("ref_decode"), "{v:?}");
    }
}
