//! Prefill/decode interleaving policy + memory admission control.
//!
//! Policy (vLLM-style, specialized to a static decode batch):
//! * decode has priority: run one decode step per cycle over live slots;
//! * before each decode step, admit up to `max_prefills_per_cycle` waiting
//!   requests into free slots — if the memory accountant can reserve their
//!   worst-case cache bytes (prevents mid-request OOM, which would force
//!   eviction we don't model);
//! * requests whose prompt exceeds every prefill bucket are rejected.

use crate::kvcache::accountant::MemoryAccountant;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// Cap on prefills interleaved per decode cycle (bounds decode stall).
    pub max_prefills_per_cycle: usize,
    /// Worst-case per-request cache bytes (from the accountant).
    pub per_request_bytes: usize,
}

pub struct Scheduler {
    pub policy: SchedulerPolicy,
    pub accountant: MemoryAccountant,
    pub rejected: u64,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy, budget_bytes: usize) -> Scheduler {
        Scheduler { policy, accountant: MemoryAccountant::new(budget_bytes), rejected: 0 }
    }

    /// How many admissions to attempt this cycle given free slots.
    pub fn admission_quota(&self, free_slots: usize, waiting: usize) -> usize {
        free_slots.min(waiting).min(self.policy.max_prefills_per_cycle)
    }

    /// Try to reserve memory for one request at the default (policy)
    /// worst-case size.
    pub fn try_admit(&mut self) -> bool {
        self.try_admit_bytes(self.policy.per_request_bytes)
    }

    /// Try to reserve an exact worst-case byte count — methods route
    /// per-request, so heterogeneous variants reserve their own footprint
    /// rather than the server default's.
    pub fn try_admit_bytes(&mut self, bytes: usize) -> bool {
        self.accountant.try_reserve(bytes)
    }

    pub fn release(&mut self) {
        self.release_bytes(self.policy.per_request_bytes);
    }

    pub fn release_bytes(&mut self, bytes: usize) {
        self.accountant.release(bytes);
    }

    /// Max concurrent requests the budget supports (Fig. 5's max batch).
    pub fn max_concurrent(&self) -> usize {
        self.accountant.budget_bytes / self.policy.per_request_bytes.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(budget: usize, per_req: usize) -> Scheduler {
        Scheduler::new(
            SchedulerPolicy { max_prefills_per_cycle: 2, per_request_bytes: per_req },
            budget,
        )
    }

    #[test]
    fn quota_is_min_of_three() {
        let s = sched(1000, 100);
        assert_eq!(s.admission_quota(5, 9), 2); // capped by policy
        assert_eq!(s.admission_quota(1, 9), 1); // capped by slots
        assert_eq!(s.admission_quota(5, 0), 0); // capped by queue
    }

    #[test]
    fn memory_admission() {
        let mut s = sched(250, 100);
        assert!(s.try_admit());
        assert!(s.try_admit());
        assert!(!s.try_admit(), "third request exceeds budget");
        s.release();
        assert!(s.try_admit());
        assert_eq!(s.max_concurrent(), 2);
    }

    #[test]
    fn byte_exact_admission_for_mixed_variants() {
        let mut s = sched(250, 100);
        assert!(s.try_admit_bytes(200)); // a bf16-sized tenant
        assert!(s.try_admit_bytes(50)); // a 2-bit tenant still fits
        assert!(!s.try_admit_bytes(1), "budget saturated");
        s.release_bytes(200);
        assert!(s.try_admit_bytes(100));
    }
}
