//! Prefill/decode interleaving policy + memory admission control.
//!
//! Policy (vLLM-style, specialized to a static decode batch):
//! * decode has priority: run one decode step per cycle over live slots;
//! * before each decode step, admit up to `max_prefills_per_cycle` waiting
//!   requests into free slots — admission is **occupancy-based**: a request
//!   is admitted when the shared page pool can cover its *actual* prefill
//!   pages and still keep a reserve watermark free for live requests'
//!   flushes. A 10-token request therefore no longer costs the concurrency
//!   budget of a 4096-token one; `worst_case_request_bytes` survives only
//!   as the reject-at-submit upper bound. **Shared prefix pages are charged
//!   once**: the pool's `leased` counter (which both `try_admit_pages` and
//!   `observe_occupancy` read) counts a refcounted page exactly once no
//!   matter how many requests reference it, and a request whose prompt hits
//!   the prefix tree in full is admitted at ZERO pages (`Engine::
//!   prefill_pages_for_prompt`) — N tenants over one prompt cost the
//!   admission budget of one, which is the concurrency half of the
//!   prefix-sharing win. A partial hit is charged only its seam-to-end
//!   tail, and admission touches the matched node path first so pressure
//!   shedding cannot evict the prefix it is about to adopt.
//! * a live slot whose due flush cannot lease pages is *parked* for the
//!   tick (router::Server::decode), not failed;
//! * requests whose prompt exceeds every prefill bucket are rejected.

use crate::kvcache::accountant::MemoryAccountant;
use crate::kvcache::pool::KvPool;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// Cap on prefills interleaved per decode cycle (bounds decode stall).
    pub max_prefills_per_cycle: usize,
    /// Worst-case per-request cache bytes (from the accountant) — the
    /// submit-time reject bound and the Fig. 5 worst-case-batch yardstick.
    pub per_request_bytes: usize,
    /// Pages the pool must keep free after an admission — decode headroom
    /// so live requests' flushes don't immediately starve.
    pub reserve_pages: usize,
}

pub struct Scheduler {
    pub policy: SchedulerPolicy,
    pub accountant: MemoryAccountant,
    /// Shared page pool occupancy-based admission draws from. `None` falls
    /// back to byte-reservation admission (standalone/unit-test use).
    pub pool: Option<KvPool>,
    pub rejected: u64,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy, budget_bytes: usize) -> Scheduler {
        Scheduler {
            policy,
            accountant: MemoryAccountant::new(budget_bytes),
            pool: None,
            rejected: 0,
        }
    }

    /// Scheduler admitting against `pool` occupancy (the serving path).
    pub fn with_pool(policy: SchedulerPolicy, budget_bytes: usize, pool: KvPool) -> Scheduler {
        Scheduler {
            policy,
            accountant: MemoryAccountant::new(budget_bytes),
            pool: Some(pool),
            rejected: 0,
        }
    }

    /// How many admissions to attempt this cycle given free slots.
    pub fn admission_quota(&self, free_slots: usize, waiting: usize) -> usize {
        free_slots.min(waiting).min(self.policy.max_prefills_per_cycle)
    }

    /// Occupancy-based admission: can the pool cover `needed` prefill pages
    /// and still keep the reserve watermark free? Without a pool this is
    /// the legacy byte reservation at the policy's worst-case size.
    pub fn try_admit_pages(&mut self, needed: usize) -> bool {
        match &self.pool {
            Some(p) => p.available() >= needed + self.policy.reserve_pages,
            None => self.try_admit_bytes(self.policy.per_request_bytes),
        }
    }

    /// Static feasibility: could `needed` pages EVER be admitted under the
    /// watermark? False means the request must be rejected at submit, or
    /// it would camp the queue head forever.
    pub fn pages_admissible(&self, needed: usize) -> bool {
        match &self.pool {
            Some(p) => match p.max_pages() {
                Some(max) => needed + self.policy.reserve_pages <= max,
                None => true,
            },
            None => true,
        }
    }

    /// Sample current pool occupancy into the accountant's live/peak gauges
    /// (leased pages at the pool's per-page deployment cost; a shared
    /// prefix page is one leased page however many requests hold it).
    pub fn observe_occupancy(&mut self, extra_bytes: usize) {
        if let Some(p) = &self.pool {
            let bytes = p.leased() * p.page_deploy_bytes() + extra_bytes;
            self.accountant.observe(bytes);
        }
    }

    /// Try to reserve memory for one request at the default (policy)
    /// worst-case size — the legacy admission path, kept as the yardstick
    /// the occupancy test compares against.
    pub fn try_admit(&mut self) -> bool {
        self.try_admit_bytes(self.policy.per_request_bytes)
    }

    /// Try to reserve an exact worst-case byte count.
    pub fn try_admit_bytes(&mut self, bytes: usize) -> bool {
        self.accountant.try_reserve(bytes)
    }

    pub fn release(&mut self) {
        self.release_bytes(self.policy.per_request_bytes);
    }

    pub fn release_bytes(&mut self, bytes: usize) {
        self.accountant.release(bytes);
    }

    /// Max concurrent requests worst-case admission would allow (Fig. 5's
    /// max batch under the old scheme — the occupancy admission's baseline).
    pub fn max_concurrent(&self) -> usize {
        self.accountant.budget_bytes / self.policy.per_request_bytes.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(budget: usize, per_req: usize) -> Scheduler {
        Scheduler::new(
            SchedulerPolicy {
                max_prefills_per_cycle: 2,
                per_request_bytes: per_req,
                reserve_pages: 0,
            },
            budget,
        )
    }

    #[test]
    fn quota_is_min_of_three() {
        let s = sched(1000, 100);
        assert_eq!(s.admission_quota(5, 9), 2); // capped by policy
        assert_eq!(s.admission_quota(1, 9), 1); // capped by slots
        assert_eq!(s.admission_quota(5, 0), 0); // capped by queue
    }

    #[test]
    fn memory_admission() {
        let mut s = sched(250, 100);
        assert!(s.try_admit());
        assert!(s.try_admit());
        assert!(!s.try_admit(), "third request exceeds budget");
        s.release();
        assert!(s.try_admit());
        assert_eq!(s.max_concurrent(), 2);
    }

    #[test]
    fn byte_exact_admission_for_mixed_variants() {
        let mut s = sched(250, 100);
        assert!(s.try_admit_bytes(200)); // a bf16-sized tenant
        assert!(s.try_admit_bytes(50)); // a 2-bit tenant still fits
        assert!(!s.try_admit_bytes(1), "budget saturated");
        s.release_bytes(200);
        assert!(s.try_admit_bytes(100));
    }

    #[test]
    fn occupancy_admission_respects_watermark() {
        use crate::quant::window::TierSpec;
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let pool = KvPool::for_specs([&spec], 32, 32, Some(10));
        let mut s = Scheduler::with_pool(
            SchedulerPolicy {
                max_prefills_per_cycle: 2,
                per_request_bytes: 1000,
                reserve_pages: 2,
            },
            1_000_000,
            pool.clone(),
        );
        // 10 pages, 2 reserved: an 8-page request fits, a 9-page one never
        assert!(s.try_admit_pages(8));
        assert!(!s.try_admit_pages(9));
        assert!(!s.pages_admissible(9));
        assert!(s.pages_admissible(8));
        // occupancy shrinks what's admissible
        let a = pool.lease().unwrap();
        let b = pool.lease().unwrap();
        assert!(s.try_admit_pages(6));
        assert!(!s.try_admit_pages(7));
        s.observe_occupancy(0);
        assert_eq!(s.accountant.live_bytes, 2 * pool.page_deploy_bytes());
        drop((a, b));
        assert!(s.try_admit_pages(8));
    }
}
