//! Serving metrics: latency/throughput aggregates (Fig. 5) and the
//! operation-level time breakdown (Table 7).
//!
//! Completion records live in a **bounded ring** ([`CompletedLog`]): a
//! long-lived server keeps only the most recent `cap` full `Completed`
//! records (token streams) while totals, per-method counts, and the
//! TTFT/latency/queue-wait percentiles **stream** over every completion
//! ever via fixed-size reservoirs — memory no longer grows with uptime.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::time::Instant;

use crate::util::faults::N_FAULT_SITES;
use crate::util::rng::Pcg32;
use crate::util::snapshot::{corrupt, SnapReader, SnapResult, SnapWriter};
use crate::util::stats::percentile;

use super::engine::EngineTimers;
use super::events::{reason_from_tag, reason_tag};
use super::session::Completed;

/// Default retained capacity of [`CompletedLog`] — generous enough that
/// every bench/offline trace gets its full record set back from
/// `Server::run`, small enough to bound a long-lived server.
pub const COMPLETED_RING_DEFAULT: usize = 4096;

/// Samples each percentile reservoir keeps. Below this many observations
/// the percentiles are exact; beyond it they are a uniform sample
/// (Algorithm R, deterministic seed).
const RESERVOIR_SAMPLES: usize = 512;

/// Distinct tenants tracked with their own percentile reservoirs before
/// further tenants fold into one shared overflow bucket — bounds per-tenant
/// SLO memory no matter how many tenant ids traffic presents.
pub const TENANT_MAX: usize = 16;

/// Tenant key of the overflow bucket (never a real tenant id).
pub const TENANT_OVERFLOW: u32 = u32::MAX;

/// Fixed-size uniform sample over an unbounded stream (Vitter's
/// Algorithm R) — the streamed substitute for "sort every observation
/// ever" percentile queries.
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Pcg32,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: Pcg32::seeded(0x5eed_cafe),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = (self.rng.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Observations ever pushed (≥ the retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    /// Serialize the full sampler state — including the Algorithm-R RNG
    /// position, so a restored reservoir makes the same keep/replace
    /// decisions on future observations as the uninterrupted one.
    pub fn write_snap<W: Write>(&self, w: &mut SnapWriter<W>) -> SnapResult<()> {
        w.usize(self.cap)?;
        w.u64(self.seen)?;
        w.slice_f64(&self.samples)?;
        let (state, inc) = self.rng.state();
        w.u64(state)?;
        w.u64(inc)
    }

    pub fn read_snap<R: Read>(&mut self, r: &mut SnapReader<R>) -> SnapResult<()> {
        self.cap = r.usize("reservoir cap")?.max(1);
        self.seen = r.u64("reservoir seen")?;
        self.samples = r.vec_f64("reservoir samples")?;
        if self.samples.len() > self.cap {
            return Err(corrupt(format!(
                "reservoir holds {} samples over cap {}",
                self.samples.len(),
                self.cap
            )));
        }
        let state = r.u64("reservoir rng state")?;
        let inc = r.u64("reservoir rng inc")?;
        self.rng = Pcg32::from_state(state, inc);
        Ok(())
    }
}

/// Per-tenant SLO aggregates: streamed TTFT/latency/queue-wait reservoirs
/// plus served/unserved counts. At most [`TENANT_MAX`] tenants get their
/// own entry; the rest share the [`TENANT_OVERFLOW`] bucket.
pub struct TenantStat {
    pub tenant: u32,
    /// Sessions that produced tokens (counted in the reservoirs).
    pub completed: u64,
    /// Sessions retired without a first token (rejected / cancelled while
    /// queued) — the fairness denominator the reservoirs exclude.
    pub unserved: u64,
    pub ttft: Reservoir,
    pub latency: Reservoir,
    pub queue_wait: Reservoir,
}

impl TenantStat {
    fn new(tenant: u32) -> TenantStat {
        TenantStat {
            tenant,
            completed: 0,
            unserved: 0,
            ttft: Reservoir::new(RESERVOIR_SAMPLES),
            latency: Reservoir::new(RESERVOIR_SAMPLES),
            queue_wait: Reservoir::new(RESERVOIR_SAMPLES),
        }
    }

    fn write_snap<W: Write>(&self, w: &mut SnapWriter<W>) -> SnapResult<()> {
        w.u32(self.tenant)?;
        w.u64(self.completed)?;
        w.u64(self.unserved)?;
        self.ttft.write_snap(w)?;
        self.latency.write_snap(w)?;
        self.queue_wait.write_snap(w)
    }

    fn read_snap<R: Read>(r: &mut SnapReader<R>) -> SnapResult<TenantStat> {
        let mut ts = TenantStat::new(r.u32("tenant id")?);
        ts.completed = r.u64("tenant completed")?;
        ts.unserved = r.u64("tenant unserved")?;
        ts.ttft.read_snap(r)?;
        ts.latency.read_snap(r)?;
        ts.queue_wait.read_snap(r)?;
        Ok(ts)
    }
}

fn write_completed<W: Write>(w: &mut SnapWriter<W>, c: &Completed) -> SnapResult<()> {
    w.u64(c.id)?;
    w.usize(c.prompt_len)?;
    w.slice_i32(&c.tokens)?;
    w.u8(reason_tag(c.reason))?;
    w.str(&c.method)?;
    w.u32(c.tenant)?;
    match c.ttft_ms {
        Some(t) => {
            w.bool(true)?;
            w.f64(t)?;
        }
        None => w.bool(false)?,
    }
    w.f64(c.queue_ms)?;
    w.f64(c.total_ms)
}

fn read_completed<R: Read>(r: &mut SnapReader<R>) -> SnapResult<Completed> {
    Ok(Completed {
        id: r.u64("completed id")?,
        prompt_len: r.usize("completed prompt_len")?,
        tokens: r.vec_i32("completed tokens")?,
        reason: reason_from_tag(r.u8("completed reason")?)?,
        method: r.str("completed method")?,
        tenant: r.u32("completed tenant")?,
        ttft_ms: if r.bool("completed has_ttft")? {
            Some(r.f64("completed ttft_ms")?)
        } else {
            None
        },
        queue_ms: r.f64("completed queue_ms")?,
        total_ms: r.f64("completed total_ms")?,
    })
}

/// Bounded completion log: a fixed-capacity ring of the most recent
/// [`Completed`] records plus streamed aggregates over everything ever
/// pushed. Records are addressed by a monotonically increasing sequence
/// number ([`CompletedLog::push`] returns it); once the ring evicts a
/// record, [`CompletedLog::get`] answers `None` and the caller falls back
/// to whatever stub it kept (`Server::poll` keeps reason + token count).
pub struct CompletedLog {
    cap: usize,
    buf: VecDeque<Completed>,
    /// Sequence number of `buf[0]`.
    start: u64,
    n_total: u64,
    gen_tokens: u64,
    prompt_tokens: u64,
    /// Completion counts per resolved method (served sessions only), in
    /// first-completion order.
    by_method: Vec<(String, u64)>,
    ttft: Reservoir,
    latency: Reservoir,
    queue_wait: Reservoir,
    /// Per-tenant reservoirs, first-seen order; entry [`TENANT_MAX`]+ fold
    /// into the [`TENANT_OVERFLOW`] bucket.
    by_tenant: Vec<TenantStat>,
}

impl Default for CompletedLog {
    fn default() -> Self {
        CompletedLog::with_capacity(COMPLETED_RING_DEFAULT)
    }
}

impl CompletedLog {
    pub fn with_capacity(cap: usize) -> CompletedLog {
        let cap = cap.max(1);
        CompletedLog {
            cap,
            buf: VecDeque::with_capacity(cap.min(1024)),
            start: 0,
            n_total: 0,
            gen_tokens: 0,
            prompt_tokens: 0,
            by_method: Vec::new(),
            ttft: Reservoir::new(RESERVOIR_SAMPLES),
            latency: Reservoir::new(RESERVOIR_SAMPLES),
            queue_wait: Reservoir::new(RESERVOIR_SAMPLES),
            by_tenant: Vec::new(),
        }
    }

    /// The tenant's stat entry, created on first sight; tenants beyond
    /// [`TENANT_MAX`] share the overflow bucket.
    fn tenant_entry(&mut self, tenant: u32) -> &mut TenantStat {
        let key = match self.by_tenant.iter().position(|t| t.tenant == tenant) {
            Some(i) => i,
            None if self.by_tenant.len() < TENANT_MAX => {
                self.by_tenant.push(TenantStat::new(tenant));
                self.by_tenant.len() - 1
            }
            None => match self.by_tenant.iter().position(|t| t.tenant == TENANT_OVERFLOW) {
                Some(i) => i,
                None => {
                    self.by_tenant.push(TenantStat::new(TENANT_OVERFLOW));
                    self.by_tenant.len() - 1
                }
            },
        };
        &mut self.by_tenant[key]
    }

    /// Record a completion: fold it into the streamed aggregates, retain
    /// the full record (evicting the oldest when at capacity), and return
    /// its sequence number.
    pub fn push(&mut self, c: Completed) -> u64 {
        self.n_total += 1;
        self.gen_tokens += c.tokens.len() as u64;
        self.prompt_tokens += c.prompt_len as u64;
        // rejected/cancelled-in-queue records never ran a method and carry
        // `ttft_ms: None` — excluded from latency stats and method counts,
        // exactly as the pre-ring percentile filters did
        if let Some(t) = c.ttft_ms {
            self.ttft.push(t);
            self.latency.push(c.total_ms);
            self.queue_wait.push(c.queue_ms);
            match self.by_method.iter_mut().find(|(m, _)| *m == c.method) {
                Some((_, n)) => *n += 1,
                None => self.by_method.push((c.method.clone(), 1)),
            }
            let ts = self.tenant_entry(c.tenant);
            ts.completed += 1;
            ts.ttft.push(t);
            ts.latency.push(c.total_ms);
            ts.queue_wait.push(c.queue_ms);
        } else {
            self.tenant_entry(c.tenant).unserved += 1;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.start += 1;
        }
        let seq = self.start + self.buf.len() as u64;
        self.buf.push_back(c);
        seq
    }

    /// The record at `seq`, if the ring still retains it.
    pub fn get(&self, seq: u64) -> Option<&Completed> {
        if seq < self.start {
            return None;
        }
        self.buf.get((seq - self.start) as usize)
    }

    /// Completions ever recorded. Deliberately NOT named `len`: the
    /// iterators yield only the RETAINED records
    /// ([`CompletedLog::retained`]), so a `len`-style name would invite
    /// `len() == iter().count()` assumptions that break past capacity.
    pub fn total(&self) -> usize {
        self.n_total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n_total == 0
    }

    /// Full records currently resident in the ring.
    pub fn retained(&self) -> usize {
        self.buf.len()
    }

    /// Iterate the retained records, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &Completed> {
        self.buf.iter()
    }

    /// The next sequence number to be assigned (= total ever pushed).
    pub fn end_seq(&self) -> u64 {
        self.start + self.buf.len() as u64
    }

    /// Clone the retained records with sequence ≥ `seq` (oldest first) —
    /// `Server::run`'s "what completed since I started" query.
    pub fn since(&self, seq: u64) -> Vec<Completed> {
        let skip = seq.saturating_sub(self.start) as usize;
        self.buf.iter().skip(skip).cloned().collect()
    }

    pub fn total_generated(&self) -> usize {
        self.gen_tokens as usize
    }

    pub fn total_prompt(&self) -> usize {
        self.prompt_tokens as usize
    }

    pub fn by_method(&self) -> Vec<(String, usize)> {
        self.by_method.iter().map(|(m, n)| (m.clone(), *n as usize)).collect()
    }

    /// Per-tenant SLO stats, first-seen order (overflow bucket last if it
    /// ever engaged).
    pub fn by_tenant(&self) -> &[TenantStat] {
        &self.by_tenant
    }

    /// Arbitrary-percentile access to the streamed global reservoirs
    /// (served sessions only) — the traffic harness reads p99s here.
    pub fn ttft_percentile(&self, p: f64) -> f64 {
        self.ttft.percentile(p)
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.percentile(p)
    }

    pub fn queue_wait_percentile(&self, p: f64) -> f64 {
        self.queue_wait.percentile(p)
    }

    /// Serialize the ring, the streamed totals, and every reservoir.
    pub fn write_snap<W: Write>(&self, w: &mut SnapWriter<W>) -> SnapResult<()> {
        w.usize(self.cap)?;
        w.u64(self.start)?;
        w.u64(self.n_total)?;
        w.u64(self.gen_tokens)?;
        w.u64(self.prompt_tokens)?;
        w.usize(self.buf.len())?;
        for c in &self.buf {
            write_completed(w, c)?;
        }
        w.usize(self.by_method.len())?;
        for (m, n) in &self.by_method {
            w.str(m)?;
            w.u64(*n)?;
        }
        self.ttft.write_snap(w)?;
        self.latency.write_snap(w)?;
        self.queue_wait.write_snap(w)?;
        w.usize(self.by_tenant.len())?;
        for ts in &self.by_tenant {
            ts.write_snap(w)?;
        }
        Ok(())
    }

    pub fn read_snap<R: Read>(&mut self, r: &mut SnapReader<R>) -> SnapResult<()> {
        self.cap = r.usize("completed-log cap")?.max(1);
        self.start = r.u64("completed-log start")?;
        self.n_total = r.u64("completed-log total")?;
        self.gen_tokens = r.u64("completed-log gen_tokens")?;
        self.prompt_tokens = r.u64("completed-log prompt_tokens")?;
        let n = r.usize("completed-log retained")?;
        if n > self.cap {
            return Err(corrupt(format!(
                "completed-log retains {n} records over cap {}",
                self.cap
            )));
        }
        self.buf.clear();
        for _ in 0..n {
            self.buf.push_back(read_completed(r)?);
        }
        let n_methods = r.usize("completed-log method count")?;
        self.by_method.clear();
        for _ in 0..n_methods {
            let m = r.str("completed-log method name")?;
            let count = r.u64("completed-log method count")?;
            self.by_method.push((m, count));
        }
        self.ttft.read_snap(r)?;
        self.latency.read_snap(r)?;
        self.queue_wait.read_snap(r)?;
        let n_tenants = r.usize("completed-log tenant count")?;
        self.by_tenant.clear();
        for _ in 0..n_tenants {
            self.by_tenant.push(TenantStat::read_snap(r)?);
        }
        Ok(())
    }
}

/// `for c in &metrics.completed` iterates the retained records, oldest
/// first — the Vec-era loop shape keeps working.
impl<'a> IntoIterator for &'a CompletedLog {
    type Item = &'a Completed;
    type IntoIter = std::collections::vec_deque::Iter<'a, Completed>;

    fn into_iter(self) -> Self::IntoIter {
        self.buf.iter()
    }
}

#[derive(Default)]
pub struct Metrics {
    /// Bounded ring + streamed aggregates — see [`CompletedLog`].
    pub completed: CompletedLog,
    pub t_start: Option<Instant>,
    pub t_end: Option<Instant>,
    pub decode_steps: u64,
    pub live_slot_steps: u64,
    pub slot_steps: u64,
    pub peak_mem_bytes: usize,
    pub max_concurrent: usize,
    /// Requests retired with `FinishReason::Rejected` — at submit (prompt
    /// exceeds every prefill bucket, unknown decode variant, footprint
    /// beyond the memory budget) or at admission (decode artifact failed
    /// to load).
    pub rejected: u64,
    /// Requests cancelled via `Server::cancel`.
    pub cancelled: u64,
    /// Admission attempts deferred because the memory budget was saturated
    /// (the request stays queued and retries next tick).
    pub admission_stalls: u64,
    /// Admissions the precision policy degraded below its top ladder rung
    /// because the pool could not cover the preferred variant's pages.
    pub policy_degradations: u64,
    // --- failure handling: faults, retries, deadlines, watchdog ----------
    /// Requests rejected at submit because the wait queue sat at
    /// `ServerConfig::max_queue` (bounded-queue backpressure).
    pub queue_rejections: u64,
    /// Failed prefill runs re-queued for a backoff retry.
    pub prefill_retries: u64,
    /// Retry ladders that stepped down to a cheaper admission rung after
    /// `MAX_PREFILL_ATTEMPTS` failures at one rung.
    pub retry_degradations: u64,
    /// Requests retired as `Error` after exhausting retries on the
    /// cheapest rung.
    pub retries_exhausted: u64,
    /// Requests that completed a clean prefill after at least one failed
    /// attempt — the retry ladder's success counter.
    pub fault_recoveries: u64,
    /// Live sessions retired as `Error` by a failed decode step (injected
    /// fault or real append error); the rest of the sub-batch proceeds.
    pub decode_errors: u64,
    /// "Can't happen" accounting bugs survived by retiring one request as
    /// `Error` instead of poisoning the tick.
    pub internal_errors: u64,
    /// Admitted work (in-flight prefill or live slot) retired at its tick
    /// deadline.
    pub deadline_exceeded: u64,
    /// Queued or backoff-waiting requests shed at their deadline before
    /// ever being admitted.
    pub deadline_shed: u64,
    /// Park-watchdog prefix-entry sheds (a slot starved
    /// `PARK_WATCHDOG_DEGRADE` consecutive ticks frees pinned pages).
    pub watchdog_degrades: u64,
    /// Park-watchdog forced session sheds (starved `PARK_WATCHDOG_SHED`
    /// consecutive ticks).
    pub watchdog_sheds: u64,
    /// Error retirements per tenant id (decode-step failures, exhausted
    /// retries).
    pub tenant_errors: Vec<(u32, u64)>,
    /// Deadline retirements per tenant id.
    pub tenant_deadlines: Vec<(u32, u64)>,
    /// Fault-injection draws per site, gauge sampled from the injector
    /// each tick (all zero when no fault plan is installed). Indexed by
    /// `FaultSite::index()`.
    pub faults_drawn: [u64; N_FAULT_SITES],
    /// Injected failures per site (same indexing as `faults_drawn`).
    pub faults_injected: [u64; N_FAULT_SITES],
    // --- crash recovery: snapshot/restore/scrub counters ------------------
    /// Successful `Server::snapshot` calls (torn writes don't count).
    pub snapshots: u64,
    /// Completed `Server::restore` loads — carried across the restore, so
    /// a twice-restored server reports 2.
    pub restores: u64,
    /// KV pages quarantined by a checksum mismatch (restore verification or
    /// a live scrub) over the server's whole lineage.
    pub pages_quarantined: u64,
    /// Requests retired as `Error` because a restore found their pages
    /// corrupt (shared prefix pages degrade to index-entry sheds instead
    /// and are counted under `prefix_evictions`/`prefix_collisions`).
    pub restore_retired: u64,
    /// Park events per tenant id (fairness: who absorbs pool pressure).
    pub tenant_parks: Vec<(u32, u64)>,
    /// Deadlock preemptions per tenant id (who gets force-finished).
    pub tenant_preemptions: Vec<(u32, u64)>,
    // --- paged KV pool gauges (sampled from KvPool each tick) ------------
    /// Pages currently leased across all live requests.
    pub pool_pages_leased: usize,
    /// Pool capacity in pages (0 when no shared pool is installed).
    pub pool_pages_total: usize,
    /// Most pages ever simultaneously leased.
    pub pool_high_water: usize,
    /// Lease requests (or flush pre-checks) the pool could not satisfy.
    pub pool_lease_failures: u64,
    /// Decode slots parked because their due flush could not lease pages.
    pub pool_parks: u64,
    /// In-flight chunked prefills that sat a tick out because the pool
    /// could not cover their remaining page claim (they resume when
    /// decode frees pages — never Rejected for pool contention).
    pub prefill_parks: u64,
    /// Parked slots that resumed decoding after pages freed up.
    pub pool_resumes: u64,
    /// Parked sessions force-finished (CacheFull) to break a pool deadlock
    /// where every live slot was parked and nothing could ever free pages.
    pub pool_preemptions: u64,
    // --- cross-request prefix sharing gauges (from the radix tree) -------
    /// Prompts served from a full prefix-tree hit (entire prefill skipped).
    pub prefix_hits: u64,
    /// Prompts served frozen-plan from a partial (interior-node) hit —
    /// only the divergent tail ran a prefill.
    pub prefix_partial_hits: u64,
    /// Prompts that ran a full prefill (and then registered their pages).
    pub prefix_misses: u64,
    /// Full prompt tails currently registered in the tree.
    pub prefix_entries: usize,
    /// Interior radix nodes currently resident (each spans one
    /// quantization group of prompt tokens).
    pub prefix_nodes: usize,
    /// Pool pages currently pinned by tree nodes (each counted once —
    /// that single charge IS the dedup).
    pub prefix_pages_pinned: usize,
    /// Deployment bytes consumers adopted instead of leasing privately,
    /// cumulative over all hits.
    pub prefix_bytes_deduped: u64,
    /// Prefix entries shed (LRU cap at registration, or pool pressure).
    pub prefix_evictions: u64,
    /// Chain-key collisions caught by the prompt-token verify (answered as
    /// misses, never served — nonzero values are expected to be vanishingly
    /// rare and worth investigating).
    pub prefix_collisions: u64,
    /// Partial hits refused because the producer's frozen plan was not
    /// adoptable under the consumer's method (served as misses).
    pub prefix_plan_conflicts: u64,
    /// Off-pool bytes held by entry sidecars (residual snapshots, logits,
    /// plans) — the bounded retention overhead of full prefill skipping.
    pub prefix_sidecar_bytes: usize,
}

impl Metrics {
    pub fn start(&mut self) {
        self.t_start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.t_end = Some(Instant::now());
    }

    pub fn record_step(&mut self, live: usize, batch: usize) {
        self.decode_steps += 1;
        self.live_slot_steps += live as u64;
        self.slot_steps += batch as u64;
        self.max_concurrent = self.max_concurrent.max(live);
    }

    pub fn wall_s(&self) -> f64 {
        match (self.t_start, self.t_end) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn total_generated(&self) -> usize {
        self.completed.total_generated()
    }

    pub fn total_prompt(&self) -> usize {
        self.completed.total_prompt()
    }

    /// Generated tokens per second (the Fig. 5 throughput metric).
    pub fn throughput_tps(&self) -> f64 {
        let w = self.wall_s();
        if w == 0.0 {
            0.0
        } else {
            self.total_generated() as f64 / w
        }
    }

    pub fn batch_occupancy(&self) -> f64 {
        if self.slot_steps == 0 {
            0.0
        } else {
            self.live_slot_steps as f64 / self.slot_steps as f64
        }
    }

    /// TTFT p50/p95 over sessions that actually produced a first token —
    /// rejected/cancelled-in-queue records carry `ttft_ms: None` and are
    /// excluded rather than dragging the percentiles toward zero. Streamed:
    /// exact up to the reservoir size, a uniform sample beyond it.
    pub fn ttft_ms(&self) -> (f64, f64) {
        (self.completed.ttft.percentile(50.0), self.completed.ttft.percentile(95.0))
    }

    /// End-to-end latency p50/p95 over served sessions (same exclusion rule
    /// as [`Metrics::ttft_ms`]: only sessions that produced tokens count).
    pub fn latency_ms(&self) -> (f64, f64) {
        (
            self.completed.latency.percentile(50.0),
            self.completed.latency.percentile(95.0),
        )
    }

    /// Completion counts per resolved method name, in first-completion
    /// order — the per-tenant routing receipt for mixed-precision serving.
    /// Rejected/cancelled-in-queue records never ran a method (placeholder
    /// "-", `ttft_ms: None`) and are excluded. Streamed — counts survive
    /// ring eviction.
    pub fn completed_by_method(&self) -> Vec<(String, usize)> {
        self.completed.by_method()
    }

    /// Queue-wait (submit → admission) p50/p95 over served sessions.
    pub fn queue_wait_ms(&self) -> (f64, f64) {
        (
            self.completed.queue_wait.percentile(50.0),
            self.completed.queue_wait.percentile(95.0),
        )
    }

    /// Per-tenant SLO stats (streamed; see [`CompletedLog::by_tenant`]).
    pub fn tenants(&self) -> &[TenantStat] {
        self.completed.by_tenant()
    }

    /// Count a park event against `tenant` (fairness accounting).
    pub fn note_tenant_park(&mut self, tenant: u32) {
        bump(&mut self.tenant_parks, tenant);
    }

    /// Count a deadlock preemption against `tenant`.
    pub fn note_tenant_preempt(&mut self, tenant: u32) {
        bump(&mut self.tenant_preemptions, tenant);
    }

    /// Count an error retirement (decode failure, exhausted retries)
    /// against `tenant`.
    pub fn note_tenant_error(&mut self, tenant: u32) {
        bump(&mut self.tenant_errors, tenant);
    }

    /// Count a deadline retirement against `tenant`.
    pub fn note_tenant_deadline(&mut self, tenant: u32) {
        bump(&mut self.tenant_deadlines, tenant);
    }

    /// Record the fault injector's cumulative per-site counters (called
    /// once per scheduling tick when a fault plan is installed).
    pub fn observe_faults(&mut self, stats: &crate::util::faults::FaultStats) {
        self.faults_drawn = stats.drawn;
        self.faults_injected = stats.injected;
    }

    /// Record the current pool counters (called once per scheduling tick).
    pub fn observe_pool(&mut self, stats: &crate::kvcache::pool::PoolStats) {
        self.pool_pages_leased = stats.leased;
        self.pool_pages_total = stats.max_pages.unwrap_or(0);
        self.pool_high_water = stats.high_water;
        self.pool_lease_failures = stats.lease_failures;
    }

    /// Record the radix prefix-tree counters (called once per scheduling
    /// tick when cross-request sharing is enabled).
    pub fn observe_prefix(&mut self, stats: &crate::kvcache::radix::PrefixStats) {
        self.prefix_hits = stats.hits;
        self.prefix_partial_hits = stats.partial_hits;
        self.prefix_misses = stats.misses;
        self.prefix_entries = stats.entries;
        self.prefix_nodes = stats.nodes;
        self.prefix_pages_pinned = stats.pages_pinned;
        self.prefix_bytes_deduped = stats.bytes_deduped;
        self.prefix_evictions = stats.evictions;
        self.prefix_collisions = stats.collisions;
        self.prefix_plan_conflicts = stats.plan_conflicts;
        self.prefix_sidecar_bytes = stats.sidecar_bytes;
    }

    /// Serialize every counter, gauge, and reservoir. The wall-clock
    /// anchors (`t_start`/`t_end`) are deliberately NOT snapshotted — a
    /// restored server re-stamps them, so wall-time-derived readouts
    /// (throughput, percentile milliseconds) measure the new process while
    /// the deterministic counters continue the old one's series.
    pub fn write_snap<W: Write>(&self, w: &mut SnapWriter<W>) -> SnapResult<()> {
        self.completed.write_snap(w)?;
        for v in [self.decode_steps, self.live_slot_steps, self.slot_steps] {
            w.u64(v)?;
        }
        w.usize(self.peak_mem_bytes)?;
        w.usize(self.max_concurrent)?;
        for v in [
            self.rejected,
            self.cancelled,
            self.admission_stalls,
            self.policy_degradations,
            self.queue_rejections,
            self.prefill_retries,
            self.retry_degradations,
            self.retries_exhausted,
            self.fault_recoveries,
            self.decode_errors,
            self.internal_errors,
            self.deadline_exceeded,
            self.deadline_shed,
            self.watchdog_degrades,
            self.watchdog_sheds,
        ] {
            w.u64(v)?;
        }
        for counts in [
            &self.tenant_errors,
            &self.tenant_deadlines,
            &self.tenant_parks,
            &self.tenant_preemptions,
        ] {
            w.usize(counts.len())?;
            for (t, n) in counts.iter() {
                w.u32(*t)?;
                w.u64(*n)?;
            }
        }
        w.slice_u64(&self.faults_drawn)?;
        w.slice_u64(&self.faults_injected)?;
        for v in [self.snapshots, self.restores, self.pages_quarantined, self.restore_retired] {
            w.u64(v)?;
        }
        w.usize(self.pool_pages_leased)?;
        w.usize(self.pool_pages_total)?;
        w.usize(self.pool_high_water)?;
        w.u64(self.pool_lease_failures)?;
        for v in [self.pool_parks, self.prefill_parks, self.pool_resumes, self.pool_preemptions] {
            w.u64(v)?;
        }
        w.u64(self.prefix_hits)?;
        w.u64(self.prefix_partial_hits)?;
        w.u64(self.prefix_misses)?;
        w.usize(self.prefix_entries)?;
        w.usize(self.prefix_nodes)?;
        w.usize(self.prefix_pages_pinned)?;
        w.u64(self.prefix_bytes_deduped)?;
        w.u64(self.prefix_evictions)?;
        w.u64(self.prefix_collisions)?;
        w.u64(self.prefix_plan_conflicts)?;
        w.usize(self.prefix_sidecar_bytes)
    }

    pub fn read_snap<R: Read>(&mut self, r: &mut SnapReader<R>) -> SnapResult<()> {
        self.completed.read_snap(r)?;
        self.decode_steps = r.u64("metrics decode_steps")?;
        self.live_slot_steps = r.u64("metrics live_slot_steps")?;
        self.slot_steps = r.u64("metrics slot_steps")?;
        self.peak_mem_bytes = r.usize("metrics peak_mem_bytes")?;
        self.max_concurrent = r.usize("metrics max_concurrent")?;
        for v in [
            &mut self.rejected,
            &mut self.cancelled,
            &mut self.admission_stalls,
            &mut self.policy_degradations,
            &mut self.queue_rejections,
            &mut self.prefill_retries,
            &mut self.retry_degradations,
            &mut self.retries_exhausted,
            &mut self.fault_recoveries,
            &mut self.decode_errors,
            &mut self.internal_errors,
            &mut self.deadline_exceeded,
            &mut self.deadline_shed,
            &mut self.watchdog_degrades,
            &mut self.watchdog_sheds,
        ] {
            *v = r.u64("metrics counter")?;
        }
        for counts in [
            &mut self.tenant_errors,
            &mut self.tenant_deadlines,
            &mut self.tenant_parks,
            &mut self.tenant_preemptions,
        ] {
            let n = r.usize("metrics tenant-count len")?;
            counts.clear();
            for _ in 0..n {
                let t = r.u32("metrics tenant id")?;
                let c = r.u64("metrics tenant count")?;
                counts.push((t, c));
            }
        }
        for arr in [&mut self.faults_drawn, &mut self.faults_injected] {
            let v = r.vec_u64("metrics fault counters")?;
            if v.len() != N_FAULT_SITES {
                return Err(corrupt(format!(
                    "fault counter array has {} sites (this build has {N_FAULT_SITES})",
                    v.len()
                )));
            }
            arr.copy_from_slice(&v);
        }
        for v in [
            &mut self.snapshots,
            &mut self.restores,
            &mut self.pages_quarantined,
            &mut self.restore_retired,
        ] {
            *v = r.u64("metrics recovery counter")?;
        }
        self.pool_pages_leased = r.usize("metrics pool leased")?;
        self.pool_pages_total = r.usize("metrics pool total")?;
        self.pool_high_water = r.usize("metrics pool high_water")?;
        self.pool_lease_failures = r.u64("metrics pool lease_failures")?;
        for v in [
            &mut self.pool_parks,
            &mut self.prefill_parks,
            &mut self.pool_resumes,
            &mut self.pool_preemptions,
        ] {
            *v = r.u64("metrics pool counter")?;
        }
        self.prefix_hits = r.u64("metrics prefix hits")?;
        self.prefix_partial_hits = r.u64("metrics prefix partial hits")?;
        self.prefix_misses = r.u64("metrics prefix misses")?;
        self.prefix_entries = r.usize("metrics prefix entries")?;
        self.prefix_nodes = r.usize("metrics prefix nodes")?;
        self.prefix_pages_pinned = r.usize("metrics prefix pinned")?;
        self.prefix_bytes_deduped = r.u64("metrics prefix deduped")?;
        self.prefix_evictions = r.u64("metrics prefix evictions")?;
        self.prefix_collisions = r.u64("metrics prefix collisions")?;
        self.prefix_plan_conflicts = r.u64("metrics prefix plan conflicts")?;
        self.prefix_sidecar_bytes = r.usize("metrics prefix sidecar")?;
        Ok(())
    }

    pub fn summary(&self) -> String {
        let (ttft50, ttft95) = self.ttft_ms();
        let (lat50, lat95) = self.latency_ms();
        let (qw50, qw95) = self.queue_wait_ms();
        let mut out = format!(
            "requests={} gen_tokens={} wall={:.2}s throughput={:.1} tok/s \
             occupancy={:.2} max_concurrent={} peak_kv_mem={:.2} MB \
             ttft p50/p95={:.0}/{:.0} ms latency p50/p95={:.0}/{:.0} ms \
             queue p50/p95={:.0}/{:.0} ms rejected={} cancelled={} stalls={} \
             pool pages={}/{} high_water={} lease_fail={} parks={} resumes={} preempt={} \
             prefill_parks={} \
             prefix hits={} partial={} misses={} entries={} nodes={} pinned={} \
             deduped={:.2}MB shed={}",
            self.completed.total(),
            self.total_generated(),
            self.wall_s(),
            self.throughput_tps(),
            self.batch_occupancy(),
            self.max_concurrent,
            self.peak_mem_bytes as f64 / 1e6,
            ttft50,
            ttft95,
            lat50,
            lat95,
            qw50,
            qw95,
            self.rejected,
            self.cancelled,
            self.admission_stalls,
            self.pool_pages_leased,
            self.pool_pages_total,
            self.pool_high_water,
            self.pool_lease_failures,
            self.pool_parks,
            self.pool_resumes,
            self.pool_preemptions,
            self.prefill_parks,
            self.prefix_hits,
            self.prefix_partial_hits,
            self.prefix_misses,
            self.prefix_entries,
            self.prefix_nodes,
            self.prefix_pages_pinned,
            self.prefix_bytes_deduped as f64 / 1e6,
            self.prefix_evictions,
        );
        if self.policy_degradations > 0 {
            out.push_str(&format!(" policy_degradations={}", self.policy_degradations));
        }
        let faults_total: u64 = self.faults_injected.iter().sum();
        let failures_seen = faults_total > 0
            || self.queue_rejections > 0
            || self.prefill_retries > 0
            || self.retry_degradations > 0
            || self.retries_exhausted > 0
            || self.fault_recoveries > 0
            || self.decode_errors > 0
            || self.internal_errors > 0
            || self.deadline_exceeded > 0
            || self.deadline_shed > 0
            || self.watchdog_degrades > 0
            || self.watchdog_sheds > 0;
        if failures_seen {
            out.push_str(&format!(
                "\n  failures: faults_injected={faults_total} \
                 (lease={} prefill={} decode={} prefix={} snapwrite={} snapcorrupt={}) \
                 prefill_retries={} retry_degradations={} exhausted={} \
                 recovered={} decode_errors={} internal={} \
                 deadline_exceeded={} deadline_shed={} queue_rejects={} \
                 watchdog degrade/shed={}/{}",
                self.faults_injected[0],
                self.faults_injected[1],
                self.faults_injected[2],
                self.faults_injected[3],
                self.faults_injected[4],
                self.faults_injected[5],
                self.prefill_retries,
                self.retry_degradations,
                self.retries_exhausted,
                self.fault_recoveries,
                self.decode_errors,
                self.internal_errors,
                self.deadline_exceeded,
                self.deadline_shed,
                self.queue_rejections,
                self.watchdog_degrades,
                self.watchdog_sheds,
            ));
        }
        if self.snapshots > 0
            || self.restores > 0
            || self.pages_quarantined > 0
            || self.restore_retired > 0
        {
            out.push_str(&format!(
                "\n  recovery: snapshots={} restores={} pages_quarantined={} \
                 restore_retired={}",
                self.snapshots, self.restores, self.pages_quarantined, self.restore_retired,
            ));
        }
        for t in self.tenants() {
            let name = if t.tenant == TENANT_OVERFLOW {
                "overflow".to_string()
            } else {
                t.tenant.to_string()
            };
            let parks = count_for(&self.tenant_parks, t.tenant);
            let preempts = count_for(&self.tenant_preemptions, t.tenant);
            let errors = count_for(&self.tenant_errors, t.tenant);
            let deadlines = count_for(&self.tenant_deadlines, t.tenant);
            out.push_str(&format!(
                "\n  tenant {name}: served={} unserved={} \
                 ttft p50/p99={:.0}/{:.0} ms latency p50/p99={:.0}/{:.0} ms \
                 queue p50/p99={:.0}/{:.0} ms parks={parks} preempt={preempts} \
                 errors={errors} deadlines={deadlines}",
                t.completed,
                t.unserved,
                t.ttft.percentile(50.0),
                t.ttft.percentile(99.0),
                t.latency.percentile(50.0),
                t.latency.percentile(99.0),
                t.queue_wait.percentile(50.0),
                t.queue_wait.percentile(99.0),
            ));
        }
        out
    }
}

fn bump(counts: &mut Vec<(u32, u64)>, tenant: u32) {
    match counts.iter_mut().find(|(t, _)| *t == tenant) {
        Some((_, n)) => *n += 1,
        None => counts.push((tenant, 1)),
    }
}

/// The count recorded for `tenant` in a `(tenant, count)` list (0 if none).
pub fn count_for(counts: &[(u32, u64)], tenant: u32) -> u64 {
    counts.iter().find(|(t, _)| *t == tenant).map_or(0, |(_, n)| *n)
}

/// Table 7-style breakdown from engine timers: share of per-step wall time
/// in channel-selection/quantization vs model execution vs host assembly,
/// plus the decode-arg scratch-pool savings (steps that reused pooled
/// buffers instead of allocating, and the bytes the pool amortizes).
pub struct Breakdown {
    pub quantize_pct: f64,
    pub model_exec_pct: f64,
    pub assemble_pct: f64,
    pub quantize_call_rate_pct: f64,
    /// Share of decode steps served from the pooled per-variant arg
    /// buffers (steady state: ~100%, one build per variant per process).
    pub assemble_reuse_pct: f64,
    /// Total heap bytes currently pooled across all variants; a reused
    /// step saves re-allocating its own variant's share of this.
    pub scratch_bytes_pooled: u64,
    /// Chunked-prefill (layer, chunk) units processed — the admission
    /// scheduler's per-tick unit of prefill work.
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled per second of prefill wall time (the
    /// blocked-chunked pipeline's throughput; 0 when no prefill ran).
    pub prefill_tok_s: f64,
    /// Worker-pool lanes the engine sharded ticks across (1 = the
    /// single-threaded path; the remaining fields are then trivial).
    pub workers: usize,
    /// Effective parallel speedup: total worker-busy time over the
    /// critical-path (busiest worker) time. 1.0 when single-threaded or
    /// idle; approaches `workers` under perfect load balance.
    pub parallel_speedup: f64,
    /// Dispatch imbalance: (busiest − idlest) busy time as a share of the
    /// busiest, in percent. 0 = perfectly balanced shards.
    pub dispatch_imbalance_pct: f64,
    /// Ticks that actually fanned work out across the pool (multi-slot
    /// decode or abundant chunked prefill).
    pub parallel_ticks: u64,
}

pub fn breakdown(t: &EngineTimers) -> Breakdown {
    let total = (t.decode_exec_ns + t.quantize_ns + t.assemble_ns).max(1) as f64;
    let assemblies = t.assemble_reuses + t.assemble_builds;
    Breakdown {
        quantize_pct: 100.0 * t.quantize_ns as f64 / total,
        model_exec_pct: 100.0 * t.decode_exec_ns as f64 / total,
        assemble_pct: 100.0 * t.assemble_ns as f64 / total,
        quantize_call_rate_pct: if t.decode_steps == 0 {
            0.0
        } else {
            100.0 * t.quantize_events as f64 / t.decode_steps as f64
        },
        assemble_reuse_pct: if assemblies == 0 {
            0.0
        } else {
            100.0 * t.assemble_reuses as f64 / assemblies as f64
        },
        scratch_bytes_pooled: t.scratch_bytes,
        prefill_chunks: t.prefill_chunks,
        prefill_tok_s: if t.prefill_exec_ns == 0 {
            0.0
        } else {
            t.prefill_tokens as f64 / (t.prefill_exec_ns as f64 * 1e-9)
        },
        workers: t.worker_busy_ns.len().max(1),
        parallel_speedup: t.parallel_speedup(),
        dispatch_imbalance_pct: 100.0 * t.dispatch_imbalance(),
        parallel_ticks: t.parallel_ticks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::FinishReason;

    fn completed(n: usize) -> Completed {
        Completed {
            id: n as u64,
            prompt_len: 10,
            tokens: vec![1; n],
            reason: FinishReason::Eos,
            method: "bf16".into(),
            tenant: 0,
            ttft_ms: Some(5.0 * n as f64),
            queue_ms: 1.0 * n as f64,
            total_ms: 20.0 * n as f64,
        }
    }

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.start();
        m.completed.push(completed(4));
        m.completed.push(completed(6));
        m.record_step(2, 8);
        m.record_step(1, 8);
        m.stop();
        assert_eq!(m.total_generated(), 10);
        assert!((m.batch_occupancy() - 3.0 / 16.0).abs() < 1e-9);
        assert!(m.throughput_tps() > 0.0);
        assert_eq!(m.max_concurrent, 2);
        assert_eq!((m.rejected, m.cancelled, m.admission_stalls), (0, 0, 0));
    }

    #[test]
    fn percentiles_exclude_tokenless_sessions() {
        let mut m = Metrics::default();
        m.completed.push(completed(4)); // ttft 20ms, total 80ms, queue 4ms
        m.completed.push(completed(4));
        // a request cancelled while queued: no first token — must not drag
        // the percentiles to zero
        m.completed.push(Completed {
            id: 99,
            prompt_len: 10,
            tokens: vec![],
            reason: FinishReason::Cancelled,
            method: "-".into(),
            tenant: 0,
            ttft_ms: None,
            queue_ms: 0.0,
            total_ms: 0.0,
        });
        let (ttft50, _) = m.ttft_ms();
        let (lat50, _) = m.latency_ms();
        let (qw50, _) = m.queue_wait_ms();
        assert!((ttft50 - 20.0).abs() < 1e-9, "ttft p50 {ttft50}");
        assert!((lat50 - 80.0).abs() < 1e-9);
        assert!((qw50 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ring_bounds_retained_records_but_streams_totals() {
        let mut m = Metrics { completed: CompletedLog::with_capacity(3), ..Metrics::default() };
        let mut seqs = Vec::new();
        for i in 0..5 {
            seqs.push(m.completed.push(completed(i + 1)));
        }
        // totals/percentiles cover all 5; only the last 3 full records stay
        assert_eq!(m.completed.total(), 5);
        assert_eq!(m.completed.retained(), 3);
        assert_eq!(m.total_generated(), 1 + 2 + 3 + 4 + 5);
        assert_eq!(m.total_prompt(), 5 * 10);
        assert_eq!(m.completed_by_method(), vec![("bf16".to_string(), 5)]);
        // evicted seqs answer None, retained ones round-trip
        assert!(m.completed.get(seqs[0]).is_none());
        assert!(m.completed.get(seqs[1]).is_none());
        assert_eq!(m.completed.get(seqs[4]).unwrap().tokens.len(), 5);
        assert_eq!(m.completed.iter().count(), 3);
        assert_eq!(m.completed.end_seq(), 5);
        assert_eq!(m.completed.since(seqs[3]).len(), 2);
        // percentiles stream over everything ever (exact under the
        // reservoir size): ttft values were 5,10,..,25 → p50 = 15
        let (p50, _) = m.ttft_ms();
        assert!((p50 - 15.0).abs() < 1e-9, "{p50}");
    }

    #[test]
    fn reservoir_is_exact_under_cap_and_bounded_over_it() {
        let mut r = Reservoir::new(8);
        for i in 0..8 {
            r.push(i as f64);
        }
        assert!((r.percentile(50.0) - 3.5).abs() < 1e-9);
        for i in 8..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 10_000);
        // sample stays bounded and within the observed range
        assert!(r.percentile(0.0) >= 0.0 && r.percentile(100.0) < 10_000.0);
    }

    #[test]
    fn tenant_reservoirs_keyed_and_capped() {
        let mut m = Metrics::default();
        // two tenants with distinct latency profiles
        for i in 0..4 {
            m.completed.push(Completed { tenant: 1, ..completed(i + 1) });
            m.completed.push(Completed {
                tenant: 2,
                ttft_ms: Some(100.0),
                total_ms: 400.0,
                ..completed(i + 1)
            });
        }
        // tenant 2 also loses one request in queue
        m.completed.push(Completed {
            tenant: 2,
            ttft_ms: None,
            tokens: vec![],
            reason: FinishReason::Rejected,
            method: "-".into(),
            ..completed(1)
        });
        let ts = m.tenants();
        assert_eq!(ts.len(), 2);
        let t1 = ts.iter().find(|t| t.tenant == 1).unwrap();
        let t2 = ts.iter().find(|t| t.tenant == 2).unwrap();
        assert_eq!((t1.completed, t1.unserved), (4, 0));
        assert_eq!((t2.completed, t2.unserved), (4, 1));
        // reservoirs are per-tenant: tenant 2's ttft is constant 100
        assert!((t2.ttft.percentile(50.0) - 100.0).abs() < 1e-9);
        assert!(t1.ttft.percentile(99.0) < 100.0);
        // summary renders a line per tenant
        let s = m.summary();
        assert!(s.contains("tenant 1:"), "{s}");
        assert!(s.contains("tenant 2:"), "{s}");
    }

    #[test]
    fn tenant_overflow_bucket_bounds_memory() {
        let mut m = Metrics::default();
        for t in 0..(TENANT_MAX as u32 + 10) {
            m.completed.push(Completed { tenant: t, ..completed(1) });
        }
        let ts = m.tenants();
        // TENANT_MAX distinct entries + one overflow bucket
        assert_eq!(ts.len(), TENANT_MAX + 1);
        let ov = ts.iter().find(|t| t.tenant == TENANT_OVERFLOW).unwrap();
        assert_eq!(ov.completed, 10);
        // overflow keeps folding, never grows new entries
        m.completed.push(Completed { tenant: 9999, ..completed(1) });
        assert_eq!(m.tenants().len(), TENANT_MAX + 1);
    }

    #[test]
    fn tenant_fairness_counters() {
        let mut m = Metrics::default();
        m.note_tenant_park(3);
        m.note_tenant_park(3);
        m.note_tenant_preempt(4);
        assert_eq!(count_for(&m.tenant_parks, 3), 2);
        assert_eq!(count_for(&m.tenant_parks, 4), 0);
        assert_eq!(count_for(&m.tenant_preemptions, 4), 1);
    }

    #[test]
    fn failure_counters_render_only_when_engaged() {
        let mut m = Metrics::default();
        // a clean run keeps the summary free of the failures line
        assert!(!m.summary().contains("failures:"), "{}", m.summary());
        m.prefill_retries = 3;
        m.retry_degradations = 1;
        m.fault_recoveries = 2;
        m.decode_errors = 1;
        m.deadline_shed = 4;
        m.queue_rejections = 2;
        m.faults_injected = [5, 3, 1, 0, 0, 0];
        m.faults_drawn = [50, 30, 10, 0, 0, 0];
        m.note_tenant_error(7);
        m.note_tenant_deadline(7);
        m.note_tenant_deadline(7);
        m.completed.push(Completed { tenant: 7, ..completed(1) });
        let s = m.summary();
        assert!(s.contains("failures: faults_injected=9"), "{s}");
        assert!(s.contains("prefill_retries=3"), "{s}");
        assert!(s.contains("deadline_shed=4"), "{s}");
        assert!(s.contains("errors=1 deadlines=2"), "{s}");
        assert_eq!(count_for(&m.tenant_errors, 7), 1);
        assert_eq!(count_for(&m.tenant_deadlines, 7), 2);
    }

    #[test]
    fn observe_faults_copies_per_site_counters() {
        let mut m = Metrics::default();
        let stats = crate::util::faults::FaultStats {
            drawn: [10, 20, 30, 40, 50, 60],
            injected: [1, 2, 3, 4, 5, 6],
        };
        m.observe_faults(&stats);
        assert_eq!(m.faults_drawn, [10, 20, 30, 40, 50, 60]);
        assert_eq!(m.faults_injected, [1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn metrics_snapshot_round_trips_counters_and_reservoir_state() {
        use crate::util::snapshot::{SnapReader, SnapWriter};
        let mut m = Metrics { completed: CompletedLog::with_capacity(3), ..Metrics::default() };
        for i in 0..5 {
            m.completed.push(Completed { tenant: (i % 2) as u32, ..completed(i + 1) });
        }
        m.completed.push(Completed {
            tenant: 1,
            ttft_ms: None,
            tokens: vec![],
            reason: FinishReason::Rejected,
            method: "-".into(),
            ..completed(1)
        });
        m.record_step(2, 8);
        m.prefill_retries = 3;
        m.deadline_shed = 4;
        m.faults_injected = [5, 3, 1, 0, 2, 1];
        m.snapshots = 2;
        m.restores = 1;
        m.pages_quarantined = 7;
        m.restore_retired = 1;
        m.note_tenant_park(1);
        m.pool_high_water = 42;
        m.prefix_hits = 9;

        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        m.write_snap(&mut w).unwrap();
        w.finish().unwrap();

        let mut m2 = Metrics::default();
        let mut r = SnapReader::new(&buf[..]).unwrap();
        m2.read_snap(&mut r).unwrap();
        r.finish().unwrap();

        assert_eq!(m2.completed.total(), m.completed.total());
        assert_eq!(m2.completed.retained(), m.completed.retained());
        assert_eq!(m2.completed.end_seq(), m.completed.end_seq());
        assert_eq!(m2.completed_by_method(), m.completed_by_method());
        assert_eq!(m2.ttft_ms(), m.ttft_ms());
        assert_eq!(m2.faults_injected, m.faults_injected);
        assert_eq!(
            (m2.snapshots, m2.restores, m2.pages_quarantined, m2.restore_retired),
            (2, 1, 7, 1)
        );
        assert_eq!(count_for(&m2.tenant_parks, 1), 1);
        assert_eq!(m2.pool_high_water, 42);
        assert_eq!(m2.prefix_hits, 9);
        // reservoir RNG state carried over: identical future pushes make
        // identical keep/replace decisions
        let (t1, t2) = {
            let mut a = m;
            let mut b = m2;
            for i in 0..2000 {
                a.completed.push(completed(i + 1));
                b.completed.push(completed(i + 1));
            }
            (a.ttft_ms(), b.ttft_ms())
        };
        assert_eq!(t1, t2);
    }

    #[test]
    fn breakdown_sums_to_100() {
        let t = EngineTimers {
            decode_exec_ns: 700,
            quantize_ns: 100,
            assemble_ns: 200,
            decode_steps: 10,
            quantize_events: 1,
            assemble_reuses: 9,
            assemble_builds: 1,
            scratch_bytes: 4096,
            ..Default::default()
        };
        let b = breakdown(&t);
        assert!((b.quantize_pct + b.model_exec_pct + b.assemble_pct - 100.0).abs() < 1e-6);
        assert!((b.quantize_call_rate_pct - 10.0).abs() < 1e-9);
        assert!((b.assemble_reuse_pct - 90.0).abs() < 1e-9);
        assert_eq!(b.scratch_bytes_pooled, 4096);
        // no worker pool installed: the parallel gauges are trivial
        assert_eq!(b.workers, 1);
        assert!((b.parallel_speedup - 1.0).abs() < 1e-9);
        assert!((b.dispatch_imbalance_pct - 0.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_reports_parallel_speedup_and_imbalance() {
        let t = EngineTimers {
            worker_busy_ns: vec![100, 80, 60, 60],
            worker_jobs: vec![4, 4, 3, 3],
            parallel_ticks: 7,
            ..Default::default()
        };
        let b = breakdown(&t);
        assert_eq!(b.workers, 4);
        // 300 ns of busy work, 100 ns critical path -> 3x effective
        assert!((b.parallel_speedup - 3.0).abs() < 1e-9, "{}", b.parallel_speedup);
        // busiest 100, idlest 60 -> 40% imbalance
        assert!(
            (b.dispatch_imbalance_pct - 40.0).abs() < 1e-9,
            "{}",
            b.dispatch_imbalance_pct
        );
        assert_eq!(b.parallel_ticks, 7);
    }
}
