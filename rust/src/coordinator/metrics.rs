//! Serving metrics: latency/throughput aggregates (Fig. 5) and the
//! operation-level time breakdown (Table 7).

use std::time::Instant;

use crate::util::stats::percentile;

use super::engine::EngineTimers;
use super::session::Completed;

#[derive(Default)]
pub struct Metrics {
    pub completed: Vec<Completed>,
    pub t_start: Option<Instant>,
    pub t_end: Option<Instant>,
    pub decode_steps: u64,
    pub live_slot_steps: u64,
    pub slot_steps: u64,
    pub peak_mem_bytes: usize,
    pub max_concurrent: usize,
    /// Requests retired with `FinishReason::Rejected` — at submit (prompt
    /// exceeds every prefill bucket, unknown decode variant, footprint
    /// beyond the memory budget) or at admission (decode artifact failed
    /// to load).
    pub rejected: u64,
    /// Requests cancelled via `Server::cancel`.
    pub cancelled: u64,
    /// Admission attempts deferred because the memory budget was saturated
    /// (the request stays queued and retries next tick).
    pub admission_stalls: u64,
    // --- paged KV pool gauges (sampled from KvPool each tick) ------------
    /// Pages currently leased across all live requests.
    pub pool_pages_leased: usize,
    /// Pool capacity in pages (0 when no shared pool is installed).
    pub pool_pages_total: usize,
    /// Most pages ever simultaneously leased.
    pub pool_high_water: usize,
    /// Lease requests (or flush pre-checks) the pool could not satisfy.
    pub pool_lease_failures: u64,
    /// Decode slots parked because their due flush could not lease pages.
    pub pool_parks: u64,
    /// Parked slots that resumed decoding after pages freed up.
    pub pool_resumes: u64,
    /// Parked sessions force-finished (CacheFull) to break a pool deadlock
    /// where every live slot was parked and nothing could ever free pages.
    pub pool_preemptions: u64,
}

impl Metrics {
    pub fn start(&mut self) {
        self.t_start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        self.t_end = Some(Instant::now());
    }

    pub fn record_step(&mut self, live: usize, batch: usize) {
        self.decode_steps += 1;
        self.live_slot_steps += live as u64;
        self.slot_steps += batch as u64;
        self.max_concurrent = self.max_concurrent.max(live);
    }

    pub fn wall_s(&self) -> f64 {
        match (self.t_start, self.t_end) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn total_generated(&self) -> usize {
        self.completed.iter().map(|c| c.tokens.len()).sum()
    }

    pub fn total_prompt(&self) -> usize {
        self.completed.iter().map(|c| c.prompt_len).sum()
    }

    /// Generated tokens per second (the Fig. 5 throughput metric).
    pub fn throughput_tps(&self) -> f64 {
        let w = self.wall_s();
        if w == 0.0 {
            0.0
        } else {
            self.total_generated() as f64 / w
        }
    }

    pub fn batch_occupancy(&self) -> f64 {
        if self.slot_steps == 0 {
            0.0
        } else {
            self.live_slot_steps as f64 / self.slot_steps as f64
        }
    }

    /// TTFT p50/p95 over sessions that actually produced a first token —
    /// rejected/cancelled-in-queue records carry `ttft_ms: None` and are
    /// excluded rather than dragging the percentiles toward zero.
    pub fn ttft_ms(&self) -> (f64, f64) {
        let xs: Vec<f64> = self.completed.iter().filter_map(|c| c.ttft_ms).collect();
        (percentile(&xs, 50.0), percentile(&xs, 95.0))
    }

    /// End-to-end latency p50/p95 over served sessions (same exclusion rule
    /// as [`Metrics::ttft_ms`]: only sessions that produced tokens count).
    pub fn latency_ms(&self) -> (f64, f64) {
        let xs: Vec<f64> = self
            .completed
            .iter()
            .filter(|c| c.ttft_ms.is_some())
            .map(|c| c.total_ms)
            .collect();
        (percentile(&xs, 50.0), percentile(&xs, 95.0))
    }

    /// Completion counts per resolved method name, in first-completion
    /// order — the per-tenant routing receipt for mixed-precision serving.
    /// Rejected/cancelled-in-queue records never ran a method (placeholder
    /// "-", `ttft_ms: None`) and are excluded.
    pub fn completed_by_method(&self) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> = Vec::new();
        for c in self.completed.iter().filter(|c| c.ttft_ms.is_some()) {
            match out.iter_mut().find(|(m, _)| *m == c.method) {
                Some((_, n)) => *n += 1,
                None => out.push((c.method.clone(), 1)),
            }
        }
        out
    }

    /// Queue-wait (submit → admission) p50/p95 over served sessions.
    pub fn queue_wait_ms(&self) -> (f64, f64) {
        let xs: Vec<f64> = self
            .completed
            .iter()
            .filter(|c| c.ttft_ms.is_some())
            .map(|c| c.queue_ms)
            .collect();
        (percentile(&xs, 50.0), percentile(&xs, 95.0))
    }

    /// Record the current pool counters (called once per scheduling tick).
    pub fn observe_pool(&mut self, stats: &crate::kvcache::pool::PoolStats) {
        self.pool_pages_leased = stats.leased;
        self.pool_pages_total = stats.max_pages.unwrap_or(0);
        self.pool_high_water = stats.high_water;
        self.pool_lease_failures = stats.lease_failures;
    }

    pub fn summary(&self) -> String {
        let (ttft50, ttft95) = self.ttft_ms();
        let (lat50, lat95) = self.latency_ms();
        let (qw50, qw95) = self.queue_wait_ms();
        format!(
            "requests={} gen_tokens={} wall={:.2}s throughput={:.1} tok/s \
             occupancy={:.2} max_concurrent={} peak_kv_mem={:.2} MB \
             ttft p50/p95={:.0}/{:.0} ms latency p50/p95={:.0}/{:.0} ms \
             queue p50/p95={:.0}/{:.0} ms rejected={} cancelled={} stalls={} \
             pool pages={}/{} high_water={} lease_fail={} parks={} resumes={} preempt={}",
            self.completed.len(),
            self.total_generated(),
            self.wall_s(),
            self.throughput_tps(),
            self.batch_occupancy(),
            self.max_concurrent,
            self.peak_mem_bytes as f64 / 1e6,
            ttft50,
            ttft95,
            lat50,
            lat95,
            qw50,
            qw95,
            self.rejected,
            self.cancelled,
            self.admission_stalls,
            self.pool_pages_leased,
            self.pool_pages_total,
            self.pool_high_water,
            self.pool_lease_failures,
            self.pool_parks,
            self.pool_resumes,
            self.pool_preemptions,
        )
    }
}

/// Table 7-style breakdown from engine timers: share of per-step wall time
/// in channel-selection/quantization vs model execution vs host assembly,
/// plus the decode-arg scratch-pool savings (steps that reused pooled
/// buffers instead of allocating, and the bytes the pool amortizes).
pub struct Breakdown {
    pub quantize_pct: f64,
    pub model_exec_pct: f64,
    pub assemble_pct: f64,
    pub quantize_call_rate_pct: f64,
    /// Share of decode steps served from the pooled per-variant arg
    /// buffers (steady state: ~100%, one build per variant per process).
    pub assemble_reuse_pct: f64,
    /// Total heap bytes currently pooled across all variants; a reused
    /// step saves re-allocating its own variant's share of this.
    pub scratch_bytes_pooled: u64,
}

pub fn breakdown(t: &EngineTimers) -> Breakdown {
    let total = (t.decode_exec_ns + t.quantize_ns + t.assemble_ns).max(1) as f64;
    let assemblies = t.assemble_reuses + t.assemble_builds;
    Breakdown {
        quantize_pct: 100.0 * t.quantize_ns as f64 / total,
        model_exec_pct: 100.0 * t.decode_exec_ns as f64 / total,
        assemble_pct: 100.0 * t.assemble_ns as f64 / total,
        quantize_call_rate_pct: if t.decode_steps == 0 {
            0.0
        } else {
            100.0 * t.quantize_events as f64 / t.decode_steps as f64
        },
        assemble_reuse_pct: if assemblies == 0 {
            0.0
        } else {
            100.0 * t.assemble_reuses as f64 / assemblies as f64
        },
        scratch_bytes_pooled: t.scratch_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::FinishReason;

    fn completed(n: usize) -> Completed {
        Completed {
            id: n as u64,
            prompt_len: 10,
            tokens: vec![1; n],
            reason: FinishReason::Eos,
            method: "bf16".into(),
            ttft_ms: Some(5.0 * n as f64),
            queue_ms: 1.0 * n as f64,
            total_ms: 20.0 * n as f64,
        }
    }

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.start();
        m.completed.push(completed(4));
        m.completed.push(completed(6));
        m.record_step(2, 8);
        m.record_step(1, 8);
        m.stop();
        assert_eq!(m.total_generated(), 10);
        assert!((m.batch_occupancy() - 3.0 / 16.0).abs() < 1e-9);
        assert!(m.throughput_tps() > 0.0);
        assert_eq!(m.max_concurrent, 2);
        assert_eq!((m.rejected, m.cancelled, m.admission_stalls), (0, 0, 0));
    }

    #[test]
    fn percentiles_exclude_tokenless_sessions() {
        let mut m = Metrics::default();
        m.completed.push(completed(4)); // ttft 20ms, total 80ms, queue 4ms
        m.completed.push(completed(4));
        // a request cancelled while queued: no first token — must not drag
        // the percentiles to zero
        m.completed.push(Completed {
            id: 99,
            prompt_len: 10,
            tokens: vec![],
            reason: FinishReason::Cancelled,
            method: "-".into(),
            ttft_ms: None,
            queue_ms: 0.0,
            total_ms: 0.0,
        });
        let (ttft50, _) = m.ttft_ms();
        let (lat50, _) = m.latency_ms();
        let (qw50, _) = m.queue_wait_ms();
        assert!((ttft50 - 20.0).abs() < 1e-9, "ttft p50 {ttft50}");
        assert!((lat50 - 80.0).abs() < 1e-9);
        assert!((qw50 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_100() {
        let t = EngineTimers {
            decode_exec_ns: 700,
            quantize_ns: 100,
            assemble_ns: 200,
            decode_steps: 10,
            quantize_events: 1,
            assemble_reuses: 9,
            assemble_builds: 1,
            scratch_bytes: 4096,
            ..Default::default()
        };
        let b = breakdown(&t);
        assert!((b.quantize_pct + b.model_exec_pct + b.assemble_pct - 100.0).abs() < 1e-6);
        assert!((b.quantize_call_rate_pct - 10.0).abs() < 1e-9);
        assert!((b.assemble_reuse_pct - 90.0).abs() < 1e-9);
        assert_eq!(b.scratch_bytes_pooled, 4096);
    }
}
