//! Per-request state machine: Queued → Prefilling → Decoding → Finished.

use std::time::Instant;

use crate::kvcache::cache::RequestCache;
use crate::model::sampler::Sampling;
use crate::model::tokenizer;
use crate::quant::methods::MethodSpec;

/// Identifier handed back by `Server::submit` and used by `poll`/`cancel`.
pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// Per-request quantization policy. `None` uses the server's default
    /// method; `Some(spec)` routes this request onto that method's decode
    /// variant — two tenants with different precision policies share one
    /// server (the batcher groups live slots into per-variant sub-batches).
    /// Pinning a method here bypasses any server-side `PrecisionPolicy`.
    pub method: Option<MethodSpec>,
    /// Tenant id for multi-tenant SLO accounting (per-tenant percentile
    /// reservoirs, park/preempt fairness counters). Single-tenant callers
    /// pass 0.
    pub tenant: u32,
    /// Per-request deadline in **server ticks** (not wall-clock), counted
    /// from submit. A queued request past its deadline is shed from the
    /// queue (instead of stalling the head); a live one retires as
    /// [`FinishReason::DeadlineExceeded`]. `None` = no deadline. Ticks keep
    /// deadline outcomes deterministic under the seeded traffic harness —
    /// wall-clock deadlines would make the fingerprint load-dependent.
    pub deadline_ticks: Option<u64>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    CacheFull,
    /// Cancelled via `Server::cancel` (queued or mid-decode).
    Cancelled,
    /// Rejected: at submit (prompt exceeds every prefill bucket, unknown
    /// decode variant, worst-case footprint beyond the whole memory
    /// budget, or a full bounded queue) or at admission (e.g. the method's
    /// decode artifact failed to load).
    Rejected,
    /// A per-request error (injected fault, decode-step failure, exhausted
    /// prefill retries) retired this request. Error isolation: only the
    /// failing request carries this reason — the tick, its variant group,
    /// and every other request proceed.
    Error,
    /// The request's tick-based deadline (`Request::deadline_ticks`)
    /// expired before it finished.
    DeadlineExceeded,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Decoding,
    Finished(FinishReason),
}

pub struct Session {
    pub request: Request,
    pub cache: RequestCache,
    pub generated: Vec<i32>,
    /// Token to feed at the next decode step.
    pub next_token: i32,
    pub phase: Phase,
    /// When the request entered the queue (submit time).
    pub t_arrival: Instant,
    /// When the request was admitted for prefill (the session is created at
    /// admission, so this is the construction time).
    pub t_admitted: Instant,
    pub t_first_token: Option<Instant>,
    pub t_finish: Option<Instant>,
    /// Set while the scheduler has parked this slot: a quantization flush
    /// is due but the shared page pool cannot cover it, so the session sits
    /// out decode ticks (instead of erroring) until pages free up.
    pub parked: bool,
    /// Consecutive ticks this slot has been parked — the park-watchdog's
    /// escalation counter (reset on resume): a slot parked too long first
    /// degrades (prefix entries shed on its behalf), then is shed.
    pub parked_streak: u32,
}

impl Session {
    pub fn new(request: Request, cache: RequestCache, first_token: i32, t_arrival: Instant) -> Self {
        let now = Instant::now();
        Session {
            request,
            cache,
            generated: vec![first_token],
            next_token: first_token,
            phase: Phase::Decoding,
            t_arrival,
            t_admitted: now,
            t_first_token: Some(now),
            t_finish: None,
            parked: false,
            parked_streak: 0,
        }
    }

    /// Record a newly sampled token; returns true if the session finished.
    pub fn push_token(&mut self, tok: i32) -> bool {
        self.generated.push(tok);
        self.next_token = tok;
        if tok == tokenizer::EOS {
            self.finish(FinishReason::Eos);
            true
        } else if self.generated.len() >= self.request.max_new_tokens {
            self.finish(FinishReason::MaxTokens);
            true
        } else {
            false
        }
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.phase = Phase::Finished(reason);
        self.t_finish = Some(Instant::now());
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished(_))
    }

    pub fn finish_reason(&self) -> Option<FinishReason> {
        match self.phase {
            Phase::Finished(r) => Some(r),
            _ => None,
        }
    }
}

/// Completed-request record handed back to callers / metrics.
#[derive(Clone, Debug)]
pub struct Completed {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Resolved method name this request was served under ("-" when it was
    /// never admitted: rejected or cancelled while queued).
    pub method: String,
    /// Tenant id carried through from the request (SLO accounting).
    pub tenant: u32,
    /// Submit → first token. `None` when the request never produced a token
    /// (rejected / cancelled in queue) — such records are excluded from the
    /// TTFT percentiles instead of dragging them toward zero.
    pub ttft_ms: Option<f64>,
    /// Submit → admission (queue wait).
    pub queue_ms: f64,
    /// Submit → finish.
    pub total_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{CacheConfig, ModelConfig};
    use crate::quant::methods::Method;
    use crate::quant::window::TierSpec;

    fn mk_session(max_new: usize) -> Session {
        let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let cache = RequestCache::new(
            &mc,
            &cc,
            &[TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }],
            Method::bf16(),
            32,
        );
        let req = Request {
            id: 1,
            prompt: vec![tokenizer::BOS],
            max_new_tokens: max_new,
            sampling: Sampling::Greedy,
            method: None,
            tenant: 0,
            deadline_ticks: None,
        };
        Session::new(req, cache, 42, Instant::now())
    }

    #[test]
    fn eos_finishes() {
        let mut s = mk_session(100);
        assert!(!s.push_token(17));
        assert!(s.push_token(tokenizer::EOS));
        assert_eq!(s.finish_reason(), Some(FinishReason::Eos));
        assert_eq!(s.generated, vec![42, 17, tokenizer::EOS]);
    }

    #[test]
    fn max_tokens_finishes() {
        let mut s = mk_session(3);
        assert!(!s.push_token(17));
        assert!(s.push_token(18)); // 3 tokens incl. first
        assert_eq!(s.finish_reason(), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn next_token_tracks_last() {
        let mut s = mk_session(10);
        s.push_token(21);
        assert_eq!(s.next_token, 21);
    }

    #[test]
    fn admission_time_not_before_arrival() {
        let s = mk_session(10);
        assert!(s.t_admitted >= s.t_arrival);
        assert!(s.t_first_token.is_some());
    }
}
