//! The serving front door: `Server` drives engine + batcher + scheduler
//! over a request trace and returns per-request completions + metrics.
//!
//! Single-threaded by design: the PJRT client is not Send, the sandbox has
//! one core, and iteration-level batching gives the same throughput math as
//! an async loop — the *policy* (what gets batched when) is identical to a
//! threaded deployment.

use anyhow::Result;
use std::time::Instant;

use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Scheduler, SchedulerPolicy};
use crate::coordinator::session::{Completed, FinishReason, Request, Session};
use crate::kvcache::accountant::MemoryAccountant;
use crate::model::sampler;
use crate::util::rng::Pcg32;

pub struct ServerConfig {
    pub memory_budget_bytes: usize,
    pub max_prefills_per_cycle: usize,
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            memory_budget_bytes: 64 << 20,
            max_prefills_per_cycle: 2,
            seed: 0,
        }
    }
}

pub struct Server {
    pub engine: Engine,
    pub batcher: Batcher,
    pub scheduler: Scheduler,
    pub metrics: Metrics,
    rng: Pcg32,
}

impl Server {
    pub fn new(engine: Engine, cfg: ServerConfig) -> Server {
        let per_request = MemoryAccountant::worst_case_request_bytes(
            &engine.meta.model,
            &engine.meta.cache,
            &engine.variant.layers,
        );
        let batch = engine.meta.cache.decode_batch;
        Server {
            engine,
            batcher: Batcher::new(batch),
            scheduler: Scheduler::new(
                SchedulerPolicy {
                    max_prefills_per_cycle: cfg.max_prefills_per_cycle,
                    per_request_bytes: per_request,
                },
                cfg.memory_budget_bytes,
            ),
            metrics: Metrics::default(),
            rng: Pcg32::seeded(cfg.seed),
        }
    }

    /// Serve a whole trace to completion (offline/batch mode — every bench
    /// and example drives this; an online server would feed `enqueue`
    /// from a socket instead).
    pub fn run(&mut self, requests: Vec<Request>) -> Result<Vec<Completed>> {
        for r in requests {
            self.batcher.enqueue(r);
        }
        self.metrics.start();
        while self.batcher.has_work() {
            self.cycle()?;
        }
        self.metrics.stop();
        Ok(self.metrics.completed.clone())
    }

    /// One scheduling cycle: admissions (prefill) then one decode step.
    pub fn cycle(&mut self) -> Result<()> {
        // --- admissions -------------------------------------------------
        let quota = self
            .scheduler
            .admission_quota(self.batcher.slots.len() - self.batcher.live(), self.batcher.waiting.len());
        for _ in 0..quota {
            if !self.scheduler.try_admit() {
                break; // memory budget saturated — leave in queue
            }
            let Some((slot, req)) = self.batcher.next_admission() else {
                self.scheduler.release();
                break;
            };
            let t_arrival = Instant::now();
            let pre = self.engine.prefill(&req.prompt)?;
            let mut cache = self.engine.admit_prefill(&pre)?;
            let first = sampler::sample(&pre.last_logits, req.sampling, &mut self.rng);
            cache.pos = pre.t; // next decode position
            let mut sess = Session::new(req, cache, first, t_arrival);
            sess.bytes_reserved = self.scheduler.policy.per_request_bytes;
            // prompt-only EOS edge case
            if sess.push_token_is_immediate_finish() {
                self.finish_session(&mut sess);
                self.scheduler.release();
                self.metrics.completed.push(make_completed(&sess));
                continue;
            }
            self.batcher.install(slot, sess);
        }

        // --- decode step -------------------------------------------------
        let live = self.batcher.live();
        if live > 0 {
            let batch = self.batcher.slots.len();
            self.metrics.record_step(live, batch);
            let mut slots: Vec<Option<(&mut crate::kvcache::cache::RequestCache, i32)>> =
                Vec::with_capacity(batch);
            for s in self.batcher.slots.iter_mut() {
                match s {
                    Some(sess) if !sess.is_finished() => {
                        let tok = sess.next_token;
                        slots.push(Some((&mut sess.cache, tok)));
                    }
                    _ => slots.push(None),
                }
            }
            let logits = self.engine.decode_step(&mut slots)?;
            drop(slots);
            for (i, lg) in logits.into_iter().enumerate() {
                if let (Some(sess), Some(lg)) = (self.batcher.slots[i].as_mut(), lg) {
                    if sess.cache.remaining() == 0 {
                        sess.finish(FinishReason::CacheFull);
                        continue;
                    }
                    let tok = sampler::sample(&lg, sess.request.sampling, &mut self.rng);
                    sess.push_token(tok);
                }
            }
            // account live cache bytes for the peak-memory metric
            let live_bytes: usize = self
                .batcher
                .slots
                .iter()
                .flatten()
                .map(|s| s.cache.bytes_used())
                .sum();
            self.metrics.peak_mem_bytes = self.metrics.peak_mem_bytes.max(live_bytes);
        }

        // --- reap finished ------------------------------------------------
        for sess in self.batcher.reap() {
            self.scheduler.release();
            self.metrics.completed.push(make_completed(&sess));
        }
        Ok(())
    }

    fn finish_session(&mut self, sess: &mut Session) {
        sess.finish(FinishReason::Eos);
    }
}

impl Session {
    /// First sampled token is already EOS / budget is 1.
    fn push_token_is_immediate_finish(&mut self) -> bool {
        self.next_token == crate::model::tokenizer::EOS || self.request.max_new_tokens <= 1
    }
}

fn make_completed(sess: &Session) -> Completed {
    let ttft = sess
        .t_first_token
        .map(|t| t.duration_since(sess.t_arrival).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    let total = sess
        .t_finish
        .map(|t| t.duration_since(sess.t_arrival).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    Completed {
        id: sess.request.id,
        prompt_len: sess.request.prompt.len(),
        tokens: sess.generated.clone(),
        reason: sess.finish_reason().unwrap_or(FinishReason::MaxTokens),
        ttft_ms: ttft,
        total_ms: total,
    }
}
