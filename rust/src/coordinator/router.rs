//! The serving front door: a session-oriented, non-blocking frontend over
//! engine + batcher + scheduler, storing every live cache in a shared
//! **paged KV pool** (kvcache::pool).
//!
//! * [`Server::submit`] accepts a request (with an optional per-request
//!   [`MethodSpec`](crate::quant::methods::MethodSpec) override) and returns
//!   its `RequestId` immediately;
//! * [`Server::tick`] runs one scheduling cycle: admissions — still
//!   **occupancy-based**: a request starts prefilling when the pool can
//!   cover its actual prefill pages and keep a reserve watermark free, and a
//!   prompt the **radix prefix tree** already covers charges only its
//!   divergent tail (a fully registered prompt charges ZERO pages — its
//!   shared pages were charged once, at registration) — then **chunked
//!   prefill work** under a per-tick `(layer, chunk)` unit budget
//!   (`ServerConfig::prefill_chunks_per_tick`), ordered
//!   shortest-remaining-chunks first (stable by arrival, so short prompts
//!   stop queueing behind long ones; reorder ticks are counted in
//!   `EngineTimers::prefill_reorders`): prompts prefill through the blocked
//!   direct-to-page pipeline
//!   ([`crate::coordinator::engine::ChunkedPrefill`]), quantized pages
//!   filling in as layers close, and a long prompt spreads across ticks
//!   instead of monopolizing one against live decoders — unless
//!   [`Engine::admit_prefill`] answers from the tree: a full hit skips the
//!   ENTIRE prefill (the cache adopts the registered shared pages
//!   copy-on-write and the first token samples from the registered logits
//!   the same tick), and a frozen-plan partial hit adopts the deepest
//!   registered prefix and resumes prefill from the divergence seam. Each
//!   completed non-full-hit prefill registers its prompt into the tree
//!   before installing. Then one decode step per live variant group. A
//!   live slot whose due quantization flush cannot lease pages is
//!   **parked** for the tick (its tokens ride in the residual meanwhile)
//!   and resumes when pages free up; under pool pressure the tree sheds
//!   LRU leaves first (retention never outranks a live flush); if every
//!   live slot is parked the largest *private* page-holder is shed as
//!   CacheFull so the server never deadlocks;
//! * [`Server::poll`] / [`Server::cancel`] / [`Server::drain_events`]
//!   observe and steer individual requests — every request emits a
//!   well-formed `Queued → Admitted → FirstToken → Token* → Finished`
//!   stream (see `coordinator::events`). The first poll that observes a
//!   terminal request takes its full record; the server then keeps only an
//!   id → (reason, token-count) stub, so a long-lived frontend does not
//!   retain every completed token stream twice (late polls answer
//!   [`RequestStatus::Retired`]);
//! * [`Server::run`] is a thin compatibility shim (submit all → tick until
//!   drained) so offline batch drivers keep working token-for-token.
//!
//! The *coordinator* is single-threaded: one thread owns admission,
//! batching, sampling, the prefix tree, and all pool bookkeeping, so
//! serving policy stays sequentially deterministic. Per-tick **compute**
//! shards across a fixed worker pool (`ServerConfig::workers`, see the
//! crate docs' "Threading model"): decode sub-batches fan out one job per
//! live slot, chunked-prefill units advance concurrently under the
//! abundance gate, and a lone decode splits by attention head — all with
//! index-ordered merges, so results are bit-identical to `workers = 1`
//! (the exact legacy single-threaded path). On the compiled backend the
//! PJRT client is not Send, so ticks stay inline regardless of `workers`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::{ChunkedPrefill, DecodeGroup, Engine};
use crate::coordinator::events::{reason_from_tag, reason_tag, Event, EventLog, RequestStatus};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Scheduler, SchedulerPolicy};
use crate::coordinator::session::{Completed, FinishReason, Phase, Request, RequestId, Session};
use crate::kvcache::accountant::MemoryAccountant;
use crate::kvcache::pool::{KvPool, Page, PageLease, SharedLease};
use crate::kvcache::radix::RadixTree;
use crate::model::reference::PrefillRun;
use crate::model::sampler::{self, Sampling};
use crate::model::tokenizer;
use crate::quant::methods::{Method, MethodSpec};
use crate::quant::policy::{PrecisionPolicy, SpecCosts};
use crate::runtime::registry::pick_bucket;
use crate::util::faults::{draw_key, FaultInjector, FaultPlan, FaultSite};
use crate::util::rng::Pcg32;
use crate::util::snapshot::{corrupt, page_checksum, SnapReader, SnapResult, SnapWriter};

/// Failed-prefill retry budget per ladder rung: after this many attempts
/// the request retries at the next cheaper rung (if the ladder has one)
/// before giving up as [`FinishReason::Error`].
const MAX_PREFILL_ATTEMPTS: u32 = 3;

/// Consecutive parked ticks before the park-watchdog *degrades* on the
/// slot's behalf (sheds a retained prefix-tree leaf to free pages).
const PARK_WATCHDOG_DEGRADE: u32 = 8;

/// Consecutive parked ticks before the park-watchdog *sheds* the slot
/// itself (retired as CacheFull) — a slot starved this long is blocking a
/// fixed decode slot without any prospect of progress.
const PARK_WATCHDOG_SHED: u32 = 16;

#[derive(Clone)]
pub struct ServerConfig {
    pub memory_budget_bytes: usize,
    pub max_prefills_per_cycle: usize,
    pub seed: u64,
    /// Pages the pool keeps free as decode headroom (admission watermark).
    /// `None` derives a default: one flush worth per decode slot, capped at
    /// a quarter of the pool.
    pub reserve_pages: Option<usize>,
    /// Chunked-prefill `(layer, chunk)` units one tick may spend across all
    /// in-flight prefills. The default is generous (typical prompts admit
    /// in one tick, matching the pre-chunked behavior); lower it to bound
    /// the decode stall a batch of long prompts can inject per tick — an
    /// unfinished prefill simply resumes next tick.
    pub prefill_chunks_per_tick: usize,
    /// Retained capacity of the bounded completion ring
    /// (`Metrics::completed`) — totals and percentiles stream past it, but
    /// only this many full `Completed` records (token streams) stay
    /// resident for `poll`/`Server::run` to hand out.
    pub completed_ring: usize,
    /// Pool pages the cross-request radix prefix tree may pin (retained
    /// shared prompt-prefix groups). `None` derives a default of a quarter
    /// of the pool; `Some(0)` disables prefix sharing.
    pub prefix_cache_pages: Option<usize>,
    /// Frozen-plan partial-hit override threaded to
    /// [`Engine::set_frozen_plan`]: `Some(true)` serves partial prefix
    /// hits for every method, `Some(false)` serves full hits only, `None`
    /// (the default) defers to the per-method default
    /// ([`crate::coordinator::engine::frozen_plan_default`] — the
    /// error-budget ablation's verdict).
    pub frozen_plan: Option<bool>,
    /// Server-side precision policy for requests that do not pin a
    /// [`MethodSpec`](crate::quant::methods::MethodSpec) themselves. `None`
    /// keeps the pre-policy behavior (the engine's default method). With a
    /// policy installed, admission walks the policy's candidate ladder:
    /// under pool pressure a new request degrades to a cheaper variant
    /// (counted in `Metrics::policy_degradations`) instead of stalling the
    /// queue. Requests with an explicit `method` bypass the policy.
    pub policy: Option<PrecisionPolicy>,
    /// Bounded wait queue: a submit arriving while this many requests are
    /// already waiting is rejected immediately (terminal `Rejected` record,
    /// counted in `Metrics::queue_rejections`) so backpressure reaches the
    /// caller instead of the queue growing without bound — under a `Fixed`
    /// policy with a full pool, queued requests otherwise wait forever.
    /// `None` keeps the queue unbounded.
    pub max_queue: Option<usize>,
    /// Deterministic fault plan (chaos testing): installing an armed plan
    /// wires a shared [`FaultInjector`] into the pool (lease denial) and
    /// the engine (prefill-chunk, decode-step, and prefix-corruption
    /// faults). Same seed → same fault schedule. `None` (the default)
    /// leaves every hook free on the happy path.
    pub faults: Option<FaultPlan>,
    /// Fixed worker-pool size for per-tick compute sharding (crate docs,
    /// "Threading model"). Defaults to the machine's available
    /// parallelism; `1` is the exact legacy single-threaded path. Results
    /// are bit-identical at every value — only wall time changes.
    pub workers: usize,
    /// Periodic crash-safe snapshot target (`mixkvq-snap-v2` image,
    /// write-then-rename). The server itself never writes it — the
    /// operator loop (`main.rs serve`) does — but it resolves here so env
    /// defaults live in exactly one place ([`ServerConfig::builder`]).
    pub snapshot_path: Option<std::path::PathBuf>,
    /// Ticks between periodic snapshots (0 disables them even with a path
    /// configured).
    pub snapshot_every_ticks: u64,
}

/// Default worker count: the `MIXKVQ_WORKERS` environment variable when
/// set (CI runs the whole suite at a pinned width this way), else the
/// machine's available parallelism (1 when it cannot be determined).
/// [`ServerConfig::builder`] consults this — callers who just want the
/// resolved default should go through the builder.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MIXKVQ_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl ServerConfig {
    /// Start a [`ServerConfigBuilder`]. Every environment default —
    /// `MIXKVQ_WORKERS`, `MIXKVQ_FROZEN_PLAN`, `MIXKVQ_PREFIX_CACHE_PAGES`,
    /// `MIXKVQ_SNAPSHOT_PATH`/`MIXKVQ_SNAPSHOT_EVERY_TICKS` — resolves in
    /// exactly one place: [`ServerConfigBuilder::build`], and only for
    /// fields the caller did not set explicitly. `ServerConfig::default()`
    /// is `builder().build()`, so plain struct-update construction
    /// (`..Default::default()`) picks the same env defaults up.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder::default()
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig::builder().build()
    }
}

/// Builder for [`ServerConfig`] — the ONE place environment defaults
/// resolve (see [`ServerConfig::builder`]). Unset fields fall back to
/// their env variable when one exists, else the hard-coded default.
#[derive(Default)]
pub struct ServerConfigBuilder {
    memory_budget_bytes: Option<usize>,
    max_prefills_per_cycle: Option<usize>,
    seed: Option<u64>,
    reserve_pages: Option<Option<usize>>,
    prefill_chunks_per_tick: Option<usize>,
    completed_ring: Option<usize>,
    prefix_cache_pages: Option<Option<usize>>,
    frozen_plan: Option<Option<bool>>,
    policy: Option<Option<PrecisionPolicy>>,
    max_queue: Option<Option<usize>>,
    faults: Option<Option<FaultPlan>>,
    workers: Option<usize>,
    snapshot_path: Option<Option<std::path::PathBuf>>,
    snapshot_every_ticks: Option<u64>,
}

/// Parse a boolean-ish env value ("1"/"true"/"on" vs "0"/"false"/"off");
/// anything else is ignored (None).
fn env_bool(name: &str) -> Option<bool> {
    let v = std::env::var(name).ok()?;
    match v.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

impl ServerConfigBuilder {
    pub fn memory_budget_bytes(mut self, v: usize) -> Self {
        self.memory_budget_bytes = Some(v);
        self
    }

    pub fn max_prefills_per_cycle(mut self, v: usize) -> Self {
        self.max_prefills_per_cycle = Some(v);
        self
    }

    pub fn seed(mut self, v: u64) -> Self {
        self.seed = Some(v);
        self
    }

    pub fn reserve_pages(mut self, v: Option<usize>) -> Self {
        self.reserve_pages = Some(v);
        self
    }

    pub fn prefill_chunks_per_tick(mut self, v: usize) -> Self {
        self.prefill_chunks_per_tick = Some(v);
        self
    }

    pub fn completed_ring(mut self, v: usize) -> Self {
        self.completed_ring = Some(v);
        self
    }

    pub fn prefix_cache_pages(mut self, v: Option<usize>) -> Self {
        self.prefix_cache_pages = Some(v);
        self
    }

    pub fn frozen_plan(mut self, v: Option<bool>) -> Self {
        self.frozen_plan = Some(v);
        self
    }

    pub fn policy(mut self, v: Option<PrecisionPolicy>) -> Self {
        self.policy = Some(v);
        self
    }

    pub fn max_queue(mut self, v: Option<usize>) -> Self {
        self.max_queue = Some(v);
        self
    }

    pub fn faults(mut self, v: Option<FaultPlan>) -> Self {
        self.faults = Some(v);
        self
    }

    pub fn workers(mut self, v: usize) -> Self {
        self.workers = Some(v.max(1));
        self
    }

    pub fn snapshot(mut self, path: Option<std::path::PathBuf>, every_ticks: u64) -> Self {
        self.snapshot_path = Some(path);
        self.snapshot_every_ticks = Some(every_ticks);
        self
    }

    /// Resolve into a [`ServerConfig`]: explicit settings win, then env
    /// variables, then hard-coded defaults.
    pub fn build(self) -> ServerConfig {
        let env_usize = |name: &str| {
            std::env::var(name).ok().and_then(|v| v.trim().parse::<usize>().ok())
        };
        let env_u64 =
            |name: &str| std::env::var(name).ok().and_then(|v| v.trim().parse::<u64>().ok());
        ServerConfig {
            memory_budget_bytes: self.memory_budget_bytes.unwrap_or(64 << 20),
            max_prefills_per_cycle: self.max_prefills_per_cycle.unwrap_or(2),
            seed: self.seed.unwrap_or(0),
            reserve_pages: self.reserve_pages.unwrap_or(None),
            prefill_chunks_per_tick: self.prefill_chunks_per_tick.unwrap_or(256),
            completed_ring: self
                .completed_ring
                .unwrap_or(crate::coordinator::metrics::COMPLETED_RING_DEFAULT),
            prefix_cache_pages: self
                .prefix_cache_pages
                .unwrap_or_else(|| env_usize("MIXKVQ_PREFIX_CACHE_PAGES")),
            frozen_plan: self.frozen_plan.unwrap_or_else(|| env_bool("MIXKVQ_FROZEN_PLAN")),
            policy: self.policy.unwrap_or(None),
            max_queue: self.max_queue.unwrap_or(None),
            faults: self.faults.unwrap_or(None),
            workers: self.workers.unwrap_or_else(default_workers),
            snapshot_path: self.snapshot_path.unwrap_or_else(|| {
                std::env::var("MIXKVQ_SNAPSHOT_PATH").ok().map(std::path::PathBuf::from)
            }),
            snapshot_every_ticks: self
                .snapshot_every_ticks
                .unwrap_or_else(|| env_u64("MIXKVQ_SNAPSHOT_EVERY_TICKS").unwrap_or(0)),
        }
    }
}

/// One in-flight chunked prefill owned by the server between admission
/// (request left the wait queue, pages reserved by occupancy) and slot
/// installation (prefill complete, first token sampled). Dropping it — on
/// cancel or a mid-run error — returns every page the cache leased.
struct PendingPrefill {
    req: Request,
    method: crate::quant::methods::Method,
    cp: ChunkedPrefill,
    /// Prefill pages this run was admitted against (its occupancy claim;
    /// ZERO for a full prefix-tree hit, only the divergent tail for a
    /// frozen-plan partial hit — shared pages were charged once, at
    /// registration). Leasing is incremental (one page per group as layers
    /// close), so admission must count `pages_claimed − leased` of every
    /// pending run as already spoken for — otherwise two runs admitted in
    /// the same tick could both pass the occupancy probe and the later one
    /// would die Rejected mid-prefill instead of waiting its turn in the
    /// queue.
    pages_claimed: usize,
    /// Admission sequence — the stable tie-break of the
    /// shortest-remaining-chunks prefill round.
    arrival: u64,
}

impl PendingPrefill {
    /// Claimed pages this run has not leased yet.
    fn outstanding_pages(&self) -> usize {
        self.pages_claimed.saturating_sub(self.cp.cache.leased_pages())
    }

    /// (layer, chunk) units still to run — the SRTF ordering key.
    fn remaining_chunks(&self, n_layers: usize) -> usize {
        if self.cp.run.is_done() {
            0
        } else {
            self.cp.run.total_chunks(n_layers) - self.cp.run.chunks_done()
        }
    }
}

/// A failed prefill waiting out its ticks-based backoff before re-entering
/// the wait queue. The attempt/rung state lives in `Server::retry_state`
/// (keyed by id), so the queue round-trip stays a plain `Request`.
struct RetryTicket {
    req: Request,
    /// Tick at which this retry re-enters the wait queue.
    ready_tick: u64,
}

/// Per-request retry bookkeeping: how many prefill attempts failed at the
/// current ladder rung, and the lowest rung the request may be admitted at
/// (advanced one rung per exhausted attempt budget — the PM-KVQ-style
/// degradation axis: retry cheaper, don't crash or camp the queue).
#[derive(Clone, Copy, Default)]
struct RetryState {
    attempt: u32,
    min_rank: usize,
}

/// Terminal-record slot in `Server::finished`: never a second copy of the
/// `Completed` (which lives in the bounded `metrics.completed` ring), and
/// demoted to a stub once a poll has observed it. The reason/count ride
/// here too, so a record the ring has already evicted still answers late
/// polls correctly (as `Retired`).
#[derive(Clone, Copy, Debug)]
enum Terminal {
    /// Sequence number in `metrics.completed`; no poll has observed it yet.
    Pending { seq: u64, reason: FinishReason, n_tokens: usize },
    /// Observed: only reason + token count remain for late polls.
    Retired { reason: FinishReason, n_tokens: usize },
}

pub struct Server {
    pub engine: Engine,
    pub batcher: Batcher,
    pub scheduler: Scheduler,
    pub metrics: Metrics,
    pub events: EventLog,
    /// The shared page pool every admitted request leases from.
    pub pool: KvPool,
    rng: Pcg32,
    /// Submit timestamps for queued/live requests (queue-wait accounting).
    submit_times: HashMap<RequestId, Instant>,
    /// Terminal records by id (the `poll` fast path) — see [`Terminal`].
    finished: HashMap<RequestId, Terminal>,
    /// In-flight chunked prefills (admitted by occupancy, not yet in a
    /// decode slot), advanced shortest-remaining-chunks-first (stable by
    /// arrival) under the per-tick chunk budget.
    prefills: Vec<PendingPrefill>,
    prefill_chunks_per_tick: usize,
    /// Admission counter feeding `PendingPrefill::arrival`.
    prefill_seq: u64,
    /// Server-side precision policy (see `ServerConfig::policy`).
    policy: Option<PrecisionPolicy>,
    /// Worst-case byte cost of every spec under this engine's Meta — the
    /// policy's cost model, computed once at construction.
    spec_costs: SpecCosts,
    /// Monotonic tick counter — the clock for deadlines and retry backoff
    /// (ticks, not wall time: deterministic under the seeded harness).
    ticks: u64,
    /// Submit tick per queued/in-flight id (deadline accounting).
    submit_ticks: HashMap<RequestId, u64>,
    /// Failed prefills waiting out their backoff (see [`RetryTicket`]).
    retries: Vec<RetryTicket>,
    /// Retry bookkeeping per in-flight id (see [`RetryState`]).
    retry_state: HashMap<RequestId, RetryState>,
    /// Bounded wait queue (see `ServerConfig::max_queue`).
    max_queue: Option<usize>,
    /// Monotonic snapshot ordinal — keys the `SnapshotWrite`/
    /// `SnapshotCorrupt` fault draws, and is itself snapshotted so a
    /// restored server continues the same fault-draw series.
    snapshot_seq: u64,
    /// Shared deterministic fault injector (chaos testing); also installed
    /// into the pool and the engine (and reachable from worker threads —
    /// draws are stateless keyed functions, see util::faults). `None` =
    /// no plan.
    faults: Option<Arc<FaultInjector>>,
}

impl Server {
    pub fn new(mut engine: Engine, cfg: ServerConfig) -> Server {
        let per_request = MemoryAccountant::worst_case_request_bytes(
            &engine.meta.model,
            &engine.meta.cache,
            &engine.variant.layers,
        );
        let batch = engine.meta.cache.decode_batch;
        let pool = engine.build_shared_pool(cfg.memory_budget_bytes);
        engine.set_kv_pool(pool.clone());
        let max_pages = pool.max_pages().unwrap_or(usize::MAX);
        let flush_pages = crate::kvcache::pool::pages_for_tokens(
            engine.r_limit,
            engine.meta.cache.group,
            engine.meta.model.n_layers,
            engine.meta.model.n_kv_heads,
        );
        let reserve = cfg
            .reserve_pages
            .unwrap_or_else(|| (batch * flush_pages.max(1)).min(max_pages / 4));
        // cross-request prefix sharing: the radix tree may pin up to a
        // quarter of the pool by default (LRU-shed from the leaves under
        // pressure, so retention never starves live flushes)
        let prefix_cap = cfg.prefix_cache_pages.unwrap_or(max_pages / 4);
        if prefix_cap > 0 {
            engine.set_prefix_tree(Rc::new(RefCell::new(RadixTree::new(
                prefix_cap,
                pool.page_deploy_bytes(),
            ))));
        }
        engine.set_frozen_plan(cfg.frozen_plan);
        // deterministic fault injection: one shared injector wired into the
        // pool (lease denial) and the engine (prefill/decode/prefix sites)
        let faults = cfg.faults.filter(FaultPlan::is_armed).map(FaultInjector::shared);
        if let Some(f) = &faults {
            pool.set_fault_injector(Arc::clone(f));
            engine.set_faults(Arc::clone(f));
        }
        // fixed worker pool for per-tick compute sharding; per-worker
        // arenas are warmed here, once
        engine.set_workers(cfg.workers);
        Server {
            batcher: Batcher::new(batch),
            scheduler: Scheduler::with_pool(
                SchedulerPolicy {
                    max_prefills_per_cycle: cfg.max_prefills_per_cycle,
                    per_request_bytes: per_request,
                    reserve_pages: reserve,
                },
                cfg.memory_budget_bytes,
                pool.clone(),
            ),
            metrics: Metrics {
                completed: crate::coordinator::metrics::CompletedLog::with_capacity(
                    cfg.completed_ring,
                ),
                ..Metrics::default()
            },
            events: EventLog::default(),
            pool,
            rng: Pcg32::seeded(cfg.seed),
            submit_times: HashMap::new(),
            finished: HashMap::new(),
            prefills: Vec::new(),
            prefill_chunks_per_tick: cfg.prefill_chunks_per_tick.max(1),
            prefill_seq: 0,
            policy: cfg.policy,
            spec_costs: SpecCosts::from_meta(&engine.meta),
            ticks: 0,
            submit_ticks: HashMap::new(),
            retries: Vec::new(),
            retry_state: HashMap::new(),
            max_queue: cfg.max_queue,
            snapshot_seq: 0,
            faults,
            engine,
        }
    }

    /// Ticks the server has run (the deadline/backoff clock).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// The request's admission ladder: candidate methods most-preferred
    /// first. An explicit per-request `MethodSpec` pins a single rung
    /// (bypassing any policy); otherwise the server policy's candidates
    /// apply; with no policy, the engine's default method is the only rung.
    fn admission_ladder(&self, req: &Request) -> Vec<Method> {
        if let Some(spec) = req.method {
            return vec![spec.build()];
        }
        match &self.policy {
            Some(p) => p.candidates(&self.spec_costs).into_iter().map(|s| s.build()).collect(),
            None => vec![self.engine.resolve_method(None)],
        }
    }

    /// Drop one LRU radix-tree leaf (pages with no other holder return to
    /// the pool immediately; interior nodes with live descendants are
    /// never shed). Returns false when there is no tree or it is empty.
    fn shed_prefix_entry(&mut self) -> bool {
        match self.engine.prefix_tree() {
            Some(ix) => ix.borrow_mut().shed_lru(),
            None => false,
        }
    }

    /// Accept a request into the wait queue and return its id immediately.
    /// Rejects up front (with a `Finished{Rejected}` event and a terminal
    /// record) when the prompt exceeds every prefill bucket, the requested
    /// method's decode variant is unknown, the method's worst-case cache
    /// footprint exceeds the server's whole memory budget, or its prefill
    /// pages can never fit under the admission watermark (such a request
    /// could never be admitted and would otherwise stall the queue head
    /// forever).
    ///
    /// Errors only on a programmer mistake: ids must be unique among
    /// in-flight requests. Reusing the id of a *terminal* request starts a
    /// fresh lifecycle and replaces its record (drain events between reuses
    /// to keep streams separable).
    pub fn submit(&mut self, req: Request) -> Result<RequestId> {
        let id = req.id;
        let in_flight = self.batcher.waiting.iter().any(|r| r.id == id)
            || self.prefills.iter().any(|p| p.req.id == id)
            || self.retries.iter().any(|t| t.req.id == id)
            || self.batcher.slots.iter().flatten().any(|s| s.request.id == id);
        if in_flight {
            bail!("request id {id} is already in flight on this server");
        }
        self.finished.remove(&id);
        let now = Instant::now();
        self.submit_times.insert(id, now);
        self.submit_ticks.insert(id, self.ticks);
        self.events.queued(id);
        // bounded queue: reject-at-submit backpressure instead of unbounded
        // growth (a Fixed policy over a full pool never drains the head)
        if self.max_queue.is_some_and(|maxq| self.batcher.waiting.len() >= maxq) {
            self.metrics.queue_rejections += 1;
            self.metrics.rejected += 1;
            self.finalize_unadmitted(id, req.prompt.len(), req.tenant, FinishReason::Rejected);
            return Ok(id);
        }
        let fits = pick_bucket(&self.engine.meta.cache.prefill_buckets, req.prompt.len()).is_ok();
        // at least one ladder rung must be affordable (worst-case footprint
        // inside the whole budget) and admissible. Full prefix-tree hits
        // charge zero pages (partial hits only their divergent tail), so a
        // prompt whose pages could never fit privately is still admissible
        // while its match is resident (admit() re-checks and retires it if
        // the nodes are shed). An empty ladder (e.g. a
        // MemorySlo budget below every spec) rejects everything unpinned.
        let serveable = fits
            && self.admission_ladder(&req).iter().any(|method| {
                let affordable = self
                    .engine
                    .worst_case_bytes_for(method)
                    .map(|b| b <= self.scheduler.accountant.budget_bytes)
                    .unwrap_or(false); // Err = unknown decode variant
                affordable
                    && self
                        .engine
                        .prefill_pages_for_prompt(&req.prompt, method)
                        .map(|n| self.scheduler.pages_admissible(n))
                        .unwrap_or(false)
            });
        if !serveable {
            self.metrics.rejected += 1;
            self.finalize_unadmitted(id, req.prompt.len(), req.tenant, FinishReason::Rejected);
            return Ok(id);
        }
        self.batcher.enqueue(req);
        Ok(id)
    }

    /// Any queued, prefilling, retrying, or live work left?
    pub fn has_work(&self) -> bool {
        self.batcher.has_work() || !self.prefills.is_empty() || !self.retries.is_empty()
    }

    /// In-flight chunked prefills — admitted (pages claimed, possibly a
    /// prefix adopted) but not yet installed into a decode slot. Tests use
    /// this to place kill points mid-prefill.
    pub fn prefills_in_flight(&self) -> usize {
        self.prefills.len()
    }

    /// Status of one request. The FIRST poll observing a terminal request
    /// returns `Finished` with the full token stream and evicts the record
    /// down to a stub; later polls return `Retired` with the reason and
    /// token count — a long-lived server does not keep every token stream
    /// in its poll index forever.
    pub fn poll(&mut self, id: RequestId) -> RequestStatus {
        if let Some(&t) = self.finished.get(&id) {
            return match t {
                Terminal::Pending { seq, reason, n_tokens } => {
                    // the ring may already have evicted a record nobody
                    // polled in time — the stub still answers correctly
                    let status = match self.metrics.completed.get(seq) {
                        Some(c) => RequestStatus::Finished {
                            reason: c.reason,
                            tokens: c.tokens.clone(),
                        },
                        None => RequestStatus::Retired { reason, n_tokens },
                    };
                    self.finished.insert(id, Terminal::Retired { reason, n_tokens });
                    status
                }
                Terminal::Retired { reason, n_tokens } => {
                    RequestStatus::Retired { reason, n_tokens }
                }
            };
        }
        if self.batcher.waiting.iter().any(|r| r.id == id)
            || self.prefills.iter().any(|p| p.req.id == id)
            || self.retries.iter().any(|t| t.req.id == id)
        {
            // chunked prefill in flight or a retry waiting out its backoff:
            // no slot, no tokens yet — still pre-admission from the event
            // stream's point of view
            return RequestStatus::Queued;
        }
        if let Some(s) = self.batcher.slots.iter().flatten().find(|s| s.request.id == id) {
            return RequestStatus::Running { generated: s.generated.len() };
        }
        RequestStatus::Unknown
    }

    /// Cancel a queued or live request. Returns false when the id is
    /// unknown or already terminal. A live cancel retires the session this
    /// tick — its cache drops and every leased page returns to the pool.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(req) = self.batcher.remove_waiting(id) {
            self.metrics.cancelled += 1;
            self.finalize_unadmitted(id, req.prompt.len(), req.tenant, FinishReason::Cancelled);
            return true;
        }
        if let Some(pos) = self.prefills.iter().position(|p| p.req.id == id) {
            // mid-prefill cancel: dropping the pending run returns every
            // page its cache leased
            let p = self.prefills.remove(pos);
            self.metrics.cancelled += 1;
            self.finalize_unadmitted(id, p.req.prompt.len(), p.req.tenant, FinishReason::Cancelled);
            return true;
        }
        if let Some(pos) = self.retries.iter().position(|t| t.req.id == id) {
            let t = self.retries.remove(pos);
            self.metrics.cancelled += 1;
            self.finalize_unadmitted(id, t.req.prompt.len(), t.req.tenant, FinishReason::Cancelled);
            return true;
        }
        for slot in self.batcher.slots.iter_mut() {
            let hit = slot
                .as_ref()
                .map(|s| s.request.id == id && !s.is_finished())
                .unwrap_or(false);
            if hit {
                let mut sess = slot.take().unwrap();
                sess.finish(FinishReason::Cancelled);
                self.metrics.cancelled += 1;
                self.finalize(sess);
                return true;
            }
        }
        false
    }

    /// Take all lifecycle events emitted since the last drain.
    pub fn drain_events(&mut self) -> Vec<Event> {
        self.events.drain()
    }

    /// Serve a whole trace to completion — the offline/batch compatibility
    /// shim, now a thin wrapper over submit/tick: every bench and harness
    /// experiment drives this; an online server feeds `submit` from a
    /// socket and calls `tick` on its loop instead. The shim has no event
    /// consumer, so lifecycle events are discarded as it goes (use
    /// submit/tick/`drain_events` directly to observe them) — otherwise a
    /// long trace would accumulate one event per generated token.
    /// Returns the records the bounded completion ring still retains for
    /// this run — a trace longer than `ServerConfig::completed_ring` loses
    /// its oldest full records (totals and percentiles still stream over
    /// everything; size the ring to the trace when the full return
    /// matters).
    pub fn run(&mut self, requests: Vec<Request>) -> Result<Vec<Completed>> {
        self.metrics.start();
        let before = self.metrics.completed.end_seq();
        for r in requests {
            self.submit(r)?;
        }
        while self.has_work() {
            self.tick()?;
            self.events.drain();
        }
        self.events.drain();
        self.metrics.stop();
        Ok(self.metrics.completed.since(before))
    }

    /// One scheduling cycle: the tick clock advances, deadlines are
    /// enforced (queued past-deadline requests shed, live ones retire),
    /// backoff-expired retries re-enter the queue, then admissions (start
    /// chunked prefills), a budgeted round of prefill chunk work
    /// (completed prompts install into decode slots), then one decode step
    /// per live variant group; pool occupancy gauges are sampled at the
    /// end. Per-request failures inside any phase retire only that request
    /// — `Err` from a tick is reserved for batch-level contract
    /// violations, never a single tenant's fault.
    pub fn tick(&mut self) -> Result<()> {
        if self.metrics.t_start.is_none() {
            self.metrics.start();
        }
        self.ticks += 1;
        self.enforce_deadlines();
        self.release_ready_retries();
        self.admit();
        self.advance_prefills();
        self.decode()?;
        // --- reap finished ----------------------------------------------
        for sess in self.batcher.reap() {
            self.finalize(sess);
        }
        // --- occupancy gauges: leased pages + live off-pool residuals,
        // including in-flight chunked prefills' caches (their leased pages
        // are already in the pool counter; their residual rows are not) ---
        let residuals: usize = self
            .batcher
            .slots
            .iter()
            .flatten()
            .map(|s| s.cache.residual_bytes())
            .sum::<usize>()
            + self.prefills.iter().map(|p| p.cp.cache.residual_bytes()).sum::<usize>();
        self.scheduler.observe_occupancy(residuals);
        self.metrics.observe_pool(&self.pool.stats());
        if let Some(ix) = self.engine.prefix_tree() {
            let stats = ix.borrow().stats();
            self.metrics.observe_prefix(&stats);
        }
        if let Some(f) = &self.faults {
            self.metrics.observe_faults(&f.stats());
        }
        Ok(())
    }

    /// Cross-subsystem self-audit, callable between ticks (chaos soak runs
    /// it after every one; tests assert it at drain). Checks that the three
    /// independent bookkeepers — pool lease counter, cache page holders,
    /// radix-tree pin counter — agree (the tree also passes its own
    /// structural [`RadixTree::audit`]), and that every in-flight request
    /// id lives in exactly one lifecycle stage. Returns the first violation
    /// as an error; `Ok(())` means the books balance.
    pub fn check_invariants(&self) -> Result<()> {
        // 1. page accounting: every page the pool counts as leased must be
        //    held by a namable owner — a live slot's or in-flight prefill's
        //    private pages, plus each DISTINCT shared page reachable from a
        //    holder or the radix prefix tree (the pool charges shared pages
        //    once)
        let mut private = 0usize;
        let mut shared_ids: Vec<usize> = Vec::new();
        for sess in self.batcher.slots.iter().flatten() {
            private += sess.cache.private_pages();
            sess.cache.collect_shared_page_ids(&mut shared_ids);
        }
        for p in &self.prefills {
            private += p.cp.cache.private_pages();
            p.cp.cache.collect_shared_page_ids(&mut shared_ids);
        }
        if let Some(ix) = self.engine.prefix_tree() {
            let ix = ix.borrow();
            if let Err(e) = ix.audit() {
                bail!("invariant violation: radix tree audit: {e}");
            }
            let mut index_ids: Vec<usize> = Vec::new();
            ix.collect_page_ids(&mut index_ids);
            index_ids.sort_unstable();
            index_ids.dedup();
            if index_ids.len() != ix.pages_pinned() {
                bail!(
                    "invariant violation: prefix tree pins {} pages but its \
                     nodes hold {} distinct pages",
                    ix.pages_pinned(),
                    index_ids.len()
                );
            }
            shared_ids.extend_from_slice(&index_ids);
        }
        shared_ids.sort_unstable();
        shared_ids.dedup();
        let expected = private + shared_ids.len();
        let leased = self.pool.leased();
        if leased != expected {
            bail!(
                "invariant violation: pool leases {leased} pages but live \
                 holders account for {expected} ({private} private + {} \
                 distinct shared)",
                shared_ids.len()
            );
        }
        // 2. id-disjointness: each in-flight id lives in exactly one stage,
        //    and never alongside a terminal record
        let mut ids: Vec<RequestId> = Vec::new();
        ids.extend(self.batcher.waiting.iter().map(|r| r.id));
        ids.extend(self.retries.iter().map(|t| t.req.id));
        ids.extend(self.prefills.iter().map(|p| p.req.id));
        ids.extend(self.batcher.slots.iter().flatten().map(|s| s.request.id));
        let n = ids.len();
        let mut uniq = ids.clone();
        uniq.sort_unstable();
        uniq.dedup();
        if uniq.len() != n {
            bail!("invariant violation: a request id occupies two lifecycle stages");
        }
        for id in &ids {
            if self.finished.contains_key(id) {
                bail!("invariant violation: request {id} is both in flight and terminal");
            }
        }
        // 3. submit bookkeeping covers exactly the in-flight ids (terminal
        //    requests must not accumulate clock entries forever)
        for id in &ids {
            if !self.submit_times.contains_key(id) || !self.submit_ticks.contains_key(id) {
                bail!("invariant violation: in-flight request {id} has no submit record");
            }
        }
        if self.submit_times.len() != n || self.submit_ticks.len() != n {
            bail!(
                "invariant violation: {} submit-time / {} submit-tick records \
                 for {n} in-flight requests",
                self.submit_times.len(),
                self.submit_ticks.len()
            );
        }
        // 4. between ticks no retired session may still hold a slot (reap
        //    runs every tick), and retry state only exists for in-flight ids
        if self.batcher.slots.iter().flatten().any(|s| s.is_finished()) {
            bail!("invariant violation: finished session still resident after reap");
        }
        for id in self.retry_state.keys() {
            if !ids.contains(id) {
                bail!("invariant violation: retry state for request {id} not in flight");
            }
        }
        // 5. page integrity coverage: at a tick boundary every live page —
        //    reachable from a slot, an in-flight prefill, or the prefix
        //    index — is sealed under exactly one checksum entry, and no
        //    quarantined page id is reachable from any holder (a
        //    quarantined page must have been discarded, never re-issued)
        let mut live_page_ids: Vec<usize> = Vec::new();
        self.walk_pages(&mut |p, _| live_page_ids.push(p.id()));
        live_page_ids.sort_unstable();
        live_page_ids.dedup();
        let sealed_ids = self.pool.checksum_ids();
        if sealed_ids != live_page_ids {
            bail!(
                "invariant violation: {} live pages across holders but {} \
                 checksum entries in the pool (every live page must be \
                 sealed exactly once)",
                live_page_ids.len(),
                sealed_ids.len()
            );
        }
        for id in &live_page_ids {
            if self.pool.is_quarantined(*id) {
                bail!("invariant violation: quarantined page {id:#x} still reachable from a holder");
            }
        }
        Ok(())
    }

    /// Visit every live page in deterministic holder order: decode slots
    /// (slot index ascending), then in-flight prefills (admission order),
    /// then the prefix radix tree (canonical (depth, key) node order). The
    /// bool is `true` for a
    /// shared reference. The snapshot writer's page-serial numbering and
    /// the integrity audit both walk this exact order.
    fn walk_pages(&self, f: &mut dyn FnMut(&Page, bool)) {
        for sess in self.batcher.slots.iter().flatten() {
            sess.cache.for_each_page(f);
        }
        for p in &self.prefills {
            p.cp.cache.for_each_page(f);
        }
        if let Some(ix) = self.engine.prefix_tree() {
            ix.borrow().for_each_page(&mut |p| f(p, true));
        }
    }

    // --- crash-safe serving: snapshot / restore / scrub ------------------

    /// Serialize the server's complete live state to `w` (the
    /// `mixkvq-snap-v2` stream — see the crate docs, "Crash recovery &
    /// snapshot ABI"). Call **between ticks only**: `tick` is synchronous,
    /// so any point outside it is a quiesce point where every leased page
    /// is sealed and no compute is in flight. Returns the bytes written.
    ///
    /// Every page is written with its FNV-1a checksum; an armed
    /// [`FaultSite::SnapshotWrite`] plan can tear the write (stream ends
    /// after the geometry prologue, `Err` returned) and
    /// [`FaultSite::SnapshotCorrupt`] bit-flips a page's serialized arenas
    /// *after* its checksum — restore detects exactly that page.
    pub fn snapshot<W: std::io::Write>(&mut self, w: W) -> SnapResult<u64> {
        const SNAP_FAULT_CTX: u64 = 0x6d78_6b76_715f_736e; // "mxkvq_sn"
        let ordinal = self.snapshot_seq;
        self.snapshot_seq += 1;
        let mut w = SnapWriter::new(w)?;
        self.write_geometry(&mut w)?;
        if let Some(f) = &self.faults {
            if f.should_fail(FaultSite::SnapshotWrite, draw_key(SNAP_FAULT_CTX, ordinal)) {
                // torn write: the stream ends mid-prologue; a restore from
                // it fails structurally (truncation names the field) and
                // the caller keeps serving from live state
                return Err(corrupt(format!(
                    "injected torn snapshot write (ordinal {ordinal})"
                )));
            }
        }
        // scalars: the deterministic clocks a restored server continues
        w.u64(self.ticks)?;
        w.u64(self.prefill_seq)?;
        w.u64(self.snapshot_seq)?;
        let (state, inc) = self.rng.state();
        w.u64(state)?;
        w.u64(inc)?;
        w.u64(self.engine.prefix_fault_seq())?;
        // pool counters
        let ps = self.pool.stats();
        w.usize(ps.high_water)?;
        w.u64(ps.lease_failures)?;
        w.u64(ps.total_leases)?;
        // fault-injector tallies (draw *positions* live in the per-cache
        // fault seqs and the ordinals above; these are just the counters)
        match &self.faults {
            Some(f) => {
                w.bool(true)?;
                let s = f.stats();
                w.slice_u64(&s.drawn)?;
                w.slice_u64(&s.injected)?;
            }
            None => w.bool(false)?,
        }
        // pages: dedup every live page across holders into a serial space
        // (first-encounter order over the deterministic `walk_pages` walk),
        // then write each page once with its checksum
        let mut serials: HashMap<usize, u32> = HashMap::new();
        self.walk_pages(&mut |p, _| {
            let next = serials.len() as u32;
            serials.entry(p.id()).or_insert(next);
        });
        w.usize(serials.len())?;
        let pool = self.pool.clone();
        let faults = self.faults.clone();
        let corrupt_ctx = draw_key(SNAP_FAULT_CTX, ordinal);
        let mut written = vec![false; serials.len()];
        let mut page_err: Option<crate::util::snapshot::SnapError> = None;
        self.walk_pages(&mut |p, _| {
            if page_err.is_some() {
                return;
            }
            let serial = serials[&p.id()] as usize;
            if written[serial] {
                return;
            }
            written[serial] = true;
            let checksum = pool
                .sealed_checksum(p.id())
                .unwrap_or_else(|| page_checksum(&p.f, &p.b));
            let flip = faults.as_ref().is_some_and(|f| {
                f.should_fail(FaultSite::SnapshotCorrupt, draw_key(corrupt_ctx, serial as u64))
            });
            let res = (|| -> SnapResult<()> {
                if flip {
                    // bit-flip AFTER the checksum was taken: the restore
                    // verifier must catch exactly this page
                    let mut f32s = p.f.clone();
                    let mut bytes = p.b.clone();
                    if let Some(x) = f32s.first_mut() {
                        *x = f32::from_bits(x.to_bits() ^ 1);
                    } else if let Some(x) = bytes.first_mut() {
                        *x ^= 1;
                    }
                    w.slice_f32(&f32s)?;
                    w.bytes(&bytes)?;
                } else {
                    w.slice_f32(&p.f)?;
                    w.bytes(&p.b)?;
                }
                w.u64(checksum)
            })();
            if let Err(e) = res {
                page_err = Some(e);
            }
        });
        if let Some(e) = page_err {
            return Err(e);
        }
        // submit clocks (wall-clock submit_times are re-stamped on restore)
        let mut submit: Vec<(u64, u64)> =
            self.submit_ticks.iter().map(|(k, v)| (*k, *v)).collect();
        submit.sort_unstable();
        w.usize(submit.len())?;
        for (id, t) in submit {
            w.u64(id)?;
            w.u64(t)?;
        }
        // wait queue (FIFO order preserved)
        w.usize(self.batcher.waiting.len())?;
        for req in &self.batcher.waiting {
            write_request(&mut w, req)?;
        }
        // decode slots: index-exact, so variant grouping and free-slot
        // selection replay identically
        w.usize(self.batcher.slots.len())?;
        for slot in &self.batcher.slots {
            let Some(sess) = slot else {
                w.bool(false)?;
                continue;
            };
            w.bool(true)?;
            write_request(&mut w, &sess.request)?;
            w.slice_i32(&sess.generated)?;
            w.i32(sess.next_token)?;
            w.bool(sess.parked)?;
            w.u32(sess.parked_streak)?;
            // the RESOLVED method (may be a policy-degraded rung, not the
            // request's submitted spec)
            w.str(&sess.cache.method.name)?;
            sess.cache.write_snap(&mut w, &mut |id| serial_for(&serials, id))?;
        }
        // in-flight chunked prefills
        w.usize(self.prefills.len())?;
        for p in &self.prefills {
            write_request(&mut w, &p.req)?;
            w.str(&p.method.name)?;
            w.usize(p.pages_claimed)?;
            w.u64(p.arrival)?;
            p.cp.cache.write_snap(&mut w, &mut |id| serial_for(&serials, id))?;
            p.cp.run.write_snap(&mut w, &self.engine.meta.model)?;
        }
        // backoff retries + retry ladder state
        w.usize(self.retries.len())?;
        for t in &self.retries {
            write_request(&mut w, &t.req)?;
            w.u64(t.ready_tick)?;
        }
        let mut rs: Vec<(u64, RetryState)> =
            self.retry_state.iter().map(|(k, v)| (*k, *v)).collect();
        rs.sort_unstable_by_key(|(k, _)| *k);
        w.usize(rs.len())?;
        for (id, st) in rs {
            w.u64(id)?;
            w.u32(st.attempt)?;
            w.usize(st.min_rank)?;
        }
        // terminal records (poll index)
        let mut fin: Vec<(u64, Terminal)> = self.finished.iter().map(|(k, v)| (*k, *v)).collect();
        fin.sort_unstable_by_key(|(k, _)| *k);
        w.usize(fin.len())?;
        for (id, t) in fin {
            w.u64(id)?;
            match t {
                Terminal::Pending { seq, reason, n_tokens } => {
                    w.u8(0)?;
                    w.u64(seq)?;
                    w.u8(reason_tag(reason))?;
                    w.usize(n_tokens)?;
                }
                Terminal::Retired { reason, n_tokens } => {
                    w.u8(1)?;
                    w.u8(reason_tag(reason))?;
                    w.usize(n_tokens)?;
                }
            }
        }
        // prefix radix tree (nodes reference the shared page serials above)
        match self.engine.prefix_tree() {
            Some(ix) => {
                w.bool(true)?;
                ix.borrow().write_snap(&mut w, &mut |id| serial_for(&serials, id))?;
            }
            None => w.bool(false)?,
        }
        // undrained lifecycle events, then the metrics books. `snapshots`
        // is bumped BEFORE the metrics section so a restored server and the
        // uninterrupted one agree on the counter.
        self.events.write_snap(&mut w)?;
        self.metrics.snapshots += 1;
        self.metrics.write_snap(&mut w)?;
        w.finish()
    }

    /// Rebuild a server from a snapshot stream. `engine` and `cfg` must
    /// match the snapshotting process (same artifacts, budget, workers,
    /// fault plan, …) — the geometry prologue rejects gross mismatches
    /// with a named error; behavioral equivalence additionally needs the
    /// same config, which is deliberately NOT serialized (config belongs
    /// to the operator, not the snapshot).
    ///
    /// Integrity: every page's checksum is re-verified. A corrupt page is
    /// quarantined and only its owners degrade — a slot or in-flight
    /// prefill holding it retires as [`FinishReason::Error`]
    /// (`Metrics::restore_retired`), a prefix entry referencing it is
    /// dropped collision-miss-style — the load itself still succeeds.
    /// Structural damage (truncation, bad magic, misaligned trailer)
    /// fails the whole restore with a descriptive error instead.
    pub fn restore<R: std::io::Read>(engine: Engine, cfg: ServerConfig, r: R) -> SnapResult<Server> {
        let mut srv = Server::new(engine, cfg);
        let mut r = SnapReader::new(r)?;
        srv.overlay(&mut r)?;
        r.finish()?;
        Ok(srv)
    }

    /// Overlay a snapshot stream onto this freshly constructed server.
    fn overlay<R: std::io::Read>(&mut self, r: &mut SnapReader<R>) -> SnapResult<()> {
        use crate::util::faults::{FaultStats, N_FAULT_SITES};
        self.check_geometry(r)?;
        self.ticks = r.u64("server ticks")?;
        self.prefill_seq = r.u64("server prefill_seq")?;
        self.snapshot_seq = r.u64("server snapshot_seq")?;
        let state = r.u64("server rng state")?;
        let inc = r.u64("server rng inc")?;
        self.rng = Pcg32::from_state(state, inc);
        let pfs = r.u64("engine prefix_fault_seq")?;
        self.engine.set_prefix_fault_seq(pfs);
        let high_water = r.usize("pool high_water")?;
        let lease_failures = r.u64("pool lease_failures")?;
        let total_leases = r.u64("pool total_leases")?;
        self.pool.restore_counters(high_water, lease_failures, total_leases);
        if r.bool("fault stats present")? {
            let drawn = r.vec_u64("fault drawn")?;
            let injected = r.vec_u64("fault injected")?;
            if drawn.len() != N_FAULT_SITES || injected.len() != N_FAULT_SITES {
                return Err(corrupt(format!(
                    "fault counter arrays have {} sites (this build has {N_FAULT_SITES})",
                    drawn.len()
                )));
            }
            if let Some(f) = &self.faults {
                let mut s = FaultStats::default();
                s.drawn.copy_from_slice(&drawn);
                s.injected.copy_from_slice(&injected);
                f.restore_stats(&s);
            }
        }
        // pages: lease fresh storage per serial, verify the checksum, and
        // quarantine (instead of installing) anything that fails
        let n_pages = r.usize("page count")?;
        let (f_len, b_len) = self.pool.arena_dims();
        let mut quarantined = 0u64;
        let mut leases: Vec<Option<PageLease>> = Vec::with_capacity(n_pages);
        for serial in 0..n_pages {
            let f32s = r.vec_f32("page f arena")?;
            let bytes = r.bytes("page b arena")?;
            let stored = r.u64("page checksum")?;
            if f32s.len() != f_len || bytes.len() != b_len {
                return Err(corrupt(format!(
                    "page {serial} arenas are {}f32/{}b but this pool's pages \
                     are {f_len}f32/{b_len}b",
                    f32s.len(),
                    bytes.len()
                )));
            }
            let mut lease = self.pool.lease().map_err(|e| {
                corrupt(format!("pool cannot cover snapshot page {serial}: {e:#}"))
            })?;
            if page_checksum(&f32s, &bytes) != stored {
                // integrity failure: condemn the storage; the owners of
                // this serial degrade per-request when they resolve it
                self.pool.quarantine_page(lease.page().id());
                quarantined += 1;
                drop(lease);
                leases.push(None);
            } else {
                lease.page_mut().f.copy_from_slice(&f32s);
                lease.page_mut().b.copy_from_slice(&bytes);
                self.pool.seal_page(lease.page());
                leases.push(Some(lease));
            }
        }
        let pages = RefCell::new(leases);
        let shared: RefCell<Vec<Option<SharedLease>>> = RefCell::new(vec![None; n_pages]);
        let mut resolve_private = |s: u32| -> Option<PageLease> {
            pages.borrow_mut().get_mut(s as usize).and_then(Option::take)
        };
        let mut resolve_shared = |s: u32| -> Option<SharedLease> {
            let mut sh = shared.borrow_mut();
            let slot = sh.get_mut(s as usize)?;
            if slot.is_none() {
                let lease = pages.borrow_mut().get_mut(s as usize)?.take()?;
                *slot = Some(SharedLease::new(lease));
            }
            slot.clone()
        };
        // submit clocks: ticks from the snapshot, wall times re-stamped now
        let now = Instant::now();
        let n_submit = r.usize("submit-tick count")?;
        for _ in 0..n_submit {
            let id = r.u64("submit-tick id")?;
            let tick = r.u64("submit-tick tick")?;
            self.submit_ticks.insert(id, tick);
            self.submit_times.insert(id, now);
        }
        let n_waiting = r.usize("waiting count")?;
        for _ in 0..n_waiting {
            let req = read_request(r)?;
            self.batcher.waiting.push_back(req);
        }
        // decode slots — corrupt-page casualties are collected and retired
        // AFTER the metrics books are restored (so their terminal records
        // land in the restored completion log, not the scaffold's)
        let mut retired_slots: Vec<Session> = Vec::new();
        let mut retired_prefills: Vec<Request> = Vec::new();
        let n_slots = r.usize("slot count")?;
        if n_slots != self.batcher.slots.len() {
            return Err(corrupt(format!(
                "snapshot has {n_slots} decode slots, this server has {}",
                self.batcher.slots.len()
            )));
        }
        for i in 0..n_slots {
            if !r.bool("slot occupied")? {
                continue;
            }
            let req = read_request(r)?;
            let generated = r.vec_i32("session generated")?;
            let next_token = r.i32("session next_token")?;
            let parked = r.bool("session parked")?;
            let parked_streak = r.u32("session parked_streak")?;
            let method_name = r.str("session method")?;
            let method = Method::by_name(&method_name).ok_or_else(|| {
                corrupt(format!("snapshot session method `{method_name}` is unknown"))
            })?;
            self.engine.ensure_method(&method).map_err(|e| {
                corrupt(format!("loading snapshot method `{method_name}`: {e:#}"))
            })?;
            let mut cache = self.engine.new_cache_for(&method).map_err(|e| {
                corrupt(format!("rebuilding cache for `{method_name}`: {e:#}"))
            })?;
            let healthy = cache.read_snap(r, &mut resolve_private, &mut resolve_shared)?;
            let sess = Session {
                request: req,
                cache,
                generated,
                next_token,
                phase: Phase::Decoding,
                t_arrival: now,
                t_admitted: now,
                t_first_token: Some(now),
                t_finish: None,
                parked,
                parked_streak,
            };
            if healthy {
                self.batcher.slots[i] = Some(sess);
            } else {
                retired_slots.push(sess);
            }
        }
        // in-flight chunked prefills
        let n_prefills = r.usize("prefill count")?;
        for _ in 0..n_prefills {
            let req = read_request(r)?;
            let method_name = r.str("prefill method")?;
            let pages_claimed = r.usize("prefill pages_claimed")?;
            let arrival = r.u64("prefill arrival")?;
            let method = Method::by_name(&method_name).ok_or_else(|| {
                corrupt(format!("snapshot prefill method `{method_name}` is unknown"))
            })?;
            self.engine.ensure_method(&method).map_err(|e| {
                corrupt(format!("loading snapshot method `{method_name}`: {e:#}"))
            })?;
            let mut cache = self.engine.new_cache_for(&method).map_err(|e| {
                corrupt(format!("rebuilding cache for `{method_name}`: {e:#}"))
            })?;
            let healthy = cache.read_snap(r, &mut resolve_private, &mut resolve_shared)?;
            let run = PrefillRun::read_snap(r, &self.engine.meta.model)?;
            if healthy {
                self.prefills.push(PendingPrefill {
                    req,
                    method,
                    cp: ChunkedPrefill { cache, run },
                    pages_claimed,
                    arrival,
                });
            } else {
                retired_prefills.push(req);
            }
        }
        let n_retries = r.usize("retry count")?;
        for _ in 0..n_retries {
            let req = read_request(r)?;
            let ready_tick = r.u64("retry ready_tick")?;
            self.retries.push(RetryTicket { req, ready_tick });
        }
        let n_rs = r.usize("retry-state count")?;
        for _ in 0..n_rs {
            let id = r.u64("retry-state id")?;
            let attempt = r.u32("retry-state attempt")?;
            let min_rank = r.usize("retry-state min_rank")?;
            self.retry_state.insert(id, RetryState { attempt, min_rank });
        }
        let n_fin = r.usize("terminal count")?;
        for _ in 0..n_fin {
            let id = r.u64("terminal id")?;
            let t = match r.u8("terminal tag")? {
                0 => Terminal::Pending {
                    seq: r.u64("terminal seq")?,
                    reason: reason_from_tag(r.u8("terminal reason")?)?,
                    n_tokens: r.usize("terminal n_tokens")?,
                },
                1 => Terminal::Retired {
                    reason: reason_from_tag(r.u8("terminal reason")?)?,
                    n_tokens: r.usize("terminal n_tokens")?,
                },
                t => return Err(corrupt(format!("unknown terminal tag {t}"))),
            };
            self.finished.insert(id, t);
        }
        // prefix radix tree: nodes with a quarantined page drop with their
        // whole subtree (collision-miss semantics) inside read_snap
        if r.bool("prefix tree present")? {
            match self.engine.prefix_tree() {
                Some(ix) => {
                    ix.borrow_mut().read_snap(r, &mut resolve_shared)?;
                }
                None => {
                    // this config disables sharing: parse the section into
                    // a throwaway tree and let its pages free on drop
                    let mut tmp = RadixTree::new(0, self.pool.page_deploy_bytes());
                    tmp.read_snap(r, &mut resolve_shared)?;
                }
            }
        }
        self.events.read_snap(r)?;
        self.metrics.read_snap(r)?;
        // leftover leases (pages whose every owner was corrupt-retired, or
        // orphaned by a dropped index entry) return to the pool here
        drop(resolve_private);
        drop(resolve_shared);
        drop(shared);
        drop(pages);
        // the books above are the snapshot's; everything from here on is
        // this process's own history
        self.metrics.restores += 1;
        self.metrics.pages_quarantined += quarantined;
        self.metrics.start();
        for mut sess in retired_slots {
            self.metrics.restore_retired += 1;
            self.metrics.note_tenant_error(sess.request.tenant);
            sess.finish(FinishReason::Error);
            self.finalize(sess);
        }
        for req in retired_prefills {
            self.metrics.restore_retired += 1;
            self.metrics.note_tenant_error(req.tenant);
            self.finalize_unadmitted(req.id, req.prompt.len(), req.tenant, FinishReason::Error);
        }
        Ok(())
    }

    /// Live integrity scrub: re-verify every live page against its sealed
    /// checksum (the same check restore runs), quarantine mismatches, and
    /// degrade per-owner — a slot or in-flight prefill holding a corrupt
    /// page retires as [`FinishReason::Error`]; prefix entries referencing
    /// one are dropped collision-miss-style. Returns the number of pages
    /// quarantined (0 = clean bill).
    pub fn scrub(&mut self) -> usize {
        let pool = self.pool.clone();
        let mut bad: Vec<usize> = Vec::new();
        self.walk_pages(&mut |p, _| {
            if !pool.verify_page(p) {
                bad.push(p.id());
            }
        });
        bad.sort_unstable();
        bad.dedup();
        if bad.is_empty() {
            return 0;
        }
        // condemn first, so every release below discards the storage
        for &id in &bad {
            self.pool.quarantine_page(id);
        }
        let holds_bad = |cache: &crate::kvcache::cache::RequestCache| {
            let mut hit = false;
            cache.for_each_page(&mut |p, _| hit |= bad.binary_search(&p.id()).is_ok());
            hit
        };
        let victims: Vec<usize> = self
            .batcher
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().is_some_and(|s| holds_bad(&s.cache)))
            .map(|(i, _)| i)
            .collect();
        for i in victims {
            let mut sess = self.batcher.slots[i].take().unwrap();
            self.metrics.restore_retired += 1;
            self.metrics.note_tenant_error(sess.request.tenant);
            sess.finish(FinishReason::Error);
            self.finalize(sess);
        }
        let mut i = 0;
        while i < self.prefills.len() {
            if holds_bad(&self.prefills[i].cp.cache) {
                let p = self.prefills.remove(i);
                self.metrics.restore_retired += 1;
                self.metrics.note_tenant_error(p.req.tenant);
                self.finalize_unadmitted(
                    p.req.id,
                    p.req.prompt.len(),
                    p.req.tenant,
                    FinishReason::Error,
                );
            } else {
                i += 1;
            }
        }
        if let Some(ix) = self.engine.prefix_tree() {
            let mut ix = ix.borrow_mut();
            for &id in &bad {
                ix.shed_page(id);
            }
        }
        self.metrics.pages_quarantined += bad.len() as u64;
        bad.len()
    }

    /// Geometry prologue: everything the snapshot's page tables and run
    /// scratch implicitly assume about the engine. Checked field-by-field
    /// on restore so a mismatch names the offending value.
    fn write_geometry<W: std::io::Write>(&self, w: &mut SnapWriter<W>) -> SnapResult<()> {
        for (_, v) in self.geometry_fields() {
            w.usize(v)?;
        }
        w.opt_u64(self.pool.max_pages().map(|n| n as u64))
    }

    fn check_geometry<R: std::io::Read>(&self, r: &mut SnapReader<R>) -> SnapResult<()> {
        for (name, cur) in self.geometry_fields() {
            let snap = r.usize(name)?;
            if snap != cur {
                return Err(corrupt(format!(
                    "geometry mismatch: snapshot `{name}` = {snap}, this \
                     server has {cur}"
                )));
            }
        }
        let snap_max = r.opt_u64("pool max_pages")?;
        let cur_max = self.pool.max_pages().map(|n| n as u64);
        if snap_max != cur_max {
            return Err(corrupt(format!(
                "geometry mismatch: snapshot `pool max_pages` = {snap_max:?}, \
                 this server has {cur_max:?}"
            )));
        }
        Ok(())
    }

    fn geometry_fields(&self) -> [(&'static str, usize); 12] {
        let m = &self.engine.meta.model;
        let c = &self.engine.meta.cache;
        let (f_len, b_len) = self.pool.arena_dims();
        [
            ("n_layers", m.n_layers),
            ("n_kv_heads", m.n_kv_heads),
            ("d_head", m.d_head),
            ("d_model", m.d_model),
            ("vocab", m.vocab),
            ("group", c.group),
            ("capacity", c.capacity),
            ("residual", c.residual),
            ("decode_batch", c.decode_batch),
            ("r_limit", self.engine.r_limit),
            ("page f_len", f_len),
            ("page b_len", b_len),
        ]
    }

    /// Has a request with `deadline_ticks = d` submitted at `t0` expired?
    fn past_deadline(&self, t0: u64, deadline: Option<u64>) -> bool {
        deadline.is_some_and(|d| self.ticks.saturating_sub(t0) >= d)
    }

    /// Enforce tick-based per-request deadlines, most-upstream first:
    /// queued requests (and retries waiting out a backoff) past their
    /// deadline are shed from the queue — they must not stall the head —
    /// in-flight prefills drop (their leases return), and live slots
    /// retire as `DeadlineExceeded` this tick.
    fn enforce_deadlines(&mut self) {
        // queued
        let expired: Vec<RequestId> = self
            .batcher
            .waiting
            .iter()
            .filter(|r| {
                let t0 = self.submit_ticks.get(&r.id).copied().unwrap_or(self.ticks);
                self.past_deadline(t0, r.deadline_ticks)
            })
            .map(|r| r.id)
            .collect();
        for id in expired {
            if let Some(req) = self.batcher.remove_waiting(id) {
                self.metrics.deadline_shed += 1;
                self.metrics.note_tenant_deadline(req.tenant);
                self.finalize_unadmitted(
                    id,
                    req.prompt.len(),
                    req.tenant,
                    FinishReason::DeadlineExceeded,
                );
            }
        }
        // backoff retries
        let mut i = 0;
        while i < self.retries.len() {
            let r = &self.retries[i].req;
            let t0 = self.submit_ticks.get(&r.id).copied().unwrap_or(self.ticks);
            if self.past_deadline(t0, r.deadline_ticks) {
                let t = self.retries.remove(i);
                self.metrics.deadline_shed += 1;
                self.metrics.note_tenant_deadline(t.req.tenant);
                self.finalize_unadmitted(
                    t.req.id,
                    t.req.prompt.len(),
                    t.req.tenant,
                    FinishReason::DeadlineExceeded,
                );
            } else {
                i += 1;
            }
        }
        // in-flight prefills (dropping the run returns its leased pages)
        let mut i = 0;
        while i < self.prefills.len() {
            let r = &self.prefills[i].req;
            let t0 = self.submit_ticks.get(&r.id).copied().unwrap_or(self.ticks);
            if self.past_deadline(t0, r.deadline_ticks) {
                let p = self.prefills.remove(i);
                self.metrics.deadline_exceeded += 1;
                self.metrics.note_tenant_deadline(p.req.tenant);
                self.finalize_unadmitted(
                    p.req.id,
                    p.req.prompt.len(),
                    p.req.tenant,
                    FinishReason::DeadlineExceeded,
                );
            } else {
                i += 1;
            }
        }
        // live slots (reaped into terminal records later this tick)
        let now = self.ticks;
        for slot in self.batcher.slots.iter_mut() {
            let Some(sess) = slot.as_mut() else { continue };
            if sess.is_finished() {
                continue;
            }
            let t0 = self
                .submit_ticks
                .get(&sess.request.id)
                .copied()
                .unwrap_or(now);
            let expired = sess
                .request
                .deadline_ticks
                .is_some_and(|d| now.saturating_sub(t0) >= d);
            if expired {
                sess.finish(FinishReason::DeadlineExceeded);
                self.metrics.deadline_exceeded += 1;
                self.metrics.note_tenant_deadline(sess.request.tenant);
            }
        }
    }

    /// Move backoff-expired retries back into the wait queue (FIFO at the
    /// back — a retry does not jump fresh arrivals).
    fn release_ready_retries(&mut self) {
        let now = self.ticks;
        let mut i = 0;
        while i < self.retries.len() {
            if self.retries[i].ready_tick <= now {
                let t = self.retries.remove(i);
                self.batcher.waiting.push_back(t.req);
            } else {
                i += 1;
            }
        }
    }

    /// Admit up to the scheduler quota of waiting requests into chunked
    /// prefill runs. Admission is occupancy-based: the request's *exact*
    /// prefill page count (not its worst case) must fit in the pool above
    /// the reserve watermark. Short prompts lease few (or zero) pages, so
    /// many more of them run concurrently than worst-case reservation ever
    /// allowed. Each in-flight prefill holds a claim on one decode slot
    /// (installed when its run completes), so admissions are capped by
    /// `free slots − pending prefills`.
    fn admit(&mut self) {
        let free = (self.batcher.slots.len() - self.batcher.live())
            .saturating_sub(self.prefills.len());
        let quota = self.scheduler.admission_quota(free, self.batcher.waiting.len());
        for _ in 0..quota {
            let Some(req) = self.batcher.waiting.pop_front() else {
                break;
            };
            // variants validated at submit; a full prefix-tree hit charges
            // zero pages and a partial hit only its divergent tail (shared
            // pages were charged once, at registration).
            // With a policy installed the ladder has multiple rungs: walk it
            // most-preferred first and admit on the first rung whose pages
            // the pool can cover — under pressure that is a cheaper variant
            // instead of a stall.
            let ladder = self.admission_ladder(&req);
            // a request that exhausted its retries at one rung re-enters
            // pinned to the next cheaper one: rungs below min_rank already
            // failed MAX_PREFILL_ATTEMPTS times and are not offered again
            let min_rank = self
                .retry_state
                .get(&req.id)
                .map(|s| s.min_rank)
                .unwrap_or(0)
                .min(ladder.len().saturating_sub(1));
            // pages already promised to in-flight prefills but not leased
            // yet (leasing is incremental) count as spoken for
            let outstanding: usize =
                self.prefills.iter().map(PendingPrefill::outstanding_pages).sum();
            let mut chosen: Option<(Method, usize, usize)> = None;
            for (rank, method) in ladder.iter().enumerate() {
                if rank < min_rank {
                    continue;
                }
                // a rung whose page claim cannot even be derived (unknown
                // bucket/variant for this prompt) is skipped, not a tick
                // error — the ladder may still hold a serveable rung
                let Ok(needed) = self.engine.prefill_pages_for_prompt(&req.prompt, method)
                else {
                    continue;
                };
                // this admission may rest on a tree match — full hit
                // (needed == 0) or partial hit (needed covers only the
                // divergent tail). Stamp the ENTIRE matched node path
                // most-recently-used so the shed loop below cannot evict
                // the very nodes it is about to serve; touching only the
                // leaf used to leave a partial hit's interior ancestors
                // stale and sheddable mid-admission.
                self.engine.touch_prefix(&req.prompt, method);
                // under pressure, retained prefix entries yield before the
                // preferred rung degrades (their pages free if nobody else
                // holds them); only the top offered rung sheds — a lower
                // rung exists precisely to avoid evicting retained state
                if rank == min_rank {
                    while !self.scheduler.try_admit_pages(needed + outstanding)
                        && self.shed_prefix_entry()
                    {}
                }
                // shedding may have evicted the very entry this prompt hit
                // — re-derive the claim so a now-missing entry charges full
                // pages
                let Ok(needed) = self.engine.prefill_pages_for_prompt(&req.prompt, method)
                else {
                    continue;
                };
                if self.scheduler.try_admit_pages(needed + outstanding) {
                    chosen = Some((method.clone(), needed, rank));
                    break;
                }
            }
            let Some((method, needed, rank)) = chosen else {
                // not even the cheapest rung fits right now
                let cheapest_fits = ladder.last().is_some_and(|method| {
                    self.engine
                        .prefill_pages_for_prompt(&req.prompt, method)
                        .map(|n| self.scheduler.pages_admissible(n))
                        .unwrap_or(false)
                });
                if !cheapest_fits {
                    // admitted at submit against a prefix entry that has
                    // since been shed, and the pages can never fit
                    // privately — retire it rather than camp the queue head
                    self.metrics.rejected += 1;
                    self.finalize_unadmitted(
                        req.id,
                        req.prompt.len(),
                        req.tenant,
                        FinishReason::Rejected,
                    );
                    continue;
                }
                // pool below the watermark — requeue at the head (FIFO) and
                // stop admitting this cycle
                self.metrics.admission_stalls += 1;
                self.batcher.waiting.push_front(req);
                break;
            };
            if rank > min_rank {
                self.metrics.policy_degradations += 1;
            }
            // the fallible admission path: if it errors (e.g. a decode
            // artifact file missing for this method), retire just this
            // request with a terminal Rejected record — one bad tenant must
            // not abort the tick and strand every other queued/live
            // request.
            let started = (|| {
                self.engine.ensure_method(&method)?;
                self.engine.admit_prefill(&req.prompt, &method)
            })();
            match started {
                Ok((_admission, mut cp)) => {
                    // key every fault draw this request's cache will ever
                    // make to the request id — replay-deterministic per
                    // site regardless of tick composition or worker count
                    cp.cache.set_fault_key(req.id);
                    self.prefill_seq += 1;
                    self.prefills.push(PendingPrefill {
                        req,
                        method,
                        cp,
                        pages_claimed: needed,
                        arrival: self.prefill_seq,
                    })
                }
                Err(e) => {
                    self.metrics.rejected += 1;
                    eprintln!("mixkvq: admission of request {} failed: {e:#}", req.id);
                    self.finalize_unadmitted(
                        req.id,
                        req.prompt.len(),
                        req.tenant,
                        FinishReason::Rejected,
                    );
                }
            }
        }
    }

    /// Spend the tick's chunk budget on in-flight prefills,
    /// **shortest-remaining-chunks first** (stable tie-break by arrival):
    /// a short prompt admitted behind a long one finishes — and frees its
    /// decode-slot claim — without waiting for the long prompt to drain,
    /// trading a little TTFT fairness for slot turnover under mixed prompt
    /// lengths (the PR 4 ROADMAP follow-on; ticks where the round actually
    /// ran out of arrival order are counted in
    /// `EngineTimers::prefill_reorders`). Whatever completes installs into
    /// its decode slot immediately — same tick, first token sampled from
    /// the last-position logits (full prefix-tree hits arrive already
    /// complete and install first, having zero remaining chunks; partial
    /// hits resume from their divergence seam with only the tail's chunks
    /// left). A run whose
    /// remaining page claim the pool cannot currently cover (decode
    /// flushes lease directly and may drain it between ticks) is **parked**
    /// for the tick — same philosophy as the decode slots' flush parking —
    /// and resumes when pages free, instead of advancing into a failing
    /// lease and dying. A run that errors mid-flight drops (every leased
    /// page returns) and enters the bounded retry-with-degradation path —
    /// only the failing request is touched, never the tick.
    fn advance_prefills(&mut self) {
        if self.prefills.len() > 1 {
            let nl = self.engine.meta.model.n_layers;
            self.prefills
                .sort_by_key(|p| (p.remaining_chunks(nl), p.arrival));
            // a reorder tick = the round will run out of arrival order
            if self.prefills.windows(2).any(|w| w[0].arrival > w[1].arrival) {
                self.engine.timers.prefill_reorders += 1;
            }
        }
        let mut budget = self.prefill_chunks_per_tick;
        // Abundance fast path (threading-model boundary (b)): when the
        // pool can cover EVERY in-flight run's remaining page claim at
        // once, no run can park and no lease can fail for lack of pages —
        // so the tick's chunk budget is pre-allocated shortest-first on
        // the coordinator (exactly the amounts the sequential loop would
        // hand out) and the whole round goes to the engine as one batch,
        // which advances the runs concurrently. Merge is in item (SRTF)
        // order, so installs, retries, and first-token sampling happen in
        // the same order as the sequential loop at any worker count. Under
        // scarcity the legacy interleaved park-check/advance loop below
        // runs instead — identical semantics to the pre-pool-sharding
        // server, on every path, at `workers = 1`.
        let total_outstanding: usize =
            self.prefills.iter().map(PendingPrefill::outstanding_pages).sum();
        if !self.prefills.is_empty() && self.pool.can_lease(total_outstanding) {
            let nl = self.engine.meta.model.n_layers;
            let mut allocs: Vec<usize> = Vec::new();
            for p in self.prefills.iter() {
                if budget == 0 {
                    break;
                }
                let alloc = p.remaining_chunks(nl).min(budget);
                budget -= alloc;
                allocs.push(alloc);
            }
            let mut items: Vec<(&mut ChunkedPrefill, &[i32], usize)> = self
                .prefills
                .iter_mut()
                .zip(allocs.iter())
                .map(|(p, &alloc)| {
                    let PendingPrefill { req, cp, .. } = p;
                    (cp, req.prompt.as_slice(), alloc)
                })
                .collect();
            let results = self.engine.advance_prefills_parallel(&mut items);
            drop(items);
            let mut idx = 0usize;
            for res in results {
                match res {
                    Err(e) => {
                        let p = self.prefills.remove(idx);
                        self.handle_prefill_failure(p, e);
                    }
                    Ok(true) => {
                        let p = self.prefills.remove(idx);
                        self.install_prefilled(p);
                    }
                    Ok(false) => idx += 1,
                }
            }
            return;
        }
        let mut i = 0;
        while i < self.prefills.len() && budget > 0 {
            let p = &mut self.prefills[i];
            if !self.pool.can_lease(p.outstanding_pages()) {
                // pool below this run's remaining claim — sit the tick out
                self.metrics.prefill_parks += 1;
                i += 1;
                continue;
            }
            let before = p.cp.run.chunks_done();
            let res = self.engine.advance_prefill_chunked(&mut p.cp, &p.req.prompt, budget);
            budget = budget.saturating_sub(p.cp.run.chunks_done() - before);
            match res {
                Err(e) => {
                    let p = self.prefills.remove(i);
                    self.handle_prefill_failure(p, e);
                }
                Ok(true) => {
                    let p = self.prefills.remove(i);
                    self.install_prefilled(p);
                }
                Ok(false) => i += 1,
            }
        }
    }

    /// A prefill step failed — injected fault or real error. The failed run
    /// drops here (every page it leased returns to the pool) and the
    /// request enters bounded retry-with-backoff: it re-queues after an
    /// exponential tick backoff, and once `MAX_PREFILL_ATTEMPTS` failures
    /// accumulate at one admission-ladder rung it retries pinned to the
    /// next *cheaper* rung instead. A failure with no cheaper rung left
    /// retires the request as `Error`. Only the failing request is
    /// touched; the tick and every other in-flight request proceed.
    fn handle_prefill_failure(&mut self, p: PendingPrefill, e: anyhow::Error) {
        let PendingPrefill { req, .. } = p;
        let id = req.id;
        let mut st = self.retry_state.get(&id).copied().unwrap_or_default();
        st.attempt += 1;
        if st.attempt >= MAX_PREFILL_ATTEMPTS {
            if st.min_rank + 1 < self.admission_ladder(&req).len() {
                st.min_rank += 1;
                st.attempt = 0;
                self.metrics.retry_degradations += 1;
            } else {
                self.metrics.retries_exhausted += 1;
                self.metrics.note_tenant_error(req.tenant);
                eprintln!(
                    "mixkvq: request {id} failed its last prefill attempt \
                     on the cheapest rung: {e:#}"
                );
                self.finalize_unadmitted(
                    id,
                    req.prompt.len(),
                    req.tenant,
                    FinishReason::Error,
                );
                return;
            }
        }
        self.metrics.prefill_retries += 1;
        let backoff = 1u64 << st.attempt.min(6);
        self.retry_state.insert(id, st);
        self.retries.push(RetryTicket { req, ready_tick: self.ticks + backoff });
    }

    /// A completed chunked prefill becomes a live session: the prompt is
    /// registered into the prefix radix tree (a partial hit's completed
    /// tail extends the matched chain; a no-op for full hits — the chain
    /// already exists — and for duplicate prompts completing the same
    /// tick), then
    /// the first token samples from the last-position logits and the
    /// session installs into a free slot (guaranteed by the admission
    /// accounting).
    fn install_prefilled(&mut self, p: PendingPrefill) {
        let PendingPrefill { req, method, cp, .. } = p;
        let ChunkedPrefill { mut cache, run } = cp;
        let id = req.id;
        if self.retry_state.remove(&id).is_some() {
            // the request had failed at least one prefill attempt and has
            // now completed cleanly — the retry ladder did its job
            self.metrics.fault_recoveries += 1;
        }
        self.engine
            .register_prefix(&mut cache, &req.prompt, &method, run.last_logits());
        let first = sampler::sample(run.last_logits(), req.sampling, &mut self.rng);
        let max_new = req.max_new_tokens;
        let t_submit = self.submit_times.get(&id).copied().unwrap_or_else(Instant::now);
        let mut sess = Session::new(req, cache, first, t_submit);
        self.events.admitted(id, &method.name);
        self.events.first_token(id, first);
        // prompt-only edge case: the prefill sample already finishes the
        // request — record that token, and report Eos only when the
        // token actually is EOS (a 1-token budget is MaxTokens)
        if first == tokenizer::EOS {
            sess.finish(FinishReason::Eos);
            self.finalize(sess);
            return;
        }
        if max_new <= 1 {
            sess.finish(FinishReason::MaxTokens);
            self.finalize(sess);
            return;
        }
        let Some(slot) = self.batcher.free_slot() else {
            // admission accounting bug — but one stranded request must not
            // poison the tick for every other tenant: retire it as Error
            // (its cache, and every leased page, drops with the session)
            self.metrics.internal_errors += 1;
            eprintln!(
                "mixkvq: no free decode slot for completed prefill of request \
                 {id} (admission accounting bug)"
            );
            sess.finish(FinishReason::Error);
            self.finalize(sess);
            return;
        };
        self.batcher.install(slot, sess);
    }

    /// One decode step over each live (variant, rotation) sub-batch,
    /// preceded by the **parking pass**: a slot whose due quantization
    /// flush cannot lease its pages — and whose residual can no longer
    /// absorb the deferral — sits this tick out instead of erroring. When
    /// every live slot is parked (a pool deadlock: nobody can flush, nobody
    /// will free), the largest page-holder is shed as CacheFull.
    fn decode(&mut self) -> Result<()> {
        let batch = self.batcher.slots.len();
        let mut parked = vec![false; batch];
        // pool pressure: retained prefix entries yield before any live slot
        // parks — shed LRU entries until the tick's total flush demand fits
        // (or the index is empty; pages pinned only by an entry free
        // immediately, co-held pages free when their last tenant retires)
        let total_due: usize = self
            .batcher
            .slots
            .iter()
            .flatten()
            .filter(|s| !s.is_finished())
            .map(|s| s.cache.due_flush_pages())
            .sum();
        while self.pool.available() < total_due && self.shed_prefix_entry() {}
        let available = self.pool.available();
        let mut pending = 0usize;
        let mut live = 0usize;
        let mut watchdog_degrades = 0usize;
        let mut watchdog_victims: Vec<usize> = Vec::new();
        for (i, slot) in self.batcher.slots.iter_mut().enumerate() {
            let Some(sess) = slot.as_mut() else { continue };
            if sess.is_finished() {
                continue;
            }
            live += 1;
            let due = sess.cache.due_flush_pages();
            let covered = due == 0 || available.saturating_sub(pending) >= due;
            if covered {
                pending += due;
            }
            // an uncovered flush can still defer onto residual headroom —
            // but it must NOT opportunistically lease during its decode
            // step (flush_hold), or it would steal the pages this pass just
            // promised to a covered slot in a later variant group. Park
            // only when the residual is about to overflow too.
            sess.cache.flush_hold = !covered;
            if covered || sess.cache.residual_headroom() > 1 {
                sess.parked_streak = 0;
                if sess.parked {
                    sess.parked = false;
                    self.metrics.pool_resumes += 1;
                }
            } else {
                if !sess.parked {
                    sess.parked = true;
                    self.metrics.pool_parks += 1;
                    self.metrics.note_tenant_park(sess.request.tenant);
                }
                parked[i] = true;
                // park-watchdog: a slot starved for this many CONSECUTIVE
                // ticks escalates — first frees pinned prefix pages, then
                // sheds itself rather than starve forever (each threshold
                // fires once per streak; a resume resets the streak)
                sess.parked_streak += 1;
                if sess.parked_streak == PARK_WATCHDOG_SHED {
                    watchdog_victims.push(i);
                } else if sess.parked_streak == PARK_WATCHDOG_DEGRADE {
                    watchdog_degrades += 1;
                }
            }
        }
        for _ in 0..watchdog_degrades {
            if self.shed_prefix_entry() {
                self.metrics.watchdog_degrades += 1;
            }
        }
        for i in watchdog_victims {
            if let Some(sess) = self.batcher.slots[i].as_mut() {
                if !sess.is_finished() {
                    sess.finish(FinishReason::CacheFull);
                    self.metrics.watchdog_sheds += 1;
                    self.metrics.note_tenant_preempt(sess.request.tenant);
                }
            }
        }
        let n_parked = parked.iter().filter(|&&p| p).count();
        if live > 0 && n_parked == live {
            // shed the largest PRIVATE page-holder: shedding a shared-page
            // holder frees nothing while co-tenants or the index keep the
            // pages alive (skip anything a watchdog just finished)
            let victim = self
                .batcher
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| {
                    parked[*i] && s.as_ref().is_some_and(|x| !x.is_finished())
                })
                .max_by_key(|(_, s)| s.as_ref().map(|x| x.cache.private_pages()).unwrap_or(0))
                .map(|(i, _)| i);
            if let Some(i) = victim {
                // unwrap guarded: the filter above only yields occupied slots
                let sess = self.batcher.slots[i].as_mut().unwrap();
                sess.finish(FinishReason::CacheFull);
                let tenant = sess.request.tenant;
                self.metrics.pool_preemptions += 1;
                self.metrics.note_tenant_preempt(tenant);
            }
        }
        let groups = self.batcher.variant_groups();
        // record_step sees one sub-batch at a time; track true concurrency
        // (all live, unparked sessions this tick) across the groups
        let live_total: usize = groups
            .iter()
            .map(|g| g.slots.iter().filter(|&&i| !parked[i]).count())
            .sum();
        self.metrics.max_concurrent = self.metrics.max_concurrent.max(live_total);
        // Build every group's slot view at once — a request occupies
        // exactly one slot of one group, so the per-slot `&mut` borrows
        // partition — and hand the whole tick's decode work to the engine
        // in a single call: the worker pool shards it one job per live
        // slot (threading-model boundary (a)) and merges in (group, slot)
        // order, bit-identical to stepping the groups sequentially.
        let mut dgs: Vec<DecodeGroup> = Vec::new();
        {
            let mut sess_refs: Vec<Option<&mut crate::coordinator::session::Session>> =
                self.batcher.slots.iter_mut().map(Option::as_mut).collect();
            for group in &groups {
                let active: Vec<usize> =
                    group.slots.iter().copied().filter(|&i| !parked[i]).collect();
                if active.is_empty() {
                    continue; // whole sub-batch parked this tick
                }
                self.metrics.record_step(active.len(), batch);
                let rot = sess_refs[active[0]].as_ref().unwrap().cache.rot.clone();
                let mut slots: Vec<Option<(&mut crate::kvcache::cache::RequestCache, i32)>> =
                    Vec::with_capacity(batch);
                for i in 0..batch {
                    let live = active.contains(&i)
                        && sess_refs[i].as_ref().is_some_and(|s| !s.is_finished());
                    if live {
                        let sess = sess_refs[i].take().unwrap();
                        let tok = sess.next_token;
                        slots.push(Some((&mut sess.cache, tok)));
                    } else {
                        slots.push(None);
                    }
                }
                dgs.push(DecodeGroup { variant: group.variant.clone(), rot, slots });
            }
        }
        // per-slot isolation: a slot whose step failed (injected fault
        // or real append error) retires alone with `Error` — the rest
        // of the sub-batch keeps its logits, and the tick proceeds.
        // `Err` from the call itself is a batch-contract violation
        // (slot-count mismatch), never one tenant's fault.
        let step_groups = self.engine.decode_groups_isolated(&mut dgs)?;
        drop(dgs);
        for step in step_groups {
            for (i, res) in step.into_iter().enumerate() {
                let Some(res) = res else { continue };
                let Some(sess) = self.batcher.slots[i].as_mut() else { continue };
                match res {
                    Ok(lg) => {
                        if sess.cache.remaining() == 0 {
                            sess.finish(FinishReason::CacheFull);
                            continue;
                        }
                        let tok = sampler::sample(&lg, sess.request.sampling, &mut self.rng);
                        let id = sess.request.id;
                        sess.push_token(tok);
                        self.events.token(id, tok);
                    }
                    Err(e) => {
                        self.metrics.decode_errors += 1;
                        self.metrics.note_tenant_error(sess.request.tenant);
                        eprintln!(
                            "mixkvq: decode step of request {} failed: {e:#}",
                            sess.request.id
                        );
                        sess.finish(FinishReason::Error);
                    }
                }
            }
        }
        if !groups.is_empty() {
            // account live cache bytes for the peak-memory metric
            let live_bytes: usize = self
                .batcher
                .slots
                .iter()
                .flatten()
                .map(|s| s.cache.bytes_used())
                .sum();
            self.metrics.peak_mem_bytes = self.metrics.peak_mem_bytes.max(live_bytes);
        }
        Ok(())
    }

    /// Retire a session: record the completion (the session's cache — and
    /// every page it leased — drops here) and index the terminal record.
    fn finalize(&mut self, sess: Session) {
        let c = make_completed(&sess);
        self.submit_times.remove(&c.id);
        self.submit_ticks.remove(&c.id);
        self.retry_state.remove(&c.id);
        self.events.finished(c.id, c.reason, c.tokens.len());
        let (id, reason, n_tokens) = (c.id, c.reason, c.tokens.len());
        let seq = self.metrics.completed.push(c);
        self.finished.insert(id, Terminal::Pending { seq, reason, n_tokens });
    }

    /// Terminal record for a request that never reached a slot (rejected at
    /// submit or cancelled while queued).
    fn finalize_unadmitted(
        &mut self,
        id: RequestId,
        prompt_len: usize,
        tenant: u32,
        reason: FinishReason,
    ) {
        let t_submit = self.submit_times.remove(&id).unwrap_or_else(Instant::now);
        self.submit_ticks.remove(&id);
        self.retry_state.remove(&id);
        let waited = t_submit.elapsed().as_secs_f64() * 1e3;
        let c = Completed {
            id,
            prompt_len,
            tokens: Vec::new(),
            reason,
            method: "-".to_string(),
            tenant,
            ttft_ms: None,
            queue_ms: waited,
            total_ms: waited,
        };
        self.events.finished(id, reason, 0);
        let seq = self.metrics.completed.push(c);
        self.finished.insert(id, Terminal::Pending { seq, reason, n_tokens: 0 });
    }
}

/// Map a live page id to its snapshot serial. Every id reachable from
/// `walk_pages` was assigned a serial in the dedup pass, so a miss here is
/// a walk-order bug, not a data condition.
fn serial_for(serials: &HashMap<usize, u32>, id: usize) -> u32 {
    *serials
        .get(&id)
        .expect("page reachable from walk_pages but absent from serial map")
}

fn write_request<W: std::io::Write>(w: &mut SnapWriter<W>, req: &Request) -> SnapResult<()> {
    w.u64(req.id)?;
    w.slice_i32(&req.prompt)?;
    w.usize(req.max_new_tokens)?;
    match req.sampling {
        Sampling::Greedy => w.u8(0)?,
        Sampling::TopP { temperature, top_p } => {
            w.u8(1)?;
            w.f32(temperature)?;
            w.f32(top_p)?;
        }
    }
    match &req.method {
        Some(spec) => {
            w.bool(true)?;
            w.str(&spec.to_string())?;
        }
        None => w.bool(false)?,
    }
    w.u32(req.tenant)?;
    w.opt_u64(req.deadline_ticks)
}

fn read_request<R: std::io::Read>(r: &mut SnapReader<R>) -> SnapResult<Request> {
    let id = r.u64("request id")?;
    let prompt = r.vec_i32("request prompt")?;
    let max_new_tokens = r.usize("request max_new_tokens")?;
    let sampling = match r.u8("request sampling tag")? {
        0 => Sampling::Greedy,
        1 => Sampling::TopP {
            temperature: r.f32("request temperature")?,
            top_p: r.f32("request top_p")?,
        },
        t => return Err(corrupt(format!("unknown request sampling tag {t}"))),
    };
    let method = if r.bool("request has_method")? {
        let s = r.str("request method spec")?;
        Some(
            s.parse::<MethodSpec>()
                .map_err(|_| corrupt(format!("unknown method spec `{s}` in snapshot request")))?,
        )
    } else {
        None
    };
    let tenant = r.u32("request tenant")?;
    let deadline_ticks = r.opt_u64("request deadline_ticks")?;
    Ok(Request { id, prompt, max_new_tokens, sampling, method, tenant, deadline_ticks })
}

fn make_completed(sess: &Session) -> Completed {
    let ms = |t: Instant| t.duration_since(sess.t_arrival).as_secs_f64() * 1e3;
    Completed {
        id: sess.request.id,
        prompt_len: sess.request.prompt.len(),
        tokens: sess.generated.clone(),
        reason: sess.finish_reason().unwrap_or(FinishReason::MaxTokens),
        method: sess.cache.method.name.clone(),
        tenant: sess.request.tenant,
        ttft_ms: sess.t_first_token.map(ms),
        queue_ms: ms(sess.t_admitted),
        total_ms: sess.t_finish.map(ms).unwrap_or(0.0),
    }
}
