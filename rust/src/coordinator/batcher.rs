//! Continuous batcher: a fixed-slot decode batch (the compiled graph's
//! static B) fed from a FIFO wait queue — the Orca/vLLM iteration-level
//! scheduling model specialized to static shapes.

use std::collections::VecDeque;

use crate::coordinator::session::{Request, Session};

pub struct Batcher {
    pub waiting: VecDeque<Request>,
    /// Fixed decode slots (None = idle).
    pub slots: Vec<Option<Session>>,
}

impl Batcher {
    pub fn new(batch: usize) -> Batcher {
        Batcher {
            waiting: VecDeque::new(),
            slots: (0..batch).map(|_| None).collect(),
        }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.live() > 0 || !self.waiting.is_empty()
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Pop the next waiting request if a slot is free (FIFO — no
    /// starvation: the head of the queue is always admitted first).
    pub fn next_admission(&mut self) -> Option<(usize, Request)> {
        let slot = self.free_slot()?;
        let req = self.waiting.pop_front()?;
        Some((slot, req))
    }

    pub fn install(&mut self, slot: usize, session: Session) {
        debug_assert!(self.slots[slot].is_none());
        self.slots[slot] = Some(session);
    }

    /// Remove finished sessions, returning them.
    pub fn reap(&mut self) -> Vec<Session> {
        let mut done = Vec::new();
        for s in self.slots.iter_mut() {
            if s.as_ref().map(|x| x.is_finished()).unwrap_or(false) {
                done.push(s.take().unwrap());
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::FinishReason;
    use crate::kvcache::cache::RequestCache;
    use crate::model::config::{CacheConfig, ModelConfig};
    use crate::model::sampler::Sampling;
    use crate::quant::methods::Method;
    use crate::quant::window::TierSpec;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1], max_new_tokens: 8, sampling: Sampling::Greedy }
    }

    fn session(id: u64) -> Session {
        let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let cache = RequestCache::new(
            &mc,
            &cc,
            &[TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }],
            Method::bf16(),
            32,
        );
        Session::new(req(id), cache, 5, Instant::now())
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(2);
        b.enqueue(req(1));
        b.enqueue(req(2));
        b.enqueue(req(3));
        let (s0, r0) = b.next_admission().unwrap();
        assert_eq!((s0, r0.id), (0, 1));
        b.install(0, session(1));
        let (s1, r1) = b.next_admission().unwrap();
        assert_eq!((s1, r1.id), (1, 2));
        b.install(1, session(2));
        assert!(b.next_admission().is_none(), "no free slot");
        assert_eq!(b.waiting.len(), 1);
    }

    #[test]
    fn reap_frees_slots() {
        let mut b = Batcher::new(2);
        b.install(0, session(1));
        b.install(1, session(2));
        b.slots[0].as_mut().unwrap().finish(FinishReason::Eos);
        let done = b.reap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 1);
        assert_eq!(b.live(), 1);
        assert_eq!(b.free_slot(), Some(0));
    }

    #[test]
    fn has_work_tracks_queue_and_slots() {
        let mut b = Batcher::new(1);
        assert!(!b.has_work());
        b.enqueue(req(1));
        assert!(b.has_work());
        let (slot, _r) = b.next_admission().unwrap();
        b.install(slot, session(1));
        assert!(b.has_work());
    }
}
