//! Continuous batcher: a fixed-slot decode batch (the compiled graph's
//! static B) fed from a FIFO wait queue — the Orca/vLLM iteration-level
//! scheduling model specialized to static shapes.
//!
//! Slots are not method-homogeneous: each session carries its own
//! quantization method, and [`Batcher::variant_groups`] partitions the live
//! slots into per-(decode variant, rotation) sub-batches — one compiled
//! graph execution each — so tenants with different precision policies
//! share the same server.

use std::collections::VecDeque;

use crate::coordinator::session::{Request, RequestId, Session};

pub struct Batcher {
    pub waiting: VecDeque<Request>,
    /// Fixed decode slots (None = idle).
    pub slots: Vec<Option<Session>>,
}

impl Batcher {
    pub fn new(batch: usize) -> Batcher {
        Batcher {
            waiting: VecDeque::new(),
            slots: (0..batch).map(|_| None).collect(),
        }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.live() > 0 || !self.waiting.is_empty()
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Pop the next waiting request if a slot is free (FIFO — no
    /// starvation: the head of the queue is always admitted first).
    pub fn next_admission(&mut self) -> Option<(usize, Request)> {
        let slot = self.free_slot()?;
        let req = self.waiting.pop_front()?;
        Some((slot, req))
    }

    pub fn install(&mut self, slot: usize, session: Session) {
        debug_assert!(self.slots[slot].is_none());
        self.slots[slot] = Some(session);
    }

    /// Remove a request from the wait queue (cancellation before admission).
    pub fn remove_waiting(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.waiting.iter().position(|r| r.id == id)?;
        self.waiting.remove(pos)
    }

    /// Partition live, unfinished slots into decode sub-batches keyed by
    /// (decode variant, rotation). Each group is one execution of that
    /// variant's compiled graph; the key includes rotation because the `rot`
    /// matrix is a whole-batch graph input (RotateKV cannot share an
    /// execution with an unrotated method even on the same variant shapes).
    /// Groups are ordered by first-occupied slot, members by slot index, so
    /// sampling order is deterministic.
    pub fn variant_groups(&self) -> Vec<VariantGroup> {
        let mut groups: Vec<VariantGroup> = Vec::new();
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(sess) = slot else { continue };
            if sess.is_finished() {
                continue;
            }
            let variant = sess.cache.method.variant.as_str();
            let rotate = sess.cache.method.rotate;
            match groups.iter_mut().find(|g| g.variant == variant && g.rotate == rotate) {
                Some(g) => g.slots.push(i),
                None => groups.push(VariantGroup {
                    variant: variant.to_string(),
                    rotate,
                    slots: vec![i],
                }),
            }
        }
        groups
    }

    /// Remove finished sessions, returning them.
    pub fn reap(&mut self) -> Vec<Session> {
        let mut done = Vec::new();
        for s in self.slots.iter_mut() {
            if s.as_ref().map(|x| x.is_finished()).unwrap_or(false) {
                // unwrap guarded: the branch condition only holds for an
                // occupied slot, so take() always yields Some here
                done.push(s.take().unwrap());
            }
        }
        done
    }
}

/// One decode sub-batch: the slot indices sharing a (variant, rotation) key.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantGroup {
    pub variant: String,
    pub rotate: bool,
    pub slots: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::FinishReason;
    use crate::kvcache::cache::RequestCache;
    use crate::model::config::{CacheConfig, ModelConfig};
    use crate::model::sampler::Sampling;
    use crate::quant::methods::Method;
    use crate::quant::window::TierSpec;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1],
            max_new_tokens: 8,
            sampling: Sampling::Greedy,
            method: None,
            tenant: 0,
            deadline_ticks: None,
        }
    }

    fn session_with(id: u64, method: Method) -> Session {
        let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let cache = RequestCache::new(
            &mc,
            &cc,
            &[TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }],
            method,
            32,
        );
        Session::new(req(id), cache, 5, Instant::now())
    }

    fn session(id: u64) -> Session {
        session_with(id, Method::bf16())
    }

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new(2);
        b.enqueue(req(1));
        b.enqueue(req(2));
        b.enqueue(req(3));
        let (s0, r0) = b.next_admission().unwrap();
        assert_eq!((s0, r0.id), (0, 1));
        b.install(0, session(1));
        let (s1, r1) = b.next_admission().unwrap();
        assert_eq!((s1, r1.id), (1, 2));
        b.install(1, session(2));
        assert!(b.next_admission().is_none(), "no free slot");
        assert_eq!(b.waiting.len(), 1);
    }

    #[test]
    fn reap_frees_slots() {
        let mut b = Batcher::new(2);
        b.install(0, session(1));
        b.install(1, session(2));
        b.slots[0].as_mut().unwrap().finish(FinishReason::Eos);
        let done = b.reap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request.id, 1);
        assert_eq!(b.live(), 1);
        assert_eq!(b.free_slot(), Some(0));
    }

    #[test]
    fn has_work_tracks_queue_and_slots() {
        let mut b = Batcher::new(1);
        assert!(!b.has_work());
        b.enqueue(req(1));
        assert!(b.has_work());
        let (slot, _r) = b.next_admission().unwrap();
        b.install(slot, session(1));
        assert!(b.has_work());
    }

    #[test]
    fn remove_waiting_preserves_order() {
        let mut b = Batcher::new(1);
        b.enqueue(req(1));
        b.enqueue(req(2));
        b.enqueue(req(3));
        assert_eq!(b.remove_waiting(2).unwrap().id, 2);
        assert!(b.remove_waiting(9).is_none());
        let ids: Vec<u64> = b.waiting.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn variant_groups_key_on_variant_and_rotation() {
        let mut b = Batcher::new(6);
        b.install(0, session_with(0, Method::kivi("kv2")));
        b.install(1, session_with(1, Method::bf16()));
        b.install(2, session_with(2, Method::skvq("kv2"))); // same graph as kivi-kv2
        b.install(3, session_with(3, Method::rotatekv("kv2"))); // same variant, rotated
        b.install(5, session_with(5, Method::kivi("kv2")));
        b.slots[5].as_mut().unwrap().finish(FinishReason::Eos); // excluded
        let groups = b.variant_groups();
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].variant, "kv2");
        assert!(!groups[0].rotate);
        assert_eq!(groups[0].slots, vec![0, 2]);
        assert_eq!(groups[1].variant, "bf16");
        assert_eq!(groups[1].slots, vec![1]);
        assert_eq!(groups[2], VariantGroup { variant: "kv2".into(), rotate: true, slots: vec![3] });
    }

    #[test]
    fn single_method_batch_is_one_group() {
        let mut b = Batcher::new(3);
        b.install(0, session_with(0, Method::mixkvq("mix30")));
        b.install(2, session_with(2, Method::mixkvq("mix30")));
        let groups = b.variant_groups();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].slots, vec![0, 2]);
        assert_eq!(groups[0].variant, "mix30");
    }
}
