//! Per-request lifecycle events emitted by the serving frontend.
//!
//! Every request produces a well-formed stream:
//!
//! ```text
//! Queued → Admitted{method} → FirstToken → Token* → Finished{reason}
//! ```
//!
//! Requests that never reach a slot (rejected at submit, cancelled while
//! queued) produce `Queued → Finished{Rejected|Cancelled}` with no
//! `Admitted`. [`validate_stream`] checks the shape; the property suite in
//! `proptest_invariants.rs` sweeps it against randomized schedules and the
//! integration tests check it against the real engine.

use crate::coordinator::session::{FinishReason, RequestId};

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Request accepted into the wait queue.
    Queued { id: RequestId },
    /// Request admitted into a decode slot, prefilled under `method` (the
    /// resolved per-request quantization policy).
    Admitted { id: RequestId, method: String },
    /// The first sampled token (produced by the prefill logits).
    FirstToken { id: RequestId, token: i32 },
    /// A subsequent decode-step token.
    Token { id: RequestId, token: i32 },
    /// Terminal event; `tokens` is the total generated count.
    Finished { id: RequestId, reason: FinishReason, tokens: usize },
}

impl Event {
    pub fn id(&self) -> RequestId {
        match *self {
            Event::Queued { id }
            | Event::Admitted { id, .. }
            | Event::FirstToken { id, .. }
            | Event::Token { id, .. }
            | Event::Finished { id, .. } => id,
        }
    }
}

/// Append-only event buffer drained by `Server::drain_events`.
#[derive(Default)]
pub struct EventLog {
    buf: Vec<Event>,
}

impl EventLog {
    pub fn queued(&mut self, id: RequestId) {
        self.buf.push(Event::Queued { id });
    }

    pub fn admitted(&mut self, id: RequestId, method: &str) {
        self.buf.push(Event::Admitted { id, method: method.to_string() });
    }

    pub fn first_token(&mut self, id: RequestId, token: i32) {
        self.buf.push(Event::FirstToken { id, token });
    }

    pub fn token(&mut self, id: RequestId, token: i32) {
        self.buf.push(Event::Token { id, token });
    }

    pub fn finished(&mut self, id: RequestId, reason: FinishReason, tokens: usize) {
        self.buf.push(Event::Finished { id, reason, tokens });
    }

    pub fn drain(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.buf)
    }

    /// Serialize the undrained buffer (events emitted since the caller's
    /// last `drain_events`; a restored server re-delivers them).
    pub fn write_snap<W: std::io::Write>(
        &self,
        w: &mut crate::util::snapshot::SnapWriter<W>,
    ) -> crate::util::snapshot::SnapResult<()> {
        w.usize(self.buf.len())?;
        for e in &self.buf {
            w.u64(e.id())?;
            match e {
                Event::Queued { .. } => w.u8(0)?,
                Event::Admitted { method, .. } => {
                    w.u8(1)?;
                    w.str(method)?;
                }
                Event::FirstToken { token, .. } => {
                    w.u8(2)?;
                    w.i32(*token)?;
                }
                Event::Token { token, .. } => {
                    w.u8(3)?;
                    w.i32(*token)?;
                }
                Event::Finished { reason, tokens, .. } => {
                    w.u8(4)?;
                    w.u8(reason_tag(*reason))?;
                    w.usize(*tokens)?;
                }
            }
        }
        Ok(())
    }

    /// Replace the buffer with snapshotted pending events.
    pub fn read_snap<R: std::io::Read>(
        &mut self,
        r: &mut crate::util::snapshot::SnapReader<R>,
    ) -> crate::util::snapshot::SnapResult<()> {
        use crate::util::snapshot::corrupt;
        let n = r.usize("event count")?;
        let mut buf = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let id = r.u64("event id")?;
            let tag = r.u8("event tag")?;
            buf.push(match tag {
                0 => Event::Queued { id },
                1 => Event::Admitted { id, method: r.str("event method")? },
                2 => Event::FirstToken { id, token: r.i32("event token")? },
                3 => Event::Token { id, token: r.i32("event token")? },
                4 => {
                    let reason = reason_from_tag(r.u8("finish reason")?)?;
                    let tokens = r.usize("finished tokens")?;
                    Event::Finished { id, reason, tokens }
                }
                t => return Err(corrupt(format!("unknown event tag {t}"))),
            });
        }
        self.buf = buf;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Stable wire tag for a [`FinishReason`] (snapshot ABI — append-only).
pub fn reason_tag(reason: FinishReason) -> u8 {
    match reason {
        FinishReason::Eos => 0,
        FinishReason::MaxTokens => 1,
        FinishReason::CacheFull => 2,
        FinishReason::Cancelled => 3,
        FinishReason::Rejected => 4,
        FinishReason::Error => 5,
        FinishReason::DeadlineExceeded => 6,
    }
}

/// Inverse of [`reason_tag`]; unknown tags are a corrupt-stream error.
pub fn reason_from_tag(tag: u8) -> crate::util::snapshot::SnapResult<FinishReason> {
    Ok(match tag {
        0 => FinishReason::Eos,
        1 => FinishReason::MaxTokens,
        2 => FinishReason::CacheFull,
        3 => FinishReason::Cancelled,
        4 => FinishReason::Rejected,
        5 => FinishReason::Error,
        6 => FinishReason::DeadlineExceeded,
        t => {
            return Err(crate::util::snapshot::corrupt(format!(
                "unknown finish-reason tag {t}"
            )))
        }
    })
}

/// Check that one request's event stream is well-formed:
/// starts with exactly one `Queued`; if admitted, exactly one `Admitted`,
/// then one `FirstToken` before any `Token`, generated count (1 + #`Token`)
/// within `max_new_tokens` (floored at 1: the prefill sample always exists);
/// exactly one terminal `Finished`, last, with a consistent token count.
pub fn validate_stream(events: &[Event], max_new_tokens: usize) -> Result<(), String> {
    if events.is_empty() {
        return Err("empty stream".into());
    }
    if !matches!(events[0], Event::Queued { .. }) {
        return Err(format!("stream must start with Queued, got {:?}", events[0]));
    }
    let id = events[0].id();
    if events.iter().any(|e| e.id() != id) {
        return Err("mixed request ids in one stream".into());
    }
    let count = |f: fn(&Event) -> bool| events.iter().filter(|e| f(e)).count();
    if count(|e| matches!(e, Event::Queued { .. })) != 1 {
        return Err("more than one Queued".into());
    }
    let n_finished = count(|e| matches!(e, Event::Finished { .. }));
    if n_finished != 1 {
        return Err(format!("want exactly one Finished, got {n_finished}"));
    }
    let Some(Event::Finished { reason, tokens, .. }) = events.last() else {
        return Err("Finished must be the terminal event".into());
    };
    let n_admitted = count(|e| matches!(e, Event::Admitted { .. }));
    let first_pos = events.iter().position(|e| matches!(e, Event::FirstToken { .. }));
    let n_tokens = count(|e| matches!(e, Event::Token { .. }));
    match n_admitted {
        0 => {
            // never admitted: no token events, terminal reason must say why
            if first_pos.is_some() || n_tokens > 0 {
                return Err("tokens emitted without admission".into());
            }
            // Error covers exhausted prefill retries; DeadlineExceeded a
            // request shed from queue/backoff/prefill before admission
            if !matches!(
                reason,
                FinishReason::Rejected
                    | FinishReason::Cancelled
                    | FinishReason::Error
                    | FinishReason::DeadlineExceeded
            ) {
                return Err(format!("unadmitted stream finished with {reason:?}"));
            }
            if *tokens != 0 {
                return Err("unadmitted stream reports generated tokens".into());
            }
        }
        1 => {
            let adm = events.iter().position(|e| matches!(e, Event::Admitted { .. })).unwrap();
            let Some(first) = first_pos else {
                return Err("admitted stream missing FirstToken".into());
            };
            if first < adm {
                return Err("FirstToken precedes Admitted".into());
            }
            if count(|e| matches!(e, Event::FirstToken { .. })) != 1 {
                return Err("more than one FirstToken".into());
            }
            if events.iter().take(first).any(|e| matches!(e, Event::Token { .. })) {
                return Err("Token precedes FirstToken".into());
            }
            let generated = 1 + n_tokens;
            if generated > max_new_tokens.max(1) {
                return Err(format!(
                    "generated {generated} tokens > max_new_tokens {max_new_tokens}"
                ));
            }
            if *tokens != generated {
                return Err(format!(
                    "Finished reports {tokens} tokens, stream has {generated}"
                ));
            }
        }
        n => return Err(format!("want at most one Admitted, got {n}")),
    }
    Ok(())
}

/// Group a drained event buffer by request id, preserving order.
pub fn by_request(events: &[Event]) -> Vec<(RequestId, Vec<Event>)> {
    let mut out: Vec<(RequestId, Vec<Event>)> = Vec::new();
    for e in events {
        match out.iter_mut().find(|(id, _)| *id == e.id()) {
            Some((_, v)) => v.push(e.clone()),
            None => out.push((e.id(), vec![e.clone()])),
        }
    }
    out
}

/// Status view returned by `Server::poll`.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestStatus {
    /// Never submitted (or submitted to a different server).
    Unknown,
    /// Waiting for a free decode slot / memory reservation.
    Queued,
    /// Live in a decode slot with `generated` tokens so far.
    Running { generated: usize },
    /// Terminal, with the finish reason and the generated tokens. Returned
    /// by the FIRST poll that observes the terminal state; the server then
    /// evicts the full record and later polls see [`RequestStatus::Retired`].
    Finished { reason: FinishReason, tokens: Vec<i32> },
    /// Terminal and already observed once: only the reason and the token
    /// count remain (the full record was evicted — `Server::poll` docs).
    Retired { reason: FinishReason, n_tokens: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn good(id: RequestId) -> Vec<Event> {
        vec![
            Event::Queued { id },
            Event::Admitted { id, method: "bf16".into() },
            Event::FirstToken { id, token: 5 },
            Event::Token { id, token: 6 },
            Event::Token { id, token: 7 },
            Event::Finished { id, reason: FinishReason::MaxTokens, tokens: 3 },
        ]
    }

    #[test]
    fn accepts_well_formed_stream() {
        assert_eq!(validate_stream(&good(1), 3), Ok(()));
        // unadmitted terminal shapes
        let rejected = vec![
            Event::Queued { id: 2 },
            Event::Finished { id: 2, reason: FinishReason::Rejected, tokens: 0 },
        ];
        assert_eq!(validate_stream(&rejected, 8), Ok(()));
    }

    #[test]
    fn rejects_malformed_streams() {
        // token budget exceeded
        assert!(validate_stream(&good(1), 2).is_err());
        // missing FirstToken
        let mut s = good(1);
        s.remove(2);
        assert!(validate_stream(&s, 3).is_err());
        // double Finished
        let mut s = good(1);
        s.push(Event::Finished { id: 1, reason: FinishReason::Eos, tokens: 3 });
        assert!(validate_stream(&s, 3).is_err());
        // Finished not last
        let mut s = good(1);
        let fin = s.remove(5);
        s.insert(3, fin);
        assert!(validate_stream(&s, 3).is_err());
        // Token before FirstToken
        let mut s = good(1);
        s.swap(2, 3);
        assert!(validate_stream(&s, 3).is_err());
        // unadmitted stream with a normal finish reason
        let s = vec![
            Event::Queued { id: 3 },
            Event::Finished { id: 3, reason: FinishReason::Eos, tokens: 0 },
        ];
        assert!(validate_stream(&s, 8).is_err());
    }

    #[test]
    fn log_snapshot_round_trips_pending_events() {
        use crate::util::snapshot::{SnapReader, SnapWriter};
        let mut log = EventLog::default();
        log.queued(7);
        log.admitted(7, "k2-v2-g32");
        log.first_token(7, -3);
        log.token(7, 11);
        log.finished(7, FinishReason::DeadlineExceeded, 2);
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        log.write_snap(&mut w).unwrap();
        w.finish().unwrap();

        let mut log2 = EventLog::default();
        let mut r = SnapReader::new(&buf[..]).unwrap();
        log2.read_snap(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(log2.drain(), log.drain());

        // every finish reason survives its wire tag
        for reason in [
            FinishReason::Eos,
            FinishReason::MaxTokens,
            FinishReason::CacheFull,
            FinishReason::Cancelled,
            FinishReason::Rejected,
            FinishReason::Error,
            FinishReason::DeadlineExceeded,
        ] {
            assert_eq!(reason_from_tag(reason_tag(reason)).unwrap(), reason);
        }
        assert!(reason_from_tag(9).is_err());
    }

    #[test]
    fn log_drains_in_order_and_groups() {
        let mut log = EventLog::default();
        log.queued(1);
        log.queued(2);
        log.admitted(1, "bf16");
        log.first_token(1, 9);
        assert_eq!(log.len(), 4);
        let events = log.drain();
        assert!(log.is_empty());
        let grouped = by_request(&events);
        assert_eq!(grouped.len(), 2);
        assert_eq!(grouped[0].0, 1);
        assert_eq!(grouped[0].1.len(), 3);
        assert_eq!(grouped[1].1, vec![Event::Queued { id: 2 }]);
    }
}
