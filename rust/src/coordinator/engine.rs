//! The inference engine: owns the PJRT runtime, the model weights, and the
//! per-request quantized caches; builds batched decode-step inputs in the
//! exact manifest order and folds the outputs back into the caches.
//!
//! One engine holds a *pool* of compiled decode variants (tier shapes are
//! compile-time, so each variant is its own executable) behind one shared
//! runtime, weight upload, and prefill graph set. `method`/`variant` name
//! the engine's default; requests carrying a `MethodSpec` override are
//! admitted with their own method's cache ([`Engine::quantize_prefill_with`])
//! and decoded through their variant's graph
//! ([`Engine::decode_step_isolated`]) — the server's batcher groups live
//! slots into per-variant sub-batches each step, and
//! [`Engine::decode_groups_isolated`] fans a whole tick's groups across
//! the engine's worker pool (crate docs, "Threading model").

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::kvcache::accountant::MemoryAccountant;
use crate::kvcache::cache::{PageField, RequestCache};
use crate::kvcache::pool::{prefix_seed, prompt_chain_key, KvPool};
use crate::kvcache::radix::{PrefixPeek, PrefixProbe, RadixTree};
use crate::model::config::{Meta, VariantSpec};
use crate::model::reference::{DecodeScratch, PrefillRun, RefModel, RopeTable};
use crate::model::weights::{ParamIndex, Weights};
use crate::quant::methods::{Method, MethodSpec};
use crate::runtime::client::Runtime;
use crate::runtime::executor::{upload, Arg, DeviceArg, Executable};
use crate::runtime::registry::{decode_artifact, pick_bucket, prefill_artifact, DType};
use crate::util::faults::{draw_key, FaultInjector, FaultSite};
use crate::util::workers::WorkerPool;

/// Prefill products shaped for RequestCache::load_prefill.
pub struct PrefillData {
    /// per-layer [Hkv * t * dh]
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// per-layer [Hkv * dh]
    pub qabs: Vec<Vec<f32>>,
    pub t: usize,
    pub last_logits: Vec<f32>,
}

/// Wall-time breakdown counters (Table 7).
#[derive(Default, Clone, Debug)]
pub struct EngineTimers {
    pub decode_exec_ns: u64,
    pub prefill_exec_ns: u64,
    pub quantize_ns: u64,
    pub assemble_ns: u64,
    pub decode_steps: u64,
    pub quantize_events: u64,
    /// Decode steps whose arg buffers came from the per-variant scratch
    /// pool (steady-state: every step after a variant's first).
    pub assemble_reuses: u64,
    /// Decode steps that had to allocate a variant's arg buffers (once per
    /// variant per process in steady state).
    pub assemble_builds: u64,
    /// Total bytes currently held by the pooled per-variant decode-arg
    /// buffers (recomputed each step, so error paths can't skew it). A
    /// reused step saves re-allocating its own variant's share of this.
    pub scratch_bytes: u64,
    /// (layer, chunk) units processed by the chunked prefill pipeline —
    /// the admission scheduler's unit of prefill work per tick.
    pub prefill_chunks: u64,
    /// Prompt tokens whose chunked prefill completed (prefill tok/s =
    /// `prefill_tokens / prefill_exec_ns`).
    pub prefill_tokens: u64,
    /// (layer, chunk) units NEVER executed because the prompt hit the
    /// shared prefix tree (fully or up to a partial-hit seam) — the
    /// compute half of the sharing win (`prefill_chunks` counts only
    /// units that actually ran).
    pub prefill_chunks_skipped: u64,
    /// Ticks whose in-flight prefill round ran in non-FIFO order because
    /// shortest-remaining-chunks scheduling promoted a shorter prompt.
    pub prefill_reorders: u64,
    /// Per-worker busy nanoseconds inside worker-pool jobs (index =
    /// worker id; worker 0 is the coordinator thread running its own
    /// share inline). Len 0 until a pool is installed; len 1 at
    /// `workers = 1`.
    pub worker_busy_ns: Vec<u64>,
    /// Per-worker job counts — the dispatch-imbalance gauge's raw data.
    pub worker_jobs: Vec<u64>,
    /// Ticks that used the parallel decode/prefill paths (`workers > 1`
    /// with more than one unit of work to shard).
    pub parallel_ticks: u64,
}

impl EngineTimers {
    /// Effective parallel speedup over the worker-pool sections:
    /// `sum(busy) / max(busy)` — how many workers' worth of compute the
    /// pool actually extracted (1.0 = single-threaded, `n` = perfectly
    /// balanced across `n` workers).
    pub fn parallel_speedup(&self) -> f64 {
        let max = self.worker_busy_ns.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        self.worker_busy_ns.iter().sum::<u64>() as f64 / max as f64
    }

    /// Dispatch imbalance across workers in [0, 1]: `(max - min) / max`
    /// over per-worker busy time. 0 = perfectly even; values near 1 mean
    /// one worker did nearly all the work (sharding is not helping).
    pub fn dispatch_imbalance(&self) -> f64 {
        let max = self.worker_busy_ns.iter().copied().max().unwrap_or(0);
        if max == 0 || self.worker_busy_ns.len() < 2 {
            return 0.0;
        }
        let min = self.worker_busy_ns.iter().copied().min().unwrap_or(0);
        (max - min) as f64 / max as f64
    }
}

/// An in-flight chunked prefill: the request's cache (quantized pages fill
/// in as layers close) plus the resumable [`PrefillRun`]. Advanced a
/// bounded number of (layer, chunk) units per serving tick by
/// [`Engine::advance_prefill_chunked`], so a long prompt no longer
/// monopolizes a tick against live decoders.
pub struct ChunkedPrefill {
    pub cache: RequestCache,
    pub run: PrefillRun,
}

/// How [`Engine::admit_prefill`] satisfied a prompt against the radix
/// prefix tree — the unified admission verdict the router's scheduler and
/// the metrics layer both key off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillAdmission {
    /// The whole prompt was registered: pages/residual/logits adopted
    /// bit-exactly, the run arrives already complete, zero chunks execute.
    FullHit,
    /// A group-aligned strict prefix was registered: the cache adopted
    /// `matched_tokens` of shared pages under the producer's frozen channel
    /// plan, and the chunked prefill resumes at `seam` (`== matched_tokens`)
    /// instead of token 0.
    PartialHit { matched_tokens: usize, seam: usize },
    /// No usable prefix: a full chunked prefill runs from token 0.
    Miss,
}

/// One variant sub-batch of a serving tick, shaped for
/// [`Engine::decode_groups_isolated`]: the batcher's per-variant slot
/// grouping with each live slot holding its request's cache and next
/// token. Groups are independent by construction (a request occupies
/// exactly one slot of one group), which is what lets the engine fan a
/// whole tick's slots across the worker pool.
pub struct DecodeGroup<'c> {
    pub variant: String,
    pub rot: Vec<f32>,
    pub slots: Vec<Option<(&'c mut RequestCache, i32)>>,
}

pub struct Engine {
    /// Compiled-graph backend. `None` = pure-Rust reference backend
    /// ([`Engine::new_reference`]): prefill already runs through the
    /// chunked reference pipeline, and decode dispatches per-slot through
    /// `RefModel::decode_step_into` — no PJRT runtime, no artifacts. The
    /// serving layers (admission, paging, batching, policy) are identical
    /// either way, which is what the traffic/policy harnesses exercise.
    runtime: Option<Runtime>,
    pub meta: Meta,
    pub weights: Weights,
    /// Default decode variant (requests without a `MethodSpec` override).
    pub variant: VariantSpec,
    /// Default quantization method.
    pub method: Method,
    pub r_limit: usize,
    pub timers: EngineTimers,
    artifacts_dir: PathBuf,
    rot: Vec<f32>,
    /// Weights uploaded to the device ONCE (§Perf: saves ~2.4 MB of host
    /// literal construction + transfer per decode step).
    weight_bufs: Vec<DeviceArg>,
    /// Per-variant pooled decode-arg buffers, keyed by decode artifact
    /// name: allocated on a variant's first step, refilled in place every
    /// step after (§Perf: the dominant per-step assembly allocations —
    /// the full K/V window gathers — are amortized; small per-step clones
    /// of the variant spec/rotation remain and are noise by comparison).
    arg_pool: HashMap<String, Vec<Owned>>,
    /// Shared KV page pool caches lease from (`Server::new` installs the
    /// bounded serving pool); `None` gives each cache a private unbounded
    /// pool — standalone engine use, benches, tests.
    kv_pool: Option<KvPool>,
    /// Cross-request radix prefix tree (`Server::new` installs it alongside
    /// the pool): [`Engine::admit_prefill`] probes it before running a
    /// single chunk, and completed prefills register into it. `None`
    /// disables sharing (standalone engine use).
    prefix_tree: Option<Rc<RefCell<RadixTree>>>,
    /// Frozen-plan (partial-hit) override: `Some(v)` forces partial
    /// adoption on/off; `None` defers to the per-method default
    /// ([`frozen_plan_default`]). Full hits are served either way.
    frozen_plan: Option<bool>,
    /// Prebuilt reference-model lookup parts for the chunked prefill path —
    /// resolved once per engine so the per-tick advance does not redo
    /// name-resolution lookups (`RefModel::with_parts`).
    ref_pidx: ParamIndex,
    ref_rope: RopeTable,
    /// Reference-backend decode arena, reused across steps (same shape as
    /// `RefDriver`'s per-driver scratch). `None` until the first reference
    /// decode step; unused on the compiled backend.
    ref_scratch: Option<DecodeScratch>,
    /// Deterministic fault injection (chaos testing), shared with the
    /// server and the pool. `None` (the default) makes every hook free.
    /// Draws are stateless keyed functions of `(plan.seed, site, key)`
    /// (util::faults), so sharing the injector across worker threads
    /// cannot perturb replay schedules.
    faults: Option<Arc<FaultInjector>>,
    /// Worker pool for the parallel decode/prefill paths. `None` until
    /// [`Engine::set_workers`]; a 1-sized pool runs everything inline on
    /// the coordinator (exact single-threaded behavior).
    workers: Option<WorkerPool>,
    /// Ordinal for `PrefixCorrupt` fault draws — the prefix tree is
    /// coordinator-only, so a sequential counter is already
    /// schedule-independent; it feeds `draw_key` to decorrelate
    /// consecutive draws.
    prefix_fault_seq: u64,
}

enum Owned {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl Owned {
    fn as_arg(&self) -> Arg<'_> {
        match self {
            Owned::F32(v) => Arg::F32(v),
            Owned::I32(v) => Arg::I32(v),
            Owned::U8(v) => Arg::U8(v),
        }
    }

    fn zeroed(dtype: DType, elems: usize) -> Owned {
        match dtype {
            DType::F32 => Owned::F32(vec![0.0; elems]),
            DType::I32 => Owned::I32(vec![0; elems]),
            DType::U8 => Owned::U8(vec![0; elems]),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Owned::F32(v) => 4 * v.len(),
            Owned::I32(v) => 4 * v.len(),
            Owned::U8(v) => v.len(),
        }
    }

    fn f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Owned::F32(v) => Ok(v),
            _ => bail!("arg buffer dtype mismatch (want f32)"),
        }
    }

    fn i32_mut(&mut self) -> Result<&mut Vec<i32>> {
        match self {
            Owned::I32(v) => Ok(v),
            _ => bail!("arg buffer dtype mismatch (want i32)"),
        }
    }

    fn u8_mut(&mut self) -> Result<&mut Vec<u8>> {
        match self {
            Owned::U8(v) => Ok(v),
            _ => bail!("arg buffer dtype mismatch (want u8)"),
        }
    }
}

impl Engine {
    pub fn new(artifacts_dir: &Path, method: Method, r_limit: usize) -> Result<Engine> {
        let meta = Meta::load(artifacts_dir)?;
        let weights = Weights::load(artifacts_dir, &meta.model)?;
        let variant = meta.variant(&method.variant)?.clone();
        let mut runtime = Runtime::cpu()?;
        let decode_name = decode_artifact(&variant.name);
        runtime.load(artifacts_dir, &decode_name)?;
        for &b in &meta.cache.prefill_buckets {
            runtime.load(artifacts_dir, &prefill_artifact(b))?;
        }
        let rot = method.rotation(meta.model.d_head);
        // upload weights to the device once
        let spec = crate::model::weights::param_spec(&meta.model);
        let weight_bufs = weights
            .flat
            .iter()
            .zip(&spec)
            .map(|(w, (_, shape))| upload(&runtime.client, &Arg::F32(w), shape))
            .collect::<Result<Vec<_>>>()?;
        let ref_pidx = ParamIndex::new(&weights, &meta.model);
        let ref_rope = RopeTable::new(meta.model.d_head, meta.model.rope_theta);
        Ok(Engine {
            runtime: Some(runtime),
            meta,
            weights,
            variant,
            method,
            r_limit,
            timers: EngineTimers::default(),
            artifacts_dir: artifacts_dir.to_path_buf(),
            rot,
            weight_bufs,
            arg_pool: HashMap::new(),
            kv_pool: None,
            prefix_tree: None,
            frozen_plan: None,
            ref_pidx,
            ref_rope,
            ref_scratch: None,
            faults: None,
            workers: None,
            prefix_fault_seq: 0,
        })
    }

    /// Build an engine over the pure-Rust reference model with synthetic
    /// weights — no PJRT runtime, no compiled artifacts on disk. Serving
    /// semantics (occupancy admission, paged storage, prefix sharing,
    /// per-variant sub-batching, precision policies) are identical to the
    /// compiled backend; only the per-step numerics run through
    /// `RefModel`. This is what the traffic/policy harnesses and CI build
    /// a [`crate::coordinator::router::Server`] on.
    pub fn new_reference(meta: Meta, seed: u64, method: Method, r_limit: usize) -> Result<Engine> {
        let weights = Weights::random(&meta.model, seed);
        let variant = meta.variant(&method.variant)?.clone();
        let rot = method.rotation(meta.model.d_head);
        let ref_pidx = ParamIndex::new(&weights, &meta.model);
        let ref_rope = RopeTable::new(meta.model.d_head, meta.model.rope_theta);
        Ok(Engine {
            runtime: None,
            meta,
            weights,
            variant,
            method,
            r_limit,
            timers: EngineTimers::default(),
            artifacts_dir: PathBuf::new(),
            rot,
            weight_bufs: Vec::new(),
            arg_pool: HashMap::new(),
            kv_pool: None,
            prefix_tree: None,
            frozen_plan: None,
            ref_pidx,
            ref_rope,
            ref_scratch: None,
            faults: None,
            workers: None,
            prefix_fault_seq: 0,
        })
    }

    /// True when this engine decodes through the pure-Rust reference model
    /// instead of compiled PJRT graphs.
    pub fn is_reference(&self) -> bool {
        self.runtime.is_none()
    }

    /// Install the shared KV page pool every admitted request leases from.
    pub fn set_kv_pool(&mut self, pool: KvPool) {
        self.kv_pool = Some(pool);
    }

    pub fn kv_pool(&self) -> Option<&KvPool> {
        self.kv_pool.as_ref()
    }

    /// Install the cross-request radix prefix tree (shared with the
    /// server, which registers completed prefills and sheds nodes under
    /// pool pressure).
    pub fn set_prefix_tree(&mut self, tree: Rc<RefCell<RadixTree>>) {
        self.prefix_tree = Some(tree);
    }

    pub fn prefix_tree(&self) -> Option<&Rc<RefCell<RadixTree>>> {
        self.prefix_tree.as_ref()
    }

    /// Override (or restore the per-method default for) frozen-plan
    /// partial-hit adoption. `Some(true)` serves partial hits for every
    /// method, `Some(false)` serves full hits only, `None` defers to
    /// [`frozen_plan_default`].
    pub fn set_frozen_plan(&mut self, v: Option<bool>) {
        self.frozen_plan = v;
    }

    /// Whether [`Engine::admit_prefill`] may serve `method` a frozen-plan
    /// partial hit (the configured override, else the method default).
    pub fn frozen_plan_enabled(&self, method: &Method) -> bool {
        self.frozen_plan.unwrap_or_else(|| frozen_plan_default(method))
    }

    /// Install the deterministic fault injector (shared with the server
    /// and the pool). Arms the `PrefillChunk`, `DecodeStep`, and
    /// `PrefixCorrupt` hooks.
    pub fn set_faults(&mut self, faults: Arc<FaultInjector>) {
        self.faults = Some(faults);
    }

    /// Install a worker pool of size `n` (clamped to ≥ 1). Per-worker
    /// decode arenas are allocated and warmed here, once, so the parallel
    /// steady state stays zero-alloc like the single-threaded path.
    /// `n = 1` keeps every path inline on the coordinator thread — exact
    /// current behavior.
    pub fn set_workers(&mut self, n: usize) {
        let cc = &self.meta.cache;
        let max_scores = cc.capacity + cc.residual + 1;
        self.workers = Some(WorkerPool::new(n.max(1), &self.meta.model, max_scores));
        let size = self.workers.as_ref().map_or(1, WorkerPool::size);
        self.timers.worker_busy_ns = vec![0; size];
        self.timers.worker_jobs = vec![0; size];
    }

    /// Installed worker-pool size (1 when no pool has been installed).
    pub fn workers(&self) -> usize {
        self.workers.as_ref().map_or(1, WorkerPool::size)
    }

    /// Refresh `timers.worker_busy_ns` / `timers.worker_jobs` from the
    /// pool's cumulative per-worker counters.
    fn sync_worker_timers(&mut self) {
        if let Some(pool) = &self.workers {
            let loads = pool.loads();
            self.timers.worker_busy_ns = loads.iter().map(|l| l.busy_ns).collect();
            self.timers.worker_jobs = loads.iter().map(|l| l.jobs).collect();
        }
    }

    /// Hash-chain seed for `method` — everything that shapes what a prompt
    /// quantizes into (method identity, residual split, group, capacity,
    /// model cache geometry). Prompts hashed under different seeds can
    /// never collide in the radix tree.
    pub fn prefix_seed_for(&self, method: &Method) -> u64 {
        let cc = &self.meta.cache;
        let mc = &self.meta.model;
        prefix_seed(
            &method.name,
            self.r_limit,
            cc.group,
            cc.capacity,
            mc.n_layers,
            mc.n_kv_heads,
            mc.d_head,
        )
    }

    /// Content-addressed full-prompt key for `prompt` under `method`: the
    /// hash-chain walk of `pool::prompt_chain_key` from
    /// [`Engine::prefix_seed_for`].
    pub fn prefix_key_for(&self, prompt: &[i32], method: &Method) -> u64 {
        prompt_chain_key(self.prefix_seed_for(method), prompt, self.meta.cache.group)
    }

    /// Deepest partial-walk depth (in groups) `prompt` may adopt from the
    /// tree under `method`: 0 when frozen-plan mode is off for the method,
    /// else capped at the consumer's own quantized-window end and strictly
    /// short of the whole prompt (the resumed prefill must recompute at
    /// least the last token to project logits).
    fn partial_walk_cap(&self, prompt_len: usize, method: &Method) -> usize {
        if !self.frozen_plan_enabled(method) {
            return 0;
        }
        let cc = &self.meta.cache;
        let (qt_c, _) = RequestCache::prefill_split(prompt_len, self.r_limit, cc.group, cc.capacity);
        RadixTree::partial_walk_groups(qt_c, prompt_len, cc.group)
    }

    /// Pages this prompt's admission will actually charge the pool: zero
    /// on a full hit (shared pages are charged once, at registration — the
    /// amortized-admission win), the divergent tail's pages on a partial
    /// hit, otherwise the exact prefill page count. Uses a counter-free
    /// probe so admission sizing does not pollute hit/miss telemetry.
    pub fn prefill_pages_for_prompt(&self, prompt: &[i32], method: &Method) -> Result<usize> {
        let full = self.prefill_pages_for(prompt.len(), method)?;
        let Some(tree) = &self.prefix_tree else {
            return Ok(full);
        };
        let seed = self.prefix_seed_for(method);
        let cc = &self.meta.cache;
        let cap = self.partial_walk_cap(prompt.len(), method);
        match tree.borrow().peek(seed, prompt, cc.group, cap) {
            PrefixPeek::Full => Ok(0),
            PrefixPeek::Partial(matched) => {
                let shared = crate::kvcache::pool::pages_for_tokens(
                    matched,
                    cc.group,
                    self.meta.variant(&method.variant)?.layers.len(),
                    self.meta.model.n_kv_heads,
                );
                Ok(full.saturating_sub(shared))
            }
            PrefixPeek::Miss => Ok(full),
        }
    }

    /// Stamp the ENTIRE verified node path `prompt`'s claim rests on (and
    /// the full-prompt tail, if resident) most recently used — the
    /// admission pass calls this before any pressure-shedding so no node
    /// under a zero/partial-page claim is the next eviction candidate.
    pub fn touch_prefix(&mut self, prompt: &[i32], method: &Method) {
        if let Some(tree) = self.prefix_tree.clone() {
            let seed = self.prefix_seed_for(method);
            let cap = self.partial_walk_cap(prompt.len(), method);
            tree.borrow_mut().touch_path(seed, prompt, self.meta.cache.group, cap);
        }
    }

    /// Register a freshly completed (non-full-hit) prefill into the radix
    /// tree: the cache's window pages convert to shared form, one node per
    /// quantization group, and future requests sharing ANY prefix length
    /// reuse them. No-op without a tree, on a duplicate, or on a
    /// plan-conflicting chain.
    pub fn register_prefix(
        &mut self,
        cache: &mut RequestCache,
        prompt: &[i32],
        method: &Method,
        last_logits: &[f32],
    ) -> bool {
        let Some(tree) = self.prefix_tree.clone() else {
            return false;
        };
        let seed = self.prefix_seed_for(method);
        cache.register_prefix(&mut tree.borrow_mut(), seed, prompt, last_logits)
    }

    /// Build a bounded page pool for `budget_bytes`, sized so a page fits
    /// the *largest* layout any known variant needs (heterogeneous tenants
    /// share one free list; pages are charged at the worst deployment
    /// cost). The off-pool residual buffers every admitted request holds
    /// (one full-capacity X_R per decode slot, worst case) are carved out
    /// of the byte budget FIRST, so pages + residuals together stay inside
    /// it — floored at half the budget so tiny test budgets still get a
    /// usable pool. Pre-warmed so steady-state leasing never allocates.
    pub fn build_shared_pool(&self, budget_bytes: usize) -> KvPool {
        let cc = &self.meta.cache;
        let mc = &self.meta.model;
        let d = mc.d_head;
        let page_bytes = self
            .meta
            .variants
            .iter()
            .flat_map(|v| v.layers.iter())
            .map(|&s| crate::kvcache::pool::PageLayout::new(s, d, cc.group).deploy_bytes())
            .max()
            .unwrap_or(1)
            .max(1);
        let resid_per_request = (crate::kvcache::accountant::fp16_bytes_per_token(d)
            * cc.residual as f64)
            .ceil() as usize
            * mc.n_layers
            * mc.n_kv_heads;
        let page_budget = budget_bytes
            .saturating_sub(cc.decode_batch * resid_per_request)
            .max(budget_bytes / 2);
        let max_pages = (page_budget / page_bytes).max(1);
        let specs = self.meta.variants.iter().flat_map(|v| v.layers.iter());
        let pool = KvPool::for_specs(specs, d, cc.group, Some(max_pages));
        pool.prewarm(max_pages);
        pool
    }

    /// Exact pages a `prompt_len`-token prompt's prefill leases under
    /// `method` — the scheduler's occupancy-based admission unit.
    pub fn prefill_pages_for(&self, prompt_len: usize, method: &Method) -> Result<usize> {
        let spec = self.meta.variant(&method.variant)?;
        let cc = &self.meta.cache;
        let (qt, _) =
            RequestCache::prefill_split(prompt_len, self.r_limit, cc.group, cc.capacity);
        Ok(crate::kvcache::pool::pages_for_tokens(
            qt,
            cc.group,
            spec.layers.len(),
            self.meta.model.n_kv_heads,
        ))
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Switch the *default* quantization method in place (compiles the new
    /// decode variant if not already resident; prefill graphs and weights
    /// are shared). The experiment roster loops use this to avoid
    /// re-creating PJRT clients.
    pub fn set_method(&mut self, method: Method) -> Result<()> {
        self.ensure_method(&method)?;
        let variant = self.meta.variant(&method.variant)?.clone();
        self.rot = method.rotation(self.meta.model.d_head);
        self.method = method;
        self.variant = variant;
        Ok(())
    }

    /// Make `method`'s decode variant resident in the executable pool
    /// (no-op when already compiled). Per-request routing calls this at
    /// admission, so a variant compiles at most once per process. On the
    /// reference backend this is validation only — every known variant's
    /// tier shapes decode through the same reference model.
    pub fn ensure_method(&mut self, method: &Method) -> Result<()> {
        self.meta
            .variant(&method.variant)
            .with_context(|| format!("method `{}`", method.name))?;
        let Some(runtime) = self.runtime.as_mut() else {
            return Ok(());
        };
        let decode_name = decode_artifact(&method.variant);
        runtime.load(&self.artifacts_dir.clone(), &decode_name)
    }

    /// Resolve a request's method override against the engine default.
    pub fn resolve_method(&self, spec: Option<MethodSpec>) -> Method {
        spec.map(MethodSpec::build).unwrap_or_else(|| self.method.clone())
    }

    /// Worst-case cache bytes for one request under `method` (its own
    /// variant's tier shapes, not the default's).
    pub fn worst_case_bytes_for(&self, method: &Method) -> Result<usize> {
        let spec = self.meta.variant(&method.variant)?;
        Ok(MemoryAccountant::worst_case_request_bytes(
            &self.meta.model,
            &self.meta.cache,
            &spec.layers,
        ))
    }

    pub fn new_cache(&self) -> RequestCache {
        self.cache_for(&self.variant.layers, self.method.clone())
    }

    /// Cache scaffold for an explicit `method` — the restore path rebuilds
    /// each live request's cache from its *snapshotted* method name (which
    /// may differ from the request's submitted method after policy
    /// degradation or retry-ladder descent), then overlays the
    /// snapshotted state.
    pub fn new_cache_for(&self, method: &Method) -> Result<RequestCache> {
        let spec = self.meta.variant(&method.variant)?;
        Ok(self.cache_for(&spec.layers, method.clone()))
    }

    /// Current `PrefixCorrupt` draw ordinal (snapshotted so a restored
    /// server's prefix-verification fault schedule continues the series).
    pub fn prefix_fault_seq(&self) -> u64 {
        self.prefix_fault_seq
    }

    /// Overwrite the `PrefixCorrupt` draw ordinal (restore only).
    pub fn set_prefix_fault_seq(&mut self, seq: u64) {
        self.prefix_fault_seq = seq;
    }

    /// Cache under the engine's shared pool when one is installed, else a
    /// private unbounded pool.
    fn cache_for(&self, specs: &[crate::quant::window::TierSpec], method: Method) -> RequestCache {
        match &self.kv_pool {
            Some(pool) => RequestCache::new_in(
                pool,
                &self.meta.model,
                &self.meta.cache,
                specs,
                method,
                self.r_limit,
            ),
            None => RequestCache::new(
                &self.meta.model,
                &self.meta.cache,
                specs,
                method,
                self.r_limit,
            ),
        }
    }

    /// Run prompt prefill through the bucketed prefill graph
    /// (compiled-backend only; the serving path uses
    /// [`Engine::admit_prefill`], which works on both backends).
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<PrefillData> {
        let Some(runtime) = self.runtime.as_ref() else {
            bail!("bucketed HLO prefill needs the compiled backend (reference engine)");
        };
        let mc = &self.meta.model;
        let t = tokens.len();
        let bucket = pick_bucket(&self.meta.cache.prefill_buckets, t)?;
        let exe = runtime.get(&prefill_artifact(bucket))?;
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let length = [t as i32];
        let args = [Arg::I32(&padded), Arg::I32(&length)];
        let t0 = Instant::now();
        let out = exe.run_b(&runtime.client, &self.weight_bufs, &args)?;
        self.timers.prefill_exec_ns += t0.elapsed().as_nanos() as u64;
        if out.len() != 4 {
            bail!("prefill returned {} outputs, want 4", out.len());
        }
        let last_logits = Executable::to_f32(&out[0])?;
        let k_full = Executable::to_f32(&out[1])?; // [L, Hkv, bucket, dh]
        let v_full = Executable::to_f32(&out[2])?;
        let qabs_full = Executable::to_f32(&out[3])?; // [L, Hkv, dh]
        let (hkv, dh, nl) = (mc.n_kv_heads, mc.d_head, mc.n_layers);
        let mut k = Vec::with_capacity(nl);
        let mut v = Vec::with_capacity(nl);
        let mut qabs = Vec::with_capacity(nl);
        for l in 0..nl {
            let mut kl = vec![0f32; hkv * t * dh];
            let mut vl = vec![0f32; hkv * t * dh];
            for h in 0..hkv {
                let src = (l * hkv + h) * bucket * dh;
                kl[h * t * dh..(h + 1) * t * dh].copy_from_slice(&k_full[src..src + t * dh]);
                vl[h * t * dh..(h + 1) * t * dh].copy_from_slice(&v_full[src..src + t * dh]);
            }
            k.push(kl);
            v.push(vl);
            qabs.push(qabs_full[l * hkv * dh..(l + 1) * hkv * dh].to_vec());
        }
        Ok(PrefillData { k, v, qabs, t, last_logits })
    }

    /// One batched decode step on the *default* variant. `slots[i] =
    /// Some((cache, token))` for live requests; idle slots are masked out.
    /// Returns per-slot logits and updates each live cache (append + lazy
    /// quantization). Legacy whole-batch error contract for benches and
    /// harness drivers: the first failing slot's error collapses the call
    /// — internally this is [`Engine::decode_step_isolated`] with the
    /// per-slot `Result`s transposed, so both entries share one step
    /// implementation.
    pub fn decode_step(
        &mut self,
        slots: &mut [Option<(&mut RequestCache, i32)>],
    ) -> Result<Vec<Option<Vec<f32>>>> {
        let variant = self.variant.name.clone();
        let rot = self.rot.clone();
        self.decode_step_isolated(&variant, &rot, slots)?
            .into_iter()
            .map(Option::transpose)
            .collect()
    }

    /// One batched decode step through `variant`'s compiled graph (must be
    /// resident — see [`Engine::ensure_method`]). Every live slot in the
    /// call must hold a cache built for this variant's tier shapes; the
    /// batcher's variant groups guarantee that in serving.
    fn decode_step_compiled(
        &mut self,
        variant: &str,
        rot: &[f32],
        slots: &mut [Option<(&mut RequestCache, i32)>],
    ) -> Result<Vec<Option<Vec<f32>>>> {
        let b = self.meta.cache.decode_batch;
        if slots.len() != b {
            bail!("decode batch must have exactly {b} slots");
        }
        let spec = self.meta.variant(variant)?.clone();
        let decode_name = decode_artifact(variant);
        // Pooled arg buffers: first step for a variant allocates, every
        // later step refills the same buffers in place. The pool is taken
        // out for the duration of the step and re-inserted on EVERY path —
        // including assemble/lookup/execution errors — so a transient
        // failure neither drops the buffers nor double-counts
        // scratch_bytes/assemble_builds on the next step.
        let mut pool = self.arg_pool.remove(&decode_name).unwrap_or_default();
        let fresh_build = pool.is_empty();
        let run = self.run_decode_pooled(&spec, rot, &decode_name, slots, &mut pool, fresh_build);
        self.arg_pool.insert(decode_name, pool);
        self.timers.scratch_bytes = self
            .arg_pool
            .values()
            .flatten()
            .map(Owned::bytes)
            .sum::<usize>() as u64;
        let out = run?;
        if out.len() != 4 {
            bail!("decode returned {} outputs, want 4", out.len());
        }
        let mc = &self.meta.model;
        let (hkv, dh, nl, vocab) = (mc.n_kv_heads, mc.d_head, mc.n_layers, mc.vocab);
        let logits = Executable::to_f32(&out[0])?; // [B, V]
        let knew = Executable::to_f32(&out[1])?; // [L, B, Hkv, dh]
        let vnew = Executable::to_f32(&out[2])?;
        let qabs = Executable::to_f32(&out[3])?;

        let mut results = Vec::with_capacity(b);
        for (i, slot) in slots.iter_mut().enumerate() {
            match slot {
                None => results.push(None),
                Some((cache, _)) => {
                    let mut kn = Vec::with_capacity(nl);
                    let mut vn = Vec::with_capacity(nl);
                    let mut qn = Vec::with_capacity(nl);
                    for l in 0..nl {
                        let off = (l * b + i) * hkv * dh;
                        kn.push(knew[off..off + hkv * dh].to_vec());
                        vn.push(vnew[off..off + hkv * dh].to_vec());
                        qn.push(qabs[off..off + hkv * dh].to_vec());
                    }
                    let tq = Instant::now();
                    let before = cache.qlen;
                    cache.append(&kn, &vn, &qn)?;
                    if cache.qlen != before {
                        self.timers.quantize_events += 1;
                        self.timers.quantize_ns += tq.elapsed().as_nanos() as u64;
                    }
                    results.push(Some(logits[i * vocab..(i + 1) * vocab].to_vec()));
                }
            }
        }
        Ok(results)
    }

    /// One batched decode step with **per-slot error isolation**: a failing
    /// slot (an injected `DecodeStep` fault or a per-request cache error)
    /// yields `Some(Err(..))` for that slot only — the rest of the variant
    /// group completes its step normally, which is what lets the router
    /// retire one bad request without poisoning its group or the tick. The
    /// outer `Err` is reserved for batch-level contract violations (wrong
    /// slot count, unknown variant). On the compiled backend a graph
    /// execution failure is inherently batch-wide; it is fanned out to
    /// every live slot so each request retires individually instead of the
    /// error killing the server tick.
    pub fn decode_step_isolated(
        &mut self,
        variant: &str,
        rot: &[f32],
        slots: &mut [Option<(&mut RequestCache, i32)>],
    ) -> Result<Vec<Option<Result<Vec<f32>>>>> {
        let b = self.meta.cache.decode_batch;
        if slots.len() != b {
            bail!("decode batch must have exactly {b} slots");
        }
        // Injected decode-step faults are drawn per live slot (one victim,
        // not the group); victims are masked out of the batch before the
        // step runs and reported as per-slot errors afterwards. Each
        // slot's draw is keyed by its own cache's per-request ordinal
        // stream, so the outcome depends only on (seed, request, step
        // number) — never on slot position, group order, or worker
        // schedule.
        let mut injected = vec![false; slots.len()];
        if let Some(f) = self.faults.clone() {
            for (i, s) in slots.iter_mut().enumerate() {
                if let Some((cache, _)) = s {
                    let key = cache.next_decode_fault_key();
                    if f.should_fail(FaultSite::DecodeStep, key) {
                        injected[i] = true;
                        *s = None;
                    }
                }
            }
        }
        let stepped: Vec<Option<Result<Vec<f32>>>> = if self.runtime.is_none() {
            self.decode_step_reference_isolated(variant, slots)?
        } else {
            match self.decode_step_compiled(variant, rot, slots) {
                Ok(res) => res.into_iter().map(|o| o.map(Ok)).collect(),
                Err(e) => {
                    let msg = format!("{e:#}");
                    slots.iter().map(|s| s.as_ref().map(|_| Err(anyhow!("{msg}")))).collect()
                }
            }
        };
        Ok(stepped
            .into_iter()
            .zip(injected)
            .map(|(o, hit)| {
                if hit {
                    Some(Err(anyhow!("injected transient fault: decode step")))
                } else {
                    o
                }
            })
            .collect())
    }

    /// One full serving tick of decode work: every variant group's
    /// sub-batch, stepped with per-slot error isolation and — on the
    /// reference backend with `workers > 1` and more than one live slot —
    /// fanned across the worker pool one job per live slot
    /// (threading-model boundary (a)). The merge is deterministic: job
    /// results fold back in (group, slot) index order, never completion
    /// order, and every cache mutation (`append`, page leases,
    /// quantization) happens on the coordinator thread in that same
    /// order — so logits, cache contents, pool books, and fault draws
    /// are bit-identical to running [`Engine::decode_step_isolated`] per
    /// group sequentially (gated by tests/parallel.rs). With a single
    /// live slot the sequential path runs instead, where the per-head
    /// attention split (boundary (c)) picks up the parallelism.
    pub fn decode_groups_isolated(
        &mut self,
        groups: &mut [DecodeGroup<'_>],
    ) -> Result<Vec<Vec<Option<Result<Vec<f32>>>>>> {
        let live: usize = groups
            .iter()
            .map(|g| g.slots.iter().filter(|s| s.is_some()).count())
            .sum();
        if !(self.runtime.is_none() && self.workers() > 1 && live > 1) {
            let mut out = Vec::with_capacity(groups.len());
            for g in groups.iter_mut() {
                out.push(self.decode_step_isolated(&g.variant, &g.rot, &mut g.slots)?);
            }
            return Ok(out);
        }
        let b = self.meta.cache.decode_batch;
        for g in groups.iter() {
            if g.slots.len() != b {
                bail!("decode batch must have exactly {b} slots");
            }
            self.meta.variant(&g.variant)?;
        }
        // Keyed per-slot fault draws in (group, slot) order — identical
        // to the sequential path's draws because each key comes from the
        // request's own ordinal stream, not from call order.
        let mut injected: Vec<Vec<bool>> =
            groups.iter().map(|g| vec![false; g.slots.len()]).collect();
        if let Some(f) = self.faults.clone() {
            for (gi, g) in groups.iter_mut().enumerate() {
                for (i, s) in g.slots.iter_mut().enumerate() {
                    if let Some((cache, _)) = s {
                        let key = cache.next_decode_fault_key();
                        if f.should_fail(FaultSite::DecodeStep, key) {
                            injected[gi][i] = true;
                            *s = None;
                        }
                    }
                }
            }
        }
        let mut workers = self.workers.take().expect("parallel path requires a pool");
        let model = RefModel::with_parts(
            self.meta.model.clone(),
            &self.weights,
            self.ref_pidx.clone(),
            self.ref_rope.clone(),
        );
        let t0 = Instant::now();
        // One job per live slot. Jobs only READ their cache — the whole
        // forward pass is pure compute against per-worker arenas; outputs
        // come back as owned buffers (the compiled path's per-slot
        // gathers allocate comparably).
        let mut order: Vec<(usize, usize)> = Vec::new();
        let mut jobs = Vec::new();
        for (gi, g) in groups.iter().enumerate() {
            for (i, s) in g.slots.iter().enumerate() {
                if let Some((cache, tok)) = s {
                    let cache: &RequestCache = &**cache;
                    let tok = *tok;
                    let m = &model;
                    order.push((gi, i));
                    jobs.push(move |ws: &mut crate::util::workers::WorkerScratch| {
                        m.decode_step_into(tok, cache, &mut ws.decode);
                        (
                            ws.decode.logits.clone(),
                            ws.decode.knew.clone(),
                            ws.decode.vnew.clone(),
                            ws.decode.qabs.clone(),
                        )
                    });
                }
            }
        }
        let stepped = workers.run(jobs);
        let mut out: Vec<Vec<Option<Result<Vec<f32>>>>> = groups
            .iter()
            .map(|g| (0..g.slots.len()).map(|_| None).collect())
            .collect();
        for ((gi, i), (logits, kn, vn, qn)) in order.into_iter().zip(stepped) {
            let (cache, _) = groups[gi].slots[i].as_mut().expect("live slot");
            let tq = Instant::now();
            let before = cache.qlen;
            out[gi][i] = Some(match cache.append(&kn, &vn, &qn) {
                Ok(()) => {
                    if cache.qlen != before {
                        self.timers.quantize_events += 1;
                        self.timers.quantize_ns += tq.elapsed().as_nanos() as u64;
                    }
                    Ok(logits)
                }
                Err(e) => Err(e),
            });
        }
        for (gi, hits) in injected.iter().enumerate() {
            for (i, &hit) in hits.iter().enumerate() {
                if hit {
                    out[gi][i] = Some(Err(anyhow!("injected transient fault: decode step")));
                }
            }
        }
        self.timers.decode_exec_ns += t0.elapsed().as_nanos() as u64;
        self.timers.decode_steps += groups.len() as u64;
        self.timers.parallel_ticks += 1;
        drop(model);
        self.workers = Some(workers);
        self.sync_worker_timers();
        Ok(out)
    }

    /// Per-slot body of the reference decode step: a slot whose
    /// `cache.append` fails carries its own `Err` while the remaining
    /// slots still step (their caches stay coherent — nothing after a
    /// failing slot depends on it). With a worker pool installed each
    /// slot's attention splits across the pool by query-head range
    /// (threading-model boundary (c)) — bit-identical to the inline path.
    fn decode_step_reference_isolated(
        &mut self,
        variant: &str,
        slots: &mut [Option<(&mut RequestCache, i32)>],
    ) -> Result<Vec<Option<Result<Vec<f32>>>>> {
        self.meta.variant(variant)?;
        let cc = &self.meta.cache;
        let mut scratch = match self.ref_scratch.take() {
            Some(s) => s,
            None => DecodeScratch::new(&self.meta.model, cc.capacity + cc.residual + 1),
        };
        let mut workers = self.workers.take();
        let model = RefModel::with_parts(
            self.meta.model.clone(),
            &self.weights,
            self.ref_pidx.clone(),
            self.ref_rope.clone(),
        );
        let mut results = Vec::with_capacity(slots.len());
        let t0 = Instant::now();
        for slot in slots.iter_mut() {
            match slot {
                None => results.push(None),
                Some((cache, tok)) => {
                    match workers.as_mut() {
                        Some(pool) if pool.size() > 1 => {
                            model.decode_step_into_mt(*tok, cache, &mut scratch, pool)
                        }
                        _ => model.decode_step_into(*tok, cache, &mut scratch),
                    }
                    let tq = Instant::now();
                    let before = cache.qlen;
                    match cache.append(&scratch.knew, &scratch.vnew, &scratch.qabs) {
                        Ok(()) => {
                            if cache.qlen != before {
                                self.timers.quantize_events += 1;
                                self.timers.quantize_ns += tq.elapsed().as_nanos() as u64;
                            }
                            results.push(Some(Ok(scratch.logits.clone())));
                        }
                        Err(e) => results.push(Some(Err(e))),
                    }
                }
            }
        }
        self.timers.decode_exec_ns += t0.elapsed().as_nanos() as u64;
        self.timers.decode_steps += 1;
        drop(model);
        self.ref_scratch = Some(scratch);
        self.workers = workers;
        self.sync_worker_timers();
        Ok(results)
    }

    /// The fallible middle of a pooled decode step: refill `pool` in place,
    /// account the assembly timers, and execute. The caller owns putting
    /// `pool` back into `arg_pool` whatever this returns.
    fn run_decode_pooled(
        &mut self,
        vspec: &VariantSpec,
        rot: &[f32],
        decode_name: &str,
        slots: &[Option<(&mut RequestCache, i32)>],
        pool: &mut Vec<Owned>,
        fresh_build: bool,
    ) -> Result<Vec<crate::runtime::xla_shim::Literal>> {
        // Count the build attempt up front so a failed first assembly still
        // registers as a build, not a later phantom reuse.
        if fresh_build {
            self.timers.assemble_builds += 1;
        } else {
            self.timers.assemble_reuses += 1;
        }
        let t_asm = Instant::now();
        self.assemble_args_into(vspec, rot, decode_name, slots, pool)?;
        let args: Vec<Arg> = pool.iter().map(|o| o.as_arg()).collect();
        self.timers.assemble_ns += t_asm.elapsed().as_nanos() as u64;

        let runtime = self.runtime.as_ref().context("compiled decode without runtime")?;
        let exe = runtime.get(decode_name)?;
        let t0 = Instant::now();
        let out = exe.run_b(&runtime.client, &self.weight_bufs, &args)?;
        self.timers.decode_exec_ns += t0.elapsed().as_nanos() as u64;
        self.timers.decode_steps += 1;
        Ok(out)
    }

    /// The ONE prefill-admission entry: build `prompt`'s cache (shared
    /// pool when installed) and its resumable chunked run, consulting the
    /// radix prefix tree first. Three verdicts, one API:
    ///
    /// * [`PrefillAdmission::FullHit`] — the cache adopts the registered
    ///   shared pages/plans/residual and the run comes back already
    ///   complete (`PrefillRun::new_shared`); every (layer, chunk) unit is
    ///   skipped, counted in `EngineTimers::prefill_chunks_skipped`.
    /// * [`PrefillAdmission::PartialHit`] — frozen-plan mode: the cache
    ///   adopts the deepest verified prefix under the producer's channel
    ///   plan and the run resumes at the divergence seam
    ///   (`PrefillRun::new_resumed`); only the skipped prefix units are
    ///   credited.
    /// * [`PrefillAdmission::Miss`] — a fresh run from token 0.
    ///
    /// No chunk executes here — drive the returned run with
    /// [`Engine::advance_prefill_chunked`]. This is the serving admission
    /// path; the bucketed HLO [`Engine::prefill`] +
    /// [`Engine::quantize_prefill_with`] pair remains for the
    /// compiled-graph harness flows.
    pub fn admit_prefill(
        &mut self,
        prompt: &[i32],
        method: &Method,
    ) -> Result<(PrefillAdmission, ChunkedPrefill)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let spec = self.meta.variant(&method.variant)?.clone();
        let mc_layers = self.meta.model.n_layers;
        let group = self.meta.cache.group;
        if let Some(tree) = self.prefix_tree.clone() {
            let seed = self.prefix_seed_for(method);
            let key = prompt_chain_key(seed, prompt, group);
            let mut tb = tree.borrow_mut();
            // Injected prefix corruption (drawn only when a full-prompt
            // tail is actually resident — the same residency gate and
            // draw-ordinal schedule as the flat index, so existing chaos
            // replays stay valid): the tail is treated as having failed
            // its token verify — distrusted, dropped with its private
            // chain, recorded as a collision-miss — and the request falls
            // through to a full prefill. A corrupted entry is never
            // served, not even as a partial hit.
            let corrupt = tb.contains(key) && {
                match self.faults.as_ref() {
                    Some(f) => {
                        let k = draw_key(0, self.prefix_fault_seq);
                        self.prefix_fault_seq += 1;
                        f.should_fail(FaultSite::PrefixCorrupt, k)
                    }
                    None => false,
                }
            };
            if corrupt {
                tb.discard_corrupt(key);
            } else {
                let cap = self.partial_walk_cap(prompt.len(), method);
                match tb.lookup(seed, prompt, group, cap) {
                    PrefixProbe::Full(m) => {
                        let mut cache = self.cache_for(&spec.layers, method.clone());
                        cache.install_prefix(&m)?;
                        let run = PrefillRun::new_shared(
                            &self.meta.model,
                            prompt.len(),
                            group,
                            m.last_logits(),
                        );
                        let skipped = run.total_chunks(mc_layers) as u64;
                        drop(tb);
                        self.timers.prefill_chunks_skipped += skipped;
                        return Ok((PrefillAdmission::FullHit, ChunkedPrefill { cache, run }));
                    }
                    PrefixProbe::Partial(m) => {
                        let matched = m.t;
                        let mut cache = self.cache_for(&spec.layers, method.clone());
                        cache.install_prefix(&m)?;
                        let run = PrefillRun::new_resumed(
                            &self.meta.model,
                            prompt.len(),
                            group,
                            matched,
                        );
                        let t = prompt.len();
                        let skipped =
                            (t.div_ceil(group) - (t - matched).div_ceil(group)) * mc_layers;
                        drop(tb);
                        self.timers.prefill_chunks_skipped += skipped as u64;
                        return Ok((
                            PrefillAdmission::PartialHit { matched_tokens: matched, seam: matched },
                            ChunkedPrefill { cache, run },
                        ));
                    }
                    PrefixProbe::Miss => {}
                }
            }
        }
        let cache = self.cache_for(&spec.layers, method.clone());
        let run = PrefillRun::new(&self.meta.model, prompt.len(), group);
        Ok((PrefillAdmission::Miss, ChunkedPrefill { cache, run }))
    }

    /// Advance a chunked prefill by up to `max_chunks` (layer, chunk)
    /// units, accounting the work in `EngineTimers` (`prefill_exec_ns`,
    /// `prefill_chunks`, and on completion `prefill_tokens` plus one
    /// quantization event — parity with `quantize_prefill_with`). Returns
    /// `true` when the prefill is complete and
    /// `ChunkedPrefill::run.last_logits()` is valid.
    pub fn advance_prefill_chunked(
        &mut self,
        cp: &mut ChunkedPrefill,
        prompt: &[i32],
        max_chunks: usize,
    ) -> Result<bool> {
        // Injected prefill-chunk fault: this advance errors before doing
        // any work — the run's cache state is untouched, so the router's
        // retry machinery can requeue the request cleanly. The draw is
        // keyed by the request's own prefill ordinal stream, so it is
        // independent of tick composition and worker schedule.
        if let Some(f) = &self.faults {
            let key = cp.cache.next_prefill_fault_key();
            if f.should_fail(FaultSite::PrefillChunk, key) {
                bail!("injected transient fault: prefill chunk step");
            }
        }
        let model = RefModel::with_parts(
            self.meta.model.clone(),
            &self.weights,
            self.ref_pidx.clone(),
            self.ref_rope.clone(),
        );
        // a prefix-index hit arrives already done: its tokens were never
        // prefilled here, so they must not inflate prefill tok/s
        let already_done = cp.run.is_done();
        let before = cp.run.chunks_done();
        let t0 = Instant::now();
        let done = cp.run.advance(&model, prompt, &mut cp.cache, max_chunks);
        self.timers.prefill_exec_ns += t0.elapsed().as_nanos() as u64;
        self.timers.prefill_chunks += (cp.run.chunks_done() - before) as u64;
        let done = done?;
        if done && !already_done {
            self.timers.prefill_tokens += prompt.len() as u64;
            self.timers.quantize_events += 1;
        }
        Ok(done)
    }

    /// Advance a whole tick's in-flight chunked prefills (threading-model
    /// boundary (b)). Each entry is `(prefill, prompt, max_chunks)`; the
    /// returned Vec is in item order, each entry exactly what
    /// [`Engine::advance_prefill_chunked`] would have returned for that
    /// item.
    ///
    /// The parallel path is **abundance-gated**: prefill units lease pool
    /// pages as layers close, so items run concurrently only when the
    /// pool could satisfy every item's worst-case claim — then no lease
    /// can fail for lack of pages regardless of worker interleaving, and
    /// the only lease outcomes left are the keyed fault draws, which are
    /// schedule-independent by construction. Under scarcity (or
    /// `workers = 1`, or a compiled runtime) items advance sequentially —
    /// the exact legacy path, including its pressure/`pool_dry`
    /// semantics.
    pub fn advance_prefills_parallel(
        &mut self,
        items: &mut [(&mut ChunkedPrefill, &[i32], usize)],
    ) -> Vec<Result<bool>> {
        let abundant = match &self.kv_pool {
            None => true,
            Some(pool) => {
                let cc = &self.meta.cache;
                let mc = &self.meta.model;
                let claim: usize = items
                    .iter()
                    .map(|(_, prompt, _)| {
                        let (qt, _) = RequestCache::prefill_split(
                            prompt.len(),
                            self.r_limit,
                            cc.group,
                            cc.capacity,
                        );
                        crate::kvcache::pool::pages_for_tokens(
                            qt,
                            cc.group,
                            mc.n_layers,
                            mc.n_kv_heads,
                        )
                    })
                    .sum();
                pool.available() >= claim
            }
        };
        if !(self.runtime.is_none() && self.workers() > 1 && items.len() > 1 && abundant) {
            return items
                .iter_mut()
                .map(|(cp, prompt, mx)| self.advance_prefill_chunked(cp, prompt, *mx))
                .collect();
        }
        // Keyed fault draws up front in item order (per-request ordinal
        // streams — identical draws to the sequential path); victims
        // error without touching their run, exactly like the inline hook.
        let mut verdicts: Vec<Option<Result<bool>>> = items.iter().map(|_| None).collect();
        if let Some(f) = self.faults.clone() {
            for (i, (cp, _, _)) in items.iter_mut().enumerate() {
                let key = cp.cache.next_prefill_fault_key();
                if f.should_fail(FaultSite::PrefillChunk, key) {
                    verdicts[i] =
                        Some(Err(anyhow!("injected transient fault: prefill chunk step")));
                }
            }
        }
        let mut workers = self.workers.take().expect("parallel path requires a pool");
        let model = RefModel::with_parts(
            self.meta.model.clone(),
            &self.weights,
            self.ref_pidx.clone(),
            self.ref_rope.clone(),
        );
        let t0 = Instant::now();
        // One job per live item: a ChunkedPrefill *is* its own resumable
        // arena (run + cache), so jobs are disjoint by construction and
        // need no worker scratch.
        let mut order: Vec<usize> = Vec::new();
        let mut jobs = Vec::new();
        for (i, (cp, prompt, mx)) in items.iter_mut().enumerate() {
            if verdicts[i].is_some() {
                continue;
            }
            let cp: &mut ChunkedPrefill = &mut **cp;
            let prompt: &[i32] = *prompt;
            let mx = *mx;
            let m = &model;
            order.push(i);
            jobs.push(move |_ws: &mut crate::util::workers::WorkerScratch| {
                let already_done = cp.run.is_done();
                let before = cp.run.chunks_done();
                let done = cp.run.advance(m, prompt, &mut cp.cache, mx);
                (cp.run.chunks_done() - before, already_done, done)
            });
        }
        let stepped = workers.run(jobs);
        for (i, (delta, already_done, done)) in order.into_iter().zip(stepped) {
            self.timers.prefill_chunks += delta as u64;
            verdicts[i] = Some(match done {
                Err(e) => Err(e),
                Ok(done) => {
                    if done && !already_done {
                        self.timers.prefill_tokens += items[i].1.len() as u64;
                        self.timers.quantize_events += 1;
                    }
                    Ok(done)
                }
            });
        }
        self.timers.prefill_exec_ns += t0.elapsed().as_nanos() as u64;
        self.timers.parallel_ticks += 1;
        drop(model);
        self.workers = Some(workers);
        self.sync_worker_timers();
        verdicts.into_iter().map(|v| v.expect("every item resolved")).collect()
    }

    /// Quantize a freshly prefilled prompt into a new cache under the
    /// default method (timed as a channel-selection/quantization event).
    /// Harness/bench entry — serving admission goes through
    /// [`Engine::admit_prefill`].
    pub fn quantize_prefill(&mut self, pre: &PrefillData) -> Result<RequestCache> {
        let method = self.method.clone();
        self.quantize_prefill_with(pre, &method)
    }

    /// Quantize a freshly prefilled prompt into a cache built for `method`
    /// — the per-request routing path: the cache gets that method's tier
    /// shapes, ordering, clipping, and rotation.
    pub fn quantize_prefill_with(&mut self, pre: &PrefillData, method: &Method) -> Result<RequestCache> {
        let spec = self.meta.variant(&method.variant)?.clone();
        let mut cache = self.cache_for(&spec.layers, method.clone());
        let t0 = Instant::now();
        cache.load_prefill(&pre.k, &pre.v, &pre.qabs, pre.t)?;
        self.timers.quantize_ns += t0.elapsed().as_nanos() as u64;
        self.timers.quantize_events += 1;
        Ok(cache)
    }

    /// Fill the non-weight decode args in manifest order into `pool`,
    /// allocating the buffers only when the pool is empty (a variant's
    /// first step); otherwise every buffer is refilled in place.
    fn assemble_args_into(
        &self,
        vspec: &VariantSpec,
        rot: &[f32],
        decode_name: &str,
        slots: &[Option<(&mut RequestCache, i32)>],
        pool: &mut Vec<Owned>,
    ) -> Result<()> {
        let mc = &self.meta.model;
        let cc = &self.meta.cache;
        let b = cc.decode_batch;
        let (hkv, dh) = (mc.n_kv_heads, mc.d_head);
        let exe = self
            .runtime
            .as_ref()
            .context("compiled decode without runtime")?
            .get(decode_name)?;
        let n_params = self.weights.flat.len();
        let n_args = exe.manifest.len() - n_params;
        if pool.is_empty() {
            for spec in exe.manifest.iter().skip(n_params) {
                pool.push(Owned::zeroed(spec.dtype, spec.elems()));
            }
        } else if pool.len() != n_args {
            bail!("arg pool shape drift for `{decode_name}`");
        }
        macro_rules! per_slot_i32 {
            ($owned:expr, $get:expr) => {{
                let buf = $owned.i32_mut()?;
                buf.fill(0);
                for (i, slot) in slots.iter().enumerate() {
                    if let Some((cache, tok)) = slot {
                        #[allow(clippy::redundant_closure_call)]
                        {
                            buf[i] = ($get)(cache, *tok);
                        }
                    }
                }
            }};
        }
        for (owned, spec) in pool.iter_mut().zip(exe.manifest.iter().skip(n_params)) {
            match spec.name.as_str() {
                "token" => per_slot_i32!(owned, |_c: &&mut RequestCache, tok: i32| tok),
                "pos" => per_slot_i32!(owned, |c: &&mut RequestCache, _t| c.pos as i32),
                "qlen" => per_slot_i32!(owned, |c: &&mut RequestCache, _t| c.qlen as i32),
                "rlen" => per_slot_i32!(owned, |c: &&mut RequestCache, _t| c.rlen() as i32),
                "rot" => owned.f32_mut()?.copy_from_slice(rot),
                name => {
                    let (l, field) = parse_layer_field(name)?;
                    self.fill_layer_field(vspec, slots, l, field, spec.elems(), b, hkv, dh, owned)?;
                }
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn fill_layer_field(
        &self,
        vspec: &VariantSpec,
        slots: &[Option<(&mut RequestCache, i32)>],
        l: usize,
        field: &str,
        elems: usize,
        b: usize,
        hkv: usize,
        dh: usize,
        owned: &mut Owned,
    ) -> Result<()> {
        let per_b = elems / b;
        let per_h = per_b / hkv;
        debug_assert_eq!(per_h * hkv * b, elems);
        // Zero (idle slots must not leak the previous step's data), then
        // gather each live slot's head buffers into its batch lane. Tier
        // fields stream the head's *page table* into the lane
        // (HeadState::copy_field_*): only leased pages are copied, the
        // lane's tail past them stays zero — the HLO masks by qlen anyway.
        macro_rules! gather {
            ($buf:expr, $get:expr) => {{
                let buf = $buf;
                debug_assert_eq!(buf.len(), elems);
                buf.fill(Default::default());
                for (i, slot) in slots.iter().enumerate() {
                    if let Some((cache, _)) = slot {
                        for h in 0..hkv {
                            let head = &cache.heads[l][h];
                            let dst = &mut buf[i * per_b + h * per_h..i * per_b + (h + 1) * per_h];
                            #[allow(clippy::redundant_closure_call)]
                            ($get)(head, dst);
                        }
                    }
                }
            }};
        }
        use crate::kvcache::cache::HeadState;
        macro_rules! gather_pages_f32 {
            ($pf:expr) => {
                gather!(owned.f32_mut()?, |hd: &HeadState, dst: &mut [f32]| hd
                    .copy_field_f32($pf, dst))
            };
        }
        macro_rules! gather_pages_u8 {
            ($pf:expr) => {
                gather!(owned.u8_mut()?, |hd: &HeadState, dst: &mut [u8]| hd
                    .copy_field_u8($pf, dst))
            };
        }
        let spec_l = vspec.layers[l];
        match field {
            "idx16" => gather!(owned.i32_mut()?, |hd: &HeadState, dst: &mut [i32]| dst
                .copy_from_slice(&hd.idx[..spec_l.n16])),
            "idx4" => gather!(owned.i32_mut()?, |hd: &HeadState, dst: &mut [i32]| dst
                .copy_from_slice(&hd.idx[spec_l.n16..spec_l.n16 + spec_l.n4])),
            "idx2" => gather!(owned.i32_mut()?, |hd: &HeadState, dst: &mut [i32]| dst
                .copy_from_slice(&hd.idx[spec_l.n16 + spec_l.n4..])),
            "k16" => gather_pages_f32!(PageField::K16),
            "k4p" => gather_pages_u8!(PageField::K4p),
            "k4s" => gather_pages_f32!(PageField::K4s),
            "k4z" => gather_pages_f32!(PageField::K4z),
            "k2p" => gather_pages_u8!(PageField::K2p),
            "k2s" => gather_pages_f32!(PageField::K2s),
            "k2z" => gather_pages_f32!(PageField::K2z),
            "vp" => gather_pages_u8!(PageField::Vp),
            "vs" => gather_pages_f32!(PageField::Vs),
            "vz" => gather_pages_f32!(PageField::Vz),
            "vfull" => gather_pages_f32!(PageField::Vfull),
            "kres" => gather!(owned.f32_mut()?, |hd: &HeadState, dst: &mut [f32]| {
                let n = hd.res.len * dh;
                dst[..n].copy_from_slice(hd.res.keys());
            }),
            "vres" => gather!(owned.f32_mut()?, |hd: &HeadState, dst: &mut [f32]| {
                let n = hd.res.len * dh;
                dst[..n].copy_from_slice(hd.res.values());
            }),
            _ => bail!("unknown layer field `{field}`"),
        }
        Ok(())
    }
}

/// Per-method default for frozen-plan partial hits: methods whose scales
/// are derived per-window adopt a producer's plan/scales losslessly for
/// the matched prefix and only the tail re-quantizes under them — within
/// the measured error budget (`harness::profiling::frozen_plan_error`).
/// Methods with *global* scale state (KVQuant's nuq-style global grids)
/// fold every token into one running estimate, so adopting a producer's
/// mid-stream state shifts ALL subsequent quantization — those default
/// off and serve full hits only.
pub fn frozen_plan_default(m: &Method) -> bool {
    !m.global_scales
}

fn parse_layer_field(name: &str) -> Result<(usize, &str)> {
    let rest = name.strip_prefix('l').context("layer field")?;
    let (num, field) = rest.split_once('.').context("layer field format")?;
    Ok((num.parse()?, field))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_field_parse() {
        assert_eq!(parse_layer_field("l0.k4p").unwrap(), (0, "k4p"));
        assert_eq!(parse_layer_field("l12.vres").unwrap(), (12, "vres"));
        assert!(parse_layer_field("rot").is_err());
    }
}
