//! # MixKVQ — query-aware mixed-precision KV cache quantization
//!
//! A full-system reproduction of *MixKVQ: Query-Aware Mixed-Precision KV
//! Cache Quantization for Long-Context Reasoning* (ACL 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels fusing packed-int
//!   dequantization into the attention dot products.
//! * **L2** (`python/compile/model.py`): the MiniReasoner transformer whose
//!   prefill/decode graphs are AOT-lowered to HLO text.
//! * **L3** (this crate): the serving runtime — PJRT execution, quantized
//!   paged KV cache, salience tracking, continuous batching, and the full
//!   experiment harness reproducing every table and figure of the paper.
//!
//! ## Serving API (v1): sessions, events, per-request routing
//!
//! Quantization methods form a typed, closed universe —
//! [`quant::methods::MethodSpec`] — with `Display`/`FromStr` as the single
//! source of truth for names and `MethodSpec::all()` enumerating every
//! constructible variant ([`quant::methods::Method::by_name`] and the
//! rosters are thin wrappers over it).
//!
//! The front door is session-oriented and non-blocking
//! ([`coordinator::router::Server`]):
//!
//! ```text
//! let id = server.submit(request)?;    // returns immediately
//! server.tick()?;                      // one scheduling cycle
//! server.poll(id);                     // Queued / Running / Finished
//! server.cancel(id);                   // queued or mid-decode
//! server.drain_events();               // Queued → Admitted → FirstToken
//!                                      //   → Token* → Finished{reason}
//! ```
//!
//! Each `Request` may carry an `Option<MethodSpec>` override: the engine
//! keeps a pool of compiled decode variants and the batcher groups live
//! slots into per-(variant, rotation) sub-batches each decode step, so two
//! tenants with different precision policies share one server.
//! `Server::run` remains as a compatibility shim (submit all → tick until
//! drained) for the offline bench drivers.
//!
//! ## Fused packed-code decode (zero-dequant, zero-alloc)
//!
//! The reference decode hot path never materializes dequantized f32
//! windows: [`model::reference::RefModel::decode_step_into`] computes
//! attention scores and outputs **directly over the cache's packed u2/u4
//! buffers** using the affine decomposition documented in
//! [`quant::packing`] (per scale-group, `q·dequant(c) = (q⊙s)·c + q·z`),
//! streamed by [`kvcache::cache::HeadState::scores_into`] /
//! [`kvcache::cache::HeadState::values_accumulate_into`]. Every
//! intermediate lives in a reusable [`model::reference::DecodeScratch`]
//! arena and RoPE frequencies are precomputed once per model
//! ([`model::reference::RopeTable`]), so the steady-state step performs
//! zero heap allocations and zero `powf` calls — property-tested against
//! the dequantize-then-attend oracle (kept as
//! `harness::refdriver::RefDriver::step_legacy`) across the full method
//! roster in tests/fused_decode.rs, and benchmarked artifact-free by
//! `cargo bench --bench ref_decode` (writes `BENCH_ref_decode.json`). The
//! engine's batch assembly pools its decode-arg buffers per variant the
//! same way ([`coordinator::engine::EngineTimers`] reports the reuse rate).
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results.

pub mod util {
    pub mod bench;
    pub mod cli;
    pub mod json;
    pub mod rng;
    pub mod stats;
}

pub mod quant {
    pub mod asym;
    pub mod methods;
    pub mod packing;
    pub mod rotation;
    pub mod salience;
    pub mod window;
}

pub mod model {
    pub mod config;
    pub mod reference;
    pub mod sampler;
    pub mod tokenizer;
    pub mod weights;
}

pub mod kvcache {
    pub mod accountant;
    pub mod cache;
    pub mod eviction;
    pub mod residual;
}

pub mod runtime {
    pub mod client;
    pub mod executor;
    pub mod registry;
    pub mod xla_shim;
}

pub mod coordinator {
    pub mod batcher;
    pub mod engine;
    pub mod events;
    pub mod metrics;
    pub mod router;
    pub mod scheduler;
    pub mod session;
}

pub mod harness {
    pub mod accuracy;
    pub mod experiments;
    pub mod pareto;
    pub mod perplexity;
    pub mod refdriver;
    pub mod workloads;
}
