//! # MixKVQ — query-aware mixed-precision KV cache quantization
//!
//! A full-system reproduction of *MixKVQ: Query-Aware Mixed-Precision KV
//! Cache Quantization for Long-Context Reasoning* (ACL 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels fusing packed-int
//!   dequantization into the attention dot products.
//! * **L2** (`python/compile/model.py`): the MiniReasoner transformer whose
//!   prefill/decode graphs are AOT-lowered to HLO text.
//! * **L3** (this crate): the serving runtime — PJRT execution, quantized
//!   paged KV cache, salience tracking, continuous batching, and the full
//!   experiment harness reproducing every table and figure of the paper.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results.

pub mod util {
    pub mod bench;
    pub mod cli;
    pub mod json;
    pub mod rng;
    pub mod stats;
}

pub mod quant {
    pub mod asym;
    pub mod methods;
    pub mod packing;
    pub mod rotation;
    pub mod salience;
    pub mod window;
}

pub mod model {
    pub mod config;
    pub mod reference;
    pub mod sampler;
    pub mod tokenizer;
    pub mod weights;
}

pub mod kvcache {
    pub mod accountant;
    pub mod cache;
    pub mod eviction;
    pub mod residual;
}

pub mod runtime {
    pub mod client;
    pub mod executor;
    pub mod registry;
}

pub mod coordinator {
    pub mod batcher;
    pub mod engine;
    pub mod metrics;
    pub mod router;
    pub mod scheduler;
    pub mod session;
}

pub mod harness {
    pub mod accuracy;
    pub mod experiments;
    pub mod pareto;
    pub mod perplexity;
    pub mod refdriver;
    pub mod workloads;
}
