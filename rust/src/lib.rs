//! # MixKVQ — query-aware mixed-precision KV cache quantization
//!
//! A full-system reproduction of *MixKVQ: Query-Aware Mixed-Precision KV
//! Cache Quantization for Long-Context Reasoning* (ACL 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels fusing packed-int
//!   dequantization into the attention dot products.
//! * **L2** (`python/compile/model.py`): the MiniReasoner transformer whose
//!   prefill/decode graphs are AOT-lowered to HLO text.
//! * **L3** (this crate): the serving runtime — PJRT execution, quantized
//!   paged KV cache, salience tracking, continuous batching, and the full
//!   experiment harness reproducing every table and figure of the paper.
//!
//! ## Serving API (v1): sessions, events, per-request routing
//!
//! Quantization methods form a typed, closed universe —
//! [`quant::methods::MethodSpec`] — with `Display`/`FromStr` as the single
//! source of truth for names and `MethodSpec::all()` enumerating every
//! constructible variant ([`quant::methods::Method::by_name`] and the
//! rosters are thin wrappers over it).
//!
//! The front door is session-oriented and non-blocking
//! ([`coordinator::router::Server`]):
//!
//! ```text
//! let id = server.submit(request)?;    // returns immediately
//! server.tick()?;                      // one scheduling cycle
//! server.poll(id);                     // Queued / Running / Finished
//! server.cancel(id);                   // queued or mid-decode
//! server.drain_events();               // Queued → Admitted → FirstToken
//!                                      //   → Token* → Finished{reason}
//! ```
//!
//! Each `Request` may carry an `Option<MethodSpec>` override: the engine
//! keeps a pool of compiled decode variants and the batcher groups live
//! slots into per-(variant, rotation) sub-batches each decode step, so two
//! tenants with different precision policies share one server.
//! `Server::run` remains as a compatibility shim (submit all → tick until
//! drained) for the offline bench drivers.
//!
//! ## Fused packed-code decode (zero-dequant, zero-alloc)
//!
//! The reference decode hot path never materializes dequantized f32
//! windows: [`model::reference::RefModel::decode_step_into`] computes
//! attention scores and outputs **directly over the cache's packed u2/u4
//! buffers** using the affine decomposition documented in
//! [`quant::packing`] (per scale-group, `q·dequant(c) = (q⊙s)·c + q·z`),
//! streamed by [`kvcache::cache::HeadState::scores_into`] /
//! [`kvcache::cache::HeadState::values_accumulate_into`]. Every
//! intermediate lives in a reusable [`model::reference::DecodeScratch`]
//! arena and RoPE frequencies are precomputed once per model
//! ([`model::reference::RopeTable`]), so the steady-state step performs
//! zero heap allocations and zero `powf` calls — property-tested against
//! the dequantize-then-attend oracle (kept as
//! `harness::refdriver::RefDriver::step_legacy`) across the full method
//! roster in tests/fused_decode.rs, and benchmarked artifact-free by
//! `cargo bench --bench ref_decode` (writes `BENCH_ref_decode.json`). The
//! engine's batch assembly pools its decode-arg buffers per variant the
//! same way ([`coordinator::engine::EngineTimers`] reports the reuse rate).
//!
//! ## Chunked GEMM-blocked prefill (direct-to-page, last-logit-only)
//!
//! Prefill — the TTFT/admission half of the hot path — no longer runs the
//! naive full-materialization forward. The production path is
//! [`model::reference::PrefillRun`]: the prompt is processed
//! **layer-streamed, chunk-tiled** (chunk = the quantization group G, so
//! tile boundaries line up with page boundaries):
//!
//! * every projection (QKV, output, MLP) goes through
//!   [`model::reference::matmul_blocked`] — 4-token × 4-weight-row tiles,
//!   one streaming pass over each weight matrix per tile instead of one
//!   per token, bit-identical summation order to the per-token `matvec`;
//! * attention streams over the layer's own f32 K/V with multi-accumulator
//!   dots ([`model::reference::dot_lanes`]), every intermediate living in a
//!   reusable [`model::reference::PrefillScratch`] arena — a steady-state
//!   (layer, chunk) unit performs **zero heap allocations** (gated by
//!   tests/blocked_prefill.rs with the counting allocator);
//! * as each layer closes, its K/V quantize **straight into `RequestCache`
//!   pool pages** ([`kvcache::cache::RequestCache::store_prefill_layer`]
//!   leases one page per group as it stores) — the `[L]`-layer f32
//!   `PrefillOut` stash and the `[Hkv, T, dh]` re-stash copy at admission
//!   are gone, so peak prefill memory is ~one layer of f32 plus the
//!   quantized pages (≥2× smaller; `cargo bench --bench prefill` writes
//!   `BENCH_prefill.json`);
//! * the vocab projection runs for the **last position only** — the
//!   `T × vocab` logits matrix every production caller discarded is gone.
//!   Full teacher-forced logits remain available from the
//!   [`model::reference::RefModel::forward_full`] oracle, which the
//!   chunked path is property-tested against to ≤1e-4 across the full
//!   method roster (tests/blocked_prefill.rs), mirroring the PR 2
//!   fused-vs-legacy decode pattern
//!   (`harness::refdriver::RefDriver::prefill_legacy` is the baseline).
//!
//! Serving admits by the same unit: `Server::tick` budgets
//! `prefill_chunks_per_tick` (layer, chunk) units across in-flight
//! [`coordinator::engine::ChunkedPrefill`] runs, so a long prompt spreads
//! over ticks instead of monopolizing one against live decoders, and
//! `EngineTimers` reports prefill chunk counts + tok/s in the serve
//! breakdown.
//!
//! ## Paged KV storage (the `KvPool`)
//!
//! Cache storage is **leased, not preallocated**: a request's quantized
//! window lives in fixed-size, group-aligned pages from a shared
//! [`kvcache::pool::KvPool`]. One page holds one quantization group (G
//! tokens) for one (layer, kv-head) across every tier buffer — packed
//! u4/u2 codes, the group's scales/zeros, value rows, and the BF16 outlier
//! columns (layout derivation in [`kvcache::pool::PageLayout`]; alignment
//! invariants in [`quant::packing`]). Pages are leased on prefill/flush and
//! returned on eviction, cancellation, or retirement via lease `Drop`, so
//! a 10-token request holds 10 tokens' worth of pages — not window
//! capacity C. Consequences across the stack:
//!
//! * the scheduler admits on **pool occupancy** with a reserve watermark
//!   ([`coordinator::scheduler::Scheduler::try_admit_pages`]), so short
//!   requests reach ≥2× the concurrency worst-case reservation allowed
//!   under the same byte budget (`worst_case_request_bytes` survives only
//!   as the reject-at-submit bound);
//! * a decode slot whose due flush cannot lease pages is **parked** for the
//!   tick, its tokens riding in the residual, and resumes when pages free
//!   up ([`coordinator::router::Server`]); an all-parked deadlock sheds the
//!   largest page-holder as CacheFull;
//! * group-aligned eviction is a page-table splice (kvcache::eviction) —
//!   freed pages are leasable by other tenants in the same tick;
//! * the fused decode path and the engine's batch gathers stream page by
//!   page ([`kvcache::cache::HeadState::scores_into`],
//!   [`kvcache::cache::HeadState::copy_field_f32`]) and stay zero-alloc
//!   (bounded pools are pre-warmed; `tests/fused_decode.rs` gates both
//!   storage configurations, `tests/paged_cache.rs` property-tests paged ↔
//!   contiguous bit-identity under append/flush/evict interleavings);
//! * `Metrics` carries pool gauges (pages leased, high water, lease
//!   failures, park/resume/preemption counts) and `mixkvq info` prints
//!   bytes-per-page and pages-per-request-at-C for every `MethodSpec`.
//!
//! ## Cross-request prefix sharing (radix tree, frozen-plan partial hits)
//!
//! Under multi-tenant traffic the same prompt *prefix* arrives again and
//! again (shared system prompts, retried chain-of-thought rollouts,
//! best-of-N sampling). A flushed page is **immutable** — appends mutate
//! only the residual, later flushes lease new pages — so a prompt's
//! quantized window is safe to share across requests:
//!
//! * [`kvcache::pool::SharedLease`] is the refcounted lease (`clone` bumps,
//!   `drop` decrements, the page frees at zero), and a page table mixes
//!   shared prefix pages with private tail pages behind
//!   [`kvcache::pool::PageRef`] — every read path streams both identically
//!   (the fused decode stays zero-alloc; gated in tests/fused_decode.rs),
//!   while writing a shared page panics;
//! * [`kvcache::radix::RadixTree`] is the registry: a radix tree over
//!   group-aligned prompt chunks, keyed by a rolling hash chain
//!   ([`kvcache::pool::prompt_chain_key`]) scoped to the quantization
//!   identity ([`kvcache::pool::prefix_seed`]). Each interior node pins one
//!   quant group's span pages plus the producer's frozen channel plan; a
//!   tail anchors a full-prompt registration (residual snapshot, |Q|
//!   state, last-position logits). **One registration serves every prefix
//!   length**: a probe walks the chain and returns the deepest
//!   token-verified match, so a 64-bit hash collision is a recorded miss,
//!   never a wrong-prompt hit;
//! * a **full hit skips the prefill entirely** — shared pages, channel
//!   plans, |Q| state, the bounded f32 residual tail, and last-position
//!   logits adopt bit-exactly (`RequestCache::register_prefix` /
//!   `install_prefix`, `PrefillRun::new_shared`) — so N requests over one
//!   prompt pay ~1× its quantized bytes and zero prefill compute;
//! * a **partial hit runs frozen-plan mode**: a consumer sharing a strict
//!   group-aligned prefix adopts the producer's channel plan and scale
//!   state for the matched groups and resumes its chunked prefill at the
//!   divergence seam (`RequestCache::begin_prefill_from`) instead of token
//!   0. Deliberately lossy — the plan was derived from the producer's
//!   window, not this prompt's — so the error is *measured*, not assumed:
//!   [`harness::profiling::frozen_plan_sweep`] holds every method whose
//!   [`coordinator::engine::frozen_plan_default`] is ON to
//!   [`harness::profiling::FROZEN_PLAN_NLL_BUDGET`] (globally-scaled
//!   methods default OFF; `ServerConfig::frozen_plan` overrides);
//! * **one admission API**: [`coordinator::engine::Engine::admit_prefill`]
//!   probes the tree and returns the verdict —
//!   [`coordinator::engine::PrefillAdmission`]: `FullHit` /
//!   `PartialHit { matched_tokens, seam }` / `Miss` — plus the run; the
//!   router's scheduler, the metrics layer, and the benches all key off
//!   it, and admission touches the whole matched node path before any
//!   pressure shedding so a hit can never shed its own prefix;
//! * **CoW at the seam**: divergence (decode appends) copies nothing — the
//!   first flush past the shared region leases private pages; eviction of
//!   a shared page drops only the local reference. The tree LRU-sheds
//!   from the leaves (tails before interior nodes; a node is never shed
//!   while a child or tail still depends on it), so retention never
//!   outranks a live flush. `tests/prefix_sharing.rs` property-tests K
//!   sharers at *different depths* against private caches for
//!   bit-identity under append/flush/evict/cancel churn, holds the
//!   deduped page budget (prefix once + private tails), and erodes a
//!   populated tree shed by shed against `RadixTree::audit`;
//! * serving charges shared pages **once**: the pool's `leased` counter
//!   sees a refcounted page a single time, full-hit admissions claim
//!   zero pages (`Engine::prefill_pages_for_prompt`), and `Metrics`
//!   reports hits/partial hits/misses/pinned pages/bytes-deduped/chunks
//!   skipped (`mixkvq serve` + `mixkvq info` surface them). Two benches
//!   feed CI's `bench-gate`: `cargo bench --bench prefix_sharing`
//!   (full-hit dedup and install speedup, `BENCH_prefix_sharing.json`)
//!   and `cargo bench --bench prefix_radix` (the shared-system-prompt
//!   workload — 2048-token shared prefix, divergent suffixes taking
//!   frozen-plan partial hits; `BENCH_prefix_radix.json`), whose ≥2×
//!   dedup, zero same-seed fingerprint drift, and frozen-plan error
//!   budget the gate enforces as the ninth bar.
//!
//! ## Adaptive precision policy + production traffic harness
//!
//! Who picks a request's [`quant::methods::MethodSpec`] when the caller
//! doesn't? A server-side [`quant::policy::PrecisionPolicy`]:
//!
//! * **Offline sensitivity profiling** ([`harness::profiling`]): a
//!   KVTuner-style one-layer-at-a-time sweep measures each spec's
//!   per-layer mean-NLL delta vs all-bf16 on a seeded calibration corpus
//!   through `RefDriver`, cached as a JSON artifact (`mixkvq profile`,
//!   default `profile.json`). Summed per-layer deltas predict full-spec
//!   error; [`quant::policy::SensitivityProfile::predicted_bound`] adds
//!   compounding slack to make the prediction a quotable bound (gated in
//!   tests/policy_traffic.rs).
//! * **Runtime policy** ([`quant::policy::PrecisionPolicy`]): `Fixed`
//!   pins one rung; `MemorySlo { budget_bytes }` admits the most accurate
//!   spec whose worst-case footprint fits the per-request byte budget;
//!   `LayerSensitivity { profile }` orders specs by predicted error and
//!   keeps the Pareto frontier (each cheaper rung strictly cheaper in
//!   bytes). The policy yields a candidate **ladder**, and the
//!   enforcement point is `KvPool` occupancy admission: under pool
//!   pressure a new request degrades to a cheaper rung (counted in
//!   `Metrics::policy_degradations`) instead of parking the queue.
//!   Explicit per-request pins bypass the policy.
//! * **Traffic harness** ([`harness::traffic`]): seeded deterministic
//!   arrival generators (Poisson bursts, diurnal ramps, closed-loop
//!   sessions) with prompt/tenant/method mixes on decorrelated RNG
//!   streams, driven through the real `Server::submit/tick/poll` loop at
//!   thousands of concurrent sessions. Per-tenant SLOs (p50/p99
//!   TTFT/latency, queue wait, park/preempt fairness) come from
//!   `Metrics`' tenant reservoirs; outcomes fold into a wall-clock-free
//!   FNV-1a fingerprint, and `mixkvq traffic` runs the same seed twice to
//!   prove bit-identical serving before emitting `BENCH_traffic.json`
//!   (CI's bench gate enforces the p99-TTFT bar and zero same-seed
//!   drift).
//!
//! ## Failure handling (deterministic faults, deadlines, bounded retries)
//!
//! The serving loop is hardened around one rule: **a failure belongs to a
//! request, never to the tick**. `Err` from `Server::tick` is reserved for
//! batch-level contract violations; everything a single tenant can trigger
//! retires only that tenant's request with a terminal
//! `FinishReason::Error` / `DeadlineExceeded` record and a well-formed
//! event stream.
//!
//! * **Deterministic fault injection** ([`util::faults`]): a
//!   [`util::faults::FaultPlan`] (seed + per-site rates) arms a
//!   [`util::faults::FaultInjector`] drawing from one named RNG stream per
//!   [`util::faults::FaultSite`] — transient pool-lease denial, prefill
//!   chunk-step error, decode-step error, prefix-tree entry corruption
//!   (detected and discarded via `RadixTree::discard_corrupt`). Same
//!   seed ⇒ same fault schedule, so every chaos failure reproduces
//!   exactly; with no plan installed the hooks cost one `Option` check.
//! * **Retry-with-degradation**: a failed prefill drops its run (every
//!   leased page returns via lease `Drop`), re-queues after an exponential
//!   tick backoff, and after `MAX_PREFILL_ATTEMPTS` failures at one
//!   admission-ladder rung retries pinned to the next *cheaper* rung;
//!   exhausting the cheapest rung retires the request as `Error`. Clean
//!   completion after a failure counts `Metrics::fault_recoveries`.
//! * **Deadlines are ticks, not wall-clock** (`Request::deadline_ticks`):
//!   queued/backoff requests past deadline shed before admission
//!   (`deadline_shed`), in-flight prefills and live slots retire as
//!   `DeadlineExceeded` — fingerprints stay bit-deterministic.
//! * **Park-watchdog**: a slot parked `PARK_WATCHDOG_DEGRADE` consecutive
//!   ticks frees pinned prefix pages; at `PARK_WATCHDOG_SHED` it sheds
//!   itself (CacheFull) instead of starving forever. A bounded wait queue
//!   (`ServerConfig::max_queue`) rejects at submit instead of growing
//!   without bound.
//! * **Self-audit + chaos gate**: `Server::check_invariants` proves the
//!   three independent bookkeepers agree — pool leases vs live holders'
//!   private pages + distinct shared pages vs the radix tree's pins (the
//!   tree's own `audit` recomputes them from its nodes and tails) — plus
//!   lifecycle-stage disjointness. `mixkvq traffic --chaos <rate>` soaks
//!   200+ sessions under ≥5% faults at all four sites, asserts the books
//!   balance after every tick, zero leaked pages at drain, and an
//!   identical same-seed fingerprint, then emits `BENCH_chaos.json` for
//!   CI's bench gate (tests/chaos.rs runs randomized fault × cancel ×
//!   deadline interleavings on top).
//!
//! ## Crash recovery & snapshot ABI (`mixkvq-snap-v2`)
//!
//! The live server is **checkpointable**: at any point outside `tick()`
//! (every tick boundary is a quiesce point — no background threads hold
//! state between ticks), [`coordinator::router::Server::snapshot`]
//! serializes the entire serving state through [`util::snapshot`]'s
//! length-delimited, versioned codec (`mixkvq-snap-v2` magic + schema
//! version, every field written through a named-field writer so a torn
//! stream fails with *which* field truncated, never a panic).
//! [`coordinator::router::Server::restore`] rebuilds a server from the
//! bytes that **passes `check_invariants` immediately** and then replays
//! the uninterrupted run's event stream bit for bit — the equivalence
//! contract `tests/snapshot.rs` and the CI kill-and-restore smoke
//! (`mixkvq traffic --kill-at-tick`, `BENCH_restore.json`, eighth
//! bench-gate bar) enforce at workers {1, 4}, chaos on/off.
//!
//! What the stream carries: pool page arenas with **per-page FNV-1a
//! checksums**, every slot's page tables (private and refcounted shared
//! pages, refcounts reconstructed through the restore-time lease
//! resolvers), residual tails, channel plans + |Q| state, in-flight
//! chunked prefills, the radix prefix tree (interior nodes and tails in
//! canonical order, frozen plans by table, recency clock and hit
//! counters), queue/backoff/retry state, RNG
//! positions, fault-draw ordinals, and the metrics reservoirs. What it
//! deliberately does **not** carry: wall-clock `Instant`s (re-stamped at
//! restore; fingerprints are wall-clock-free so this cannot drift them),
//! operator config (`ServerConfig` is provided by the caller and checked
//! against the snapshot's named geometry fields — a mismatch is refused
//! by field name), and the pool's lifetime `quarantined_total` counter
//! (`Metrics::pages_quarantined` carries the lineage across restores).
//!
//! Integrity is **per page, and failure is per request**: a checksum
//! mismatch at restore — or found live by [`coordinator::router::Server::scrub`]
//! — quarantines the page and retires only the owning request as
//! `FinishReason::Error` (a corrupt *shared* prefix page is dropped from
//! the tree collision-miss-style); the load itself never aborts, so a
//! fully corrupt snapshot still restores with queued page-less requests
//! riding through. [`util::faults::FaultSite::SnapshotWrite`] (torn
//! mid-stream write) and [`util::faults::FaultSite::SnapshotCorrupt`]
//! (per-page bit flip) make both failure modes deterministically
//! injectable. `mixkvq serve --snapshot-path <file> --snapshot-every-ticks
//! N` writes periodic atomic (tmp + rename) snapshots and `--restore`
//! resumes from one; `mixkvq info` prints the schema version and
//! estimated snapshot bytes per `MethodSpec`.
//!
//! ## Threading model (the multi-core engine)
//!
//! The serving hot loop shards across a fixed-size
//! [`util::workers::WorkerPool`] (`ServerConfig::workers`, default =
//! available parallelism; `--workers N` on `mixkvq serve`/`traffic`;
//! `workers = 1` is *exactly* the single-threaded engine — no pool
//! threads exist). Three independence boundaries are sharded:
//!
//! * **Decode slots** — `Batcher::variant_groups` partitions live slots
//!   into per-(variant, rotation) sub-batches; each slot's step is
//!   per-slot isolated (`Engine::decode_step_isolated` semantics), so
//!   slots dispatch to workers as independent jobs and their
//!   `Result<logits>`s merge back **in (group, slot) index order** —
//!   never completion order. Sampling stays on the coordinator thread in
//!   that same order, so the shared sampler RNG consumes draws exactly as
//!   the sequential engine did.
//! * **Chunked-prefill units** — each in-flight
//!   [`coordinator::engine::ChunkedPrefill`] advances independently;
//!   shortest-remaining-chunks stays the dispatch priority. Parallel
//!   dispatch is **abundance-gated**: the batch runs concurrently only
//!   when free pool pages cover every candidate's outstanding worst-case
//!   page claim, otherwise the tick falls back to the exact sequential
//!   admit-as-you-go path — so page-scarcity outcomes are identical at
//!   every worker count.
//! * **Per-head attention** within one decode step —
//!   [`model::reference::RefModel::decode_step_into_mt`] splits the
//!   query-head loop into contiguous ranges (deterministic
//!   `split_ranges`), each worker writing a disjoint slice of the
//!   attention output; per-layer barrier, fixed-order reassembly.
//!
//! Determinism is structural, not fenced: every worker writes only its
//! own pre-warmed arena ([`util::workers::WorkerScratch`], built at pool
//! construction so the zero-alloc steady-state gate holds) plus disjoint
//! output slots; all reductions merge in input-index order (the
//! `matmul_blocked` summation-order discipline lifted to the scheduling
//! layer); and fault draws are **stateless keyed draws** — a pure
//! function of `(seed, site, request-context key, per-context counter)`
//! ([`util::faults::FaultInjector::should_fail`]) — so the chaos
//! schedule cannot drift with thread interleaving. The shared mutable
//! spine is minimal: `KvPool` is `Arc<Mutex<…>>` (lease/free are short
//! critical sections; `can_lease` decisions are made schedule-invariant
//! by the router's parking-pass page reservations), the `FaultInjector`
//! is a lock-free `Arc`, and the radix prefix tree stays coordinator-only.
//! `tests/parallel.rs` property-tests `workers=1` vs `workers=N`
//! byte-identity — logits, event streams, metrics fingerprints — across
//! the full `MethodSpec` roster, and `cargo bench --bench parallel`
//! writes `BENCH_parallel.json` whose ≥2× tick-throughput-at-4-workers
//! bar CI's `bench-gate` enforces alongside zero same-seed fingerprint
//! drift.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results.

pub mod util {
    pub mod bench;
    pub mod cli;
    pub mod faults;
    pub mod json;
    pub mod rng;
    pub mod snapshot;
    pub mod stats;
    pub mod workers;
}

pub mod quant {
    pub mod asym;
    pub mod methods;
    pub mod packing;
    pub mod policy;
    pub mod rotation;
    pub mod salience;
    pub mod window;
}

pub mod model {
    pub mod config;
    pub mod reference;
    pub mod sampler;
    pub mod tokenizer;
    pub mod weights;
}

pub mod kvcache {
    pub mod accountant;
    pub mod cache;
    pub mod eviction;
    pub mod pool;
    pub mod radix;
    pub mod residual;
}

pub mod runtime {
    pub mod client;
    pub mod executor;
    pub mod registry;
    pub mod xla_shim;
}

pub mod coordinator {
    pub mod batcher;
    pub mod engine;
    pub mod events;
    pub mod metrics;
    pub mod router;
    pub mod scheduler;
    pub mod session;
}

pub mod harness {
    pub mod accuracy;
    pub mod experiments;
    pub mod pareto;
    pub mod perplexity;
    pub mod profiling;
    pub mod refdriver;
    pub mod traffic;
    pub mod workloads;
}
