//! Length-prefixed, versioned binary codec for crash-safe serving
//! snapshots (`mixkvq-snap-v2`) — no external serialization crates.
//!
//! The format is deliberately dumb: a magic + version header, then a fixed
//! sequence of primitive fields and length-prefixed arrays written in one
//! documented order by `Server::snapshot` and read back in the same order
//! by `Server::restore`, closed by a trailer sentinel so truncation is
//! always detected. Every multi-byte value is little-endian. There is no
//! self-description or field tagging — the version number in the header IS
//! the schema contract, and a version bump invalidates old snapshots
//! loudly instead of misparsing them.
//!
//! Error discipline (the same bar as the hardened JSON loaders): a
//! malformed, truncated, or version-mismatched snapshot returns a
//! descriptive [`SnapError`] naming the field being read and the byte
//! offset — never a panic, never an out-of-bounds slice. Corruption
//! *inside* a KV page's payload is deliberately NOT a codec-level error:
//! pages carry a per-page FNV-1a checksum ([`page_checksum`]) and the
//! restore path quarantines a mismatching page and retires only its owning
//! request (see `coordinator::router`).

use std::io::{Read, Write};

/// Magic line opening every snapshot stream. v2 replaced the flat prefix
/// index section with the radix prefix tree (nodes + anchored tails +
/// frozen-plan table); v1 images are rejected loudly, not misparsed.
pub const SNAP_MAGIC: &[u8; 15] = b"mixkvq-snap-v2\n";

/// Schema version written after the magic; bump on ANY layout change.
pub const SNAP_VERSION: u32 = 2;

/// Trailer sentinel closing the stream — a read that ends without it was
/// truncated.
pub const SNAP_TRAILER: u64 = 0x6d78_6b76_7120_454e; // "mxkvq EN"

/// Per-field sanity cap on length prefixes (bytes or elements): a corrupt
/// length must fail with a named error, not an allocator abort.
const MAX_FIELD_LEN: u64 = 1 << 31;

/// Snapshot codec failure: an I/O error from the underlying stream, or a
/// structural corruption naming the offending field and byte offset.
#[derive(Debug)]
pub enum SnapError {
    Io(std::io::Error),
    Corrupt(String),
}

impl std::fmt::Display for SnapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapError::Io(e) => write!(f, "snapshot i/o error: {e}"),
            SnapError::Corrupt(msg) => write!(f, "snapshot corrupt: {msg}"),
        }
    }
}

impl std::error::Error for SnapError {}

impl From<std::io::Error> for SnapError {
    fn from(e: std::io::Error) -> SnapError {
        SnapError::Io(e)
    }
}

pub type SnapResult<T> = Result<T, SnapError>;

/// Shorthand for a structural-corruption error.
pub fn corrupt(msg: impl Into<String>) -> SnapError {
    SnapError::Corrupt(msg.into())
}

// --- checksums -----------------------------------------------------------

/// FNV-1a over a byte slice (same constants as the prefix-index chain
/// hash and the traffic fingerprint).
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Per-page integrity checksum over both arenas (f32 bits LE, then the
/// byte arena). Computed when a page is sealed after its quantization
/// store, re-verified by `KvPool::verify_page` scrubs and on restore.
pub fn page_checksum(f: &[f32], b: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in f {
        h = fnv1a(h, &x.to_bits().to_le_bytes());
    }
    fnv1a(h, b)
}

// --- writer --------------------------------------------------------------

/// Forward-only snapshot writer; tracks bytes written so the caller can
/// report snapshot size.
pub struct SnapWriter<W: Write> {
    w: W,
    written: u64,
}

impl<W: Write> SnapWriter<W> {
    /// Open a writer and emit the magic + version header.
    pub fn new(w: W) -> SnapResult<SnapWriter<W>> {
        let mut sw = SnapWriter { w, written: 0 };
        sw.raw(SNAP_MAGIC)?;
        sw.u32(SNAP_VERSION)?;
        Ok(sw)
    }

    pub fn bytes_written(&self) -> u64 {
        self.written
    }

    pub fn raw(&mut self, bytes: &[u8]) -> SnapResult<()> {
        self.w.write_all(bytes)?;
        self.written += bytes.len() as u64;
        Ok(())
    }

    pub fn u8(&mut self, v: u8) -> SnapResult<()> {
        self.raw(&[v])
    }

    pub fn bool(&mut self, v: bool) -> SnapResult<()> {
        self.u8(v as u8)
    }

    pub fn u32(&mut self, v: u32) -> SnapResult<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> SnapResult<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn usize(&mut self, v: usize) -> SnapResult<()> {
        self.u64(v as u64)
    }

    pub fn i32(&mut self, v: i32) -> SnapResult<()> {
        self.raw(&v.to_le_bytes())
    }

    pub fn f32(&mut self, v: f32) -> SnapResult<()> {
        self.raw(&v.to_bits().to_le_bytes())
    }

    pub fn f64(&mut self, v: f64) -> SnapResult<()> {
        self.raw(&v.to_bits().to_le_bytes())
    }

    pub fn opt_u64(&mut self, v: Option<u64>) -> SnapResult<()> {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1)?;
                self.u64(x)
            }
        }
    }

    pub fn bytes(&mut self, v: &[u8]) -> SnapResult<()> {
        self.u64(v.len() as u64)?;
        self.raw(v)
    }

    pub fn str(&mut self, v: &str) -> SnapResult<()> {
        self.bytes(v.as_bytes())
    }

    pub fn slice_f32(&mut self, v: &[f32]) -> SnapResult<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.f32(x)?;
        }
        Ok(())
    }

    pub fn slice_f64(&mut self, v: &[f64]) -> SnapResult<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.f64(x)?;
        }
        Ok(())
    }

    pub fn slice_i32(&mut self, v: &[i32]) -> SnapResult<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.i32(x)?;
        }
        Ok(())
    }

    pub fn slice_u64(&mut self, v: &[u64]) -> SnapResult<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x)?;
        }
        Ok(())
    }

    /// Emit the trailer sentinel and flush; must be the final call.
    pub fn finish(mut self) -> SnapResult<u64> {
        self.u64(SNAP_TRAILER)?;
        self.w.flush()?;
        Ok(self.written)
    }
}

// --- reader --------------------------------------------------------------

/// Forward-only snapshot reader. Every read names the field it is
/// consuming so a truncated or garbled stream fails with "snapshot
/// corrupt: truncated reading `<field>` at byte N", never a panic.
pub struct SnapReader<R: Read> {
    r: R,
    read: u64,
}

impl<R: Read> SnapReader<R> {
    /// Open a reader and validate the magic + version header.
    pub fn new(r: R) -> SnapResult<SnapReader<R>> {
        let mut sr = SnapReader { r, read: 0 };
        let mut magic = [0u8; 15];
        sr.fill(&mut magic, "header magic")?;
        if &magic != SNAP_MAGIC {
            return Err(corrupt(format!(
                "bad magic {:?} (expected {:?}) — not a mixkvq snapshot",
                String::from_utf8_lossy(&magic),
                String::from_utf8_lossy(SNAP_MAGIC),
            )));
        }
        let version = sr.u32("header version")?;
        if version != SNAP_VERSION {
            return Err(corrupt(format!(
                "schema version {version} (this build reads version {SNAP_VERSION})"
            )));
        }
        Ok(sr)
    }

    pub fn bytes_read(&self) -> u64 {
        self.read
    }

    fn fill(&mut self, buf: &mut [u8], field: &str) -> SnapResult<()> {
        let at = self.read;
        self.r.read_exact(buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                corrupt(format!("truncated reading `{field}` at byte {at}"))
            } else {
                SnapError::Io(e)
            }
        })?;
        self.read += buf.len() as u64;
        Ok(())
    }

    pub fn u8(&mut self, field: &str) -> SnapResult<u8> {
        let mut b = [0u8; 1];
        self.fill(&mut b, field)?;
        Ok(b[0])
    }

    pub fn bool(&mut self, field: &str) -> SnapResult<bool> {
        match self.u8(field)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(corrupt(format!("field `{field}`: bool byte {v} (want 0 or 1)"))),
        }
    }

    pub fn u32(&mut self, field: &str) -> SnapResult<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b, field)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self, field: &str) -> SnapResult<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b, field)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn usize(&mut self, field: &str) -> SnapResult<usize> {
        Ok(self.u64(field)? as usize)
    }

    pub fn i32(&mut self, field: &str) -> SnapResult<i32> {
        let mut b = [0u8; 4];
        self.fill(&mut b, field)?;
        Ok(i32::from_le_bytes(b))
    }

    pub fn f32(&mut self, field: &str) -> SnapResult<f32> {
        Ok(f32::from_bits(self.u32(field)?))
    }

    pub fn f64(&mut self, field: &str) -> SnapResult<f64> {
        Ok(f64::from_bits(self.u64(field)?))
    }

    pub fn opt_u64(&mut self, field: &str) -> SnapResult<Option<u64>> {
        match self.u8(field)? {
            0 => Ok(None),
            1 => Ok(Some(self.u64(field)?)),
            v => Err(corrupt(format!("field `{field}`: option tag {v} (want 0 or 1)"))),
        }
    }

    /// Read a length prefix, rejecting implausible values so a corrupt
    /// length fails with a named error instead of an allocator abort.
    pub fn len(&mut self, field: &str) -> SnapResult<usize> {
        let n = self.u64(field)?;
        if n > MAX_FIELD_LEN {
            return Err(corrupt(format!("field `{field}`: implausible length {n}")));
        }
        Ok(n as usize)
    }

    pub fn bytes(&mut self, field: &str) -> SnapResult<Vec<u8>> {
        let n = self.len(field)?;
        let mut v = vec![0u8; n];
        self.fill(&mut v, field)?;
        Ok(v)
    }

    pub fn str(&mut self, field: &str) -> SnapResult<String> {
        let b = self.bytes(field)?;
        String::from_utf8(b)
            .map_err(|_| corrupt(format!("field `{field}`: invalid utf-8 string")))
    }

    pub fn vec_f32(&mut self, field: &str) -> SnapResult<Vec<f32>> {
        let n = self.len(field)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32(field)?);
        }
        Ok(v)
    }

    pub fn vec_f64(&mut self, field: &str) -> SnapResult<Vec<f64>> {
        let n = self.len(field)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64(field)?);
        }
        Ok(v)
    }

    pub fn vec_i32(&mut self, field: &str) -> SnapResult<Vec<i32>> {
        let n = self.len(field)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32(field)?);
        }
        Ok(v)
    }

    pub fn vec_u64(&mut self, field: &str) -> SnapResult<Vec<u64>> {
        let n = self.len(field)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64(field)?);
        }
        Ok(v)
    }

    /// Consume and validate the trailer sentinel — the final call.
    pub fn finish(mut self) -> SnapResult<u64> {
        let t = self.u64("trailer sentinel")?;
        if t != SNAP_TRAILER {
            return Err(corrupt(format!(
                "trailer sentinel {t:#x} (expected {SNAP_TRAILER:#x}) — stream misaligned"
            )));
        }
        Ok(self.read)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        w.u8(7).unwrap();
        w.bool(true).unwrap();
        w.u32(0xdead_beef).unwrap();
        w.u64(u64::MAX - 3).unwrap();
        w.i32(-42).unwrap();
        w.f32(1.5e-3).unwrap();
        w.f64(-2.25).unwrap();
        w.opt_u64(None).unwrap();
        w.opt_u64(Some(99)).unwrap();
        w.str("mixkvq-mix30").unwrap();
        w.slice_f32(&[0.0, -0.5, f32::MIN_POSITIVE]).unwrap();
        w.slice_i32(&[-1, 0, i32::MAX]).unwrap();
        w.slice_u64(&[1, 2, 3]).unwrap();
        w.slice_f64(&[0.125]).unwrap();
        w.bytes(&[9, 8, 7]).unwrap();
        let written = w.finish().unwrap();
        assert_eq!(written, buf.len() as u64);

        let mut r = SnapReader::new(&buf[..]).unwrap();
        assert_eq!(r.u8("a").unwrap(), 7);
        assert!(r.bool("b").unwrap());
        assert_eq!(r.u32("c").unwrap(), 0xdead_beef);
        assert_eq!(r.u64("d").unwrap(), u64::MAX - 3);
        assert_eq!(r.i32("e").unwrap(), -42);
        assert_eq!(r.f32("f").unwrap(), 1.5e-3);
        assert_eq!(r.f64("g").unwrap(), -2.25);
        assert_eq!(r.opt_u64("h").unwrap(), None);
        assert_eq!(r.opt_u64("i").unwrap(), Some(99));
        assert_eq!(r.str("j").unwrap(), "mixkvq-mix30");
        assert_eq!(r.vec_f32("k").unwrap(), vec![0.0, -0.5, f32::MIN_POSITIVE]);
        assert_eq!(r.vec_i32("l").unwrap(), vec![-1, 0, i32::MAX]);
        assert_eq!(r.vec_u64("m").unwrap(), vec![1, 2, 3]);
        assert_eq!(r.vec_f64("n").unwrap(), vec![0.125]);
        assert_eq!(r.bytes("o").unwrap(), vec![9, 8, 7]);
        r.finish().unwrap();
    }

    #[test]
    fn bad_magic_and_version_are_named_errors() {
        let err = SnapReader::new(&b"not-a-snapshot!!"[..]).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        let mut buf = Vec::new();
        let w = SnapWriter::new(&mut buf).unwrap();
        w.finish().unwrap();
        buf[SNAP_MAGIC.len()] = 99; // version byte
        let err = SnapReader::new(&buf[..]).unwrap_err();
        assert!(err.to_string().contains("schema version 99"), "{err}");
    }

    #[test]
    fn truncation_names_the_field_never_panics() {
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        w.str("hello").unwrap();
        w.u64(5).unwrap();
        w.finish().unwrap();
        // every possible truncation point must yield Err, not panic
        for cut in 0..buf.len() {
            let short = &buf[..cut];
            let r = SnapReader::new(short);
            let Ok(mut r) = r else { continue };
            let res = r
                .str("greeting")
                .and_then(|_| r.u64("count"))
                .and_then(|_| r.finish());
            assert!(res.is_err(), "cut at {cut} must error");
        }
        // full stream names a missing trailing field
        let mut r = SnapReader::new(&buf[..buf.len() - 8]).unwrap();
        r.str("greeting").unwrap();
        r.u64("count").unwrap();
        let err = r.finish().unwrap_err();
        assert!(err.to_string().contains("trailer sentinel"), "{err}");
    }

    #[test]
    fn implausible_length_is_rejected() {
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        w.u64(u64::MAX / 2).unwrap(); // poses as a length prefix
        w.finish().unwrap();
        let mut r = SnapReader::new(&buf[..]).unwrap();
        let err = r.vec_f32("huge").unwrap_err();
        assert!(err.to_string().contains("implausible length"), "{err}");
    }

    #[test]
    fn wrong_trailer_is_a_misalignment_error() {
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        w.u64(1).unwrap();
        w.finish().unwrap();
        let mut r = SnapReader::new(&buf[..]).unwrap();
        // skip nothing: the first u64 is data, so finish() reads it as the
        // trailer and must flag the misalignment
        let err = r.finish().unwrap_err();
        assert!(err.to_string().contains("trailer sentinel"), "{err}");
    }

    #[test]
    fn page_checksum_is_content_sensitive() {
        let f = vec![0.5f32, -1.0, 3.25];
        let b = vec![1u8, 2, 3, 4];
        let h = page_checksum(&f, &b);
        assert_eq!(h, page_checksum(&f, &b));
        let mut f2 = f.clone();
        f2[1] = -1.0000001;
        assert_ne!(h, page_checksum(&f2, &b));
        let mut b2 = b.clone();
        b2[3] ^= 0x10;
        assert_ne!(h, page_checksum(&f, &b2));
        // -0.0 and 0.0 are distinct bit patterns and must hash differently
        assert_ne!(page_checksum(&[0.0], &[]), page_checksum(&[-0.0], &[]));
    }
}
