//! Small statistics helpers shared by the bench harness and experiments.

/// Pearson correlation coefficient (Fig. 3a of the paper reports r = 0.16
/// between query magnitude and key scale).
pub fn pearson(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f32;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f32>() / n;
    let my = y.iter().sum::<f32>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

pub fn mean_f64(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

pub fn rel_l2(approx: &[f32], exact: &[f32]) -> f32 {
    let num: f32 = approx.iter().zip(exact).map(|(a, e)| (a - e).powi(2)).sum();
    let den: f32 = exact.iter().map(|e| e * e).sum();
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-6);
        let yneg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn pearson_uncorrelated_constant() {
        let x = [1.0, 2.0, 3.0];
        let y = [5.0, 5.0, 5.0];
        assert_eq!(pearson(&x, &y), 0.0);
    }

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn rel_l2_zero_when_equal() {
        let a = [1.0, -2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-9);
    }
}
