//! Deterministic, seeded fault injection for chaos testing the serving
//! path.
//!
//! A [`FaultPlan`] names a per-site injection rate and a seed; a
//! [`FaultInjector`] turns it into four independent deterministic draw
//! streams (one per [`FaultSite`], derived with the same
//! [`crate::util::rng::stream`] named-stream discipline the traffic
//! harness uses), so **the same seed produces the same fault schedule** —
//! a chaos soak is exactly as reproducible as a clean run. The injector is
//! shared single-threaded (`Rc<RefCell<…>>`, like the pool and the prefix
//! index) between the server, the engine, and the KV pool; every hook is
//! `Option`-gated and free when no plan is installed.
//!
//! The four sites are the real failure surfaces of the request lifecycle:
//!
//! * [`FaultSite::LeaseDenial`] — `KvPool::lease` fails transiently, as a
//!   fragmented or contended allocator would.
//! * [`FaultSite::PrefillChunk`] — one `Engine::advance_prefill_chunked`
//!   step errors; the router's retry-with-backoff machinery absorbs it.
//! * [`FaultSite::DecodeStep`] — one slot's decode step errors; per-slot
//!   isolation retires that request without poisoning its variant group.
//! * [`FaultSite::PrefixCorrupt`] — a prefix-index entry fails its verify;
//!   the entry is distrusted and dropped, the request falls back to a full
//!   prefill (corrupted pages are never served).

use std::cell::RefCell;
use std::rc::Rc;

use crate::util::rng::{stream, Pcg32};

/// A failure surface faults can be injected at. `name()` doubles as the
/// RNG stream name, so each site draws from its own deterministic stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Transient `KvPool::lease` denial.
    LeaseDenial,
    /// One chunked-prefill advance step errors.
    PrefillChunk,
    /// One slot's decode step errors.
    DecodeStep,
    /// A prefix-index entry fails its token verify (corruption).
    PrefixCorrupt,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] = [
        FaultSite::LeaseDenial,
        FaultSite::PrefillChunk,
        FaultSite::DecodeStep,
        FaultSite::PrefixCorrupt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::LeaseDenial => "fault-lease",
            FaultSite::PrefillChunk => "fault-prefill",
            FaultSite::DecodeStep => "fault-decode",
            FaultSite::PrefixCorrupt => "fault-prefix",
        }
    }

    pub fn index(self) -> usize {
        match self {
            FaultSite::LeaseDenial => 0,
            FaultSite::PrefillChunk => 1,
            FaultSite::DecodeStep => 2,
            FaultSite::PrefixCorrupt => 3,
        }
    }
}

/// Per-site injection rates plus the seed the draw streams derive from.
/// Pure data — install it via `ServerConfig::faults` (or build a
/// [`FaultInjector`] directly in tests).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Injection probability per draw, indexed by [`FaultSite::index`].
    pub rates: [f64; 4],
}

impl FaultPlan {
    /// The same rate at every site — the chaos soak's default shape.
    pub fn uniform(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, rates: [rate; 4] }
    }

    /// Builder-style per-site override.
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rates[site.index()] = rate;
        self
    }

    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    /// Is any site armed? A plan of all-zero rates is equivalent to no
    /// plan at all.
    pub fn is_armed(&self) -> bool {
        self.rates.iter().any(|&r| r > 0.0)
    }
}

/// Counter snapshot, indexed by [`FaultSite::index`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Draws taken at each site (one per hook evaluation with a live plan).
    pub drawn: [u64; 4],
    /// Faults actually injected at each site.
    pub injected: [u64; 4],
}

impl FaultStats {
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().sum()
    }

    pub fn injected_at(&self, site: FaultSite) -> u64 {
        self.injected[site.index()]
    }
}

/// The live draw state: one deterministic [`Pcg32`] stream per site.
/// Single-threaded by design (shared as `Rc<RefCell<FaultInjector>>`);
/// with a fixed call schedule — which the deterministic server loop
/// guarantees — the injected-fault schedule is a pure function of the
/// plan.
pub struct FaultInjector {
    plan: FaultPlan,
    streams: [Pcg32; 4],
    drawn: [u64; 4],
    injected: [u64; 4],
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let streams =
            [0, 1, 2, 3].map(|i| stream(plan.seed, FaultSite::ALL[i].name()));
        FaultInjector { plan, streams, drawn: [0; 4], injected: [0; 4] }
    }

    /// Shared handle the server hands to the pool and the engine.
    pub fn shared(plan: FaultPlan) -> Rc<RefCell<FaultInjector>> {
        Rc::new(RefCell::new(FaultInjector::new(plan)))
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One deterministic draw at `site`. Zero-rate sites never draw (so a
    /// partially armed plan doesn't advance streams it never uses).
    pub fn should_fail(&mut self, site: FaultSite) -> bool {
        let i = site.index();
        let rate = self.plan.rates[i];
        if rate <= 0.0 {
            return false;
        }
        self.drawn[i] += 1;
        let hit = (self.streams[i].f32() as f64) < rate;
        if hit {
            self.injected[i] += 1;
        }
        hit
    }

    pub fn stats(&self) -> FaultStats {
        FaultStats { drawn: self.drawn, injected: self.injected }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let mut a = FaultInjector::new(FaultPlan::uniform(7, 0.25));
        let mut b = FaultInjector::new(FaultPlan::uniform(7, 0.25));
        for site in FaultSite::ALL {
            for _ in 0..256 {
                assert_eq!(a.should_fail(site), b.should_fail(site));
            }
        }
        assert_eq!(a.stats().injected, b.stats().injected);
        assert!(a.stats().injected_total() > 0, "25% over 1024 draws must fire");
    }

    #[test]
    fn sites_draw_from_independent_streams() {
        // Drawing at one site must not perturb another site's schedule.
        let mut interleaved = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let mut solo = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let mut a = Vec::new();
        for _ in 0..64 {
            a.push(interleaved.should_fail(FaultSite::DecodeStep));
            interleaved.should_fail(FaultSite::LeaseDenial);
            interleaved.should_fail(FaultSite::PrefixCorrupt);
        }
        let b: Vec<bool> =
            (0..64).map(|_| solo.should_fail(FaultSite::DecodeStep)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn zero_rate_never_fires_and_never_draws() {
        let mut f = FaultInjector::new(FaultPlan::uniform(9, 0.0));
        for site in FaultSite::ALL {
            for _ in 0..64 {
                assert!(!f.should_fail(site));
            }
        }
        assert_eq!(f.stats().drawn, [0; 4]);
        assert_eq!(f.stats().injected_total(), 0);
    }

    #[test]
    fn rate_one_always_fires() {
        let mut f = FaultInjector::new(FaultPlan::uniform(1, 1.0));
        assert!(f.should_fail(FaultSite::LeaseDenial));
        assert_eq!(f.stats().injected_at(FaultSite::LeaseDenial), 1);
    }

    #[test]
    fn per_site_rates_compose() {
        let plan = FaultPlan::uniform(5, 0.0).with_rate(FaultSite::PrefillChunk, 1.0);
        assert!(plan.is_armed());
        assert_eq!(plan.rate(FaultSite::LeaseDenial), 0.0);
        let mut f = FaultInjector::new(plan);
        assert!(!f.should_fail(FaultSite::LeaseDenial));
        assert!(f.should_fail(FaultSite::PrefillChunk));
    }

    #[test]
    fn observed_rate_tracks_plan() {
        let mut f = FaultInjector::new(FaultPlan::uniform(11, 0.1));
        let mut hits = 0;
        for _ in 0..10_000 {
            if f.should_fail(FaultSite::DecodeStep) {
                hits += 1;
            }
        }
        assert!((800..1200).contains(&hits), "10% ± 2%: got {hits}");
    }
}
