//! Deterministic, seeded fault injection for chaos testing the serving
//! path.
//!
//! A [`FaultPlan`] names a per-site injection rate and a seed; a
//! [`FaultInjector`] turns it into a **stateless keyed draw** per site:
//! every hook supplies a deterministic key (request/cache identity × a
//! per-context draw counter) and the outcome is a pure function of
//! `(seed, site, key)` — no mutable stream state at all. That is what
//! makes the schedule replay-deterministic *regardless of thread
//! schedule*: with the worker pool enabled, lease denials and prefill
//! faults are drawn from worker threads in whatever order the OS runs
//! them, yet the same seed still produces the same fault schedule, and
//! `workers = 1` and `workers = N` produce the *identical* schedule. (The
//! pre-PR-8 injector kept one sequential Pcg32 stream per site, which is
//! deterministic only under a fixed call order — exactly what a thread
//! pool does not guarantee.)
//!
//! The injector is shared as `Arc<FaultInjector>` between the server, the
//! engine, and the KV pool; the only interior state is the atomic
//! drawn/injected counters (order-independent sums, so stats are
//! deterministic too). Every hook is `Option`-gated and free when no plan
//! is installed.
//!
//! The sites are the real failure surfaces of the request lifecycle:
//!
//! * [`FaultSite::LeaseDenial`] — `KvPool::lease` fails transiently, as a
//!   fragmented or contended allocator would.
//! * [`FaultSite::PrefillChunk`] — one `Engine::advance_prefill_chunked`
//!   step errors; the router's retry-with-backoff machinery absorbs it.
//! * [`FaultSite::DecodeStep`] — one slot's decode step errors; per-slot
//!   isolation retires that request without poisoning its variant group.
//! * [`FaultSite::PrefixCorrupt`] — a prefix-index entry fails its verify;
//!   the entry is distrusted and dropped, the request falls back to a full
//!   prefill (corrupted pages are never served).
//! * [`FaultSite::SnapshotWrite`] — a `Server::snapshot` write tears
//!   mid-stream (truncated output, as a crashed disk write would leave).
//! * [`FaultSite::SnapshotCorrupt`] — a serialized KV page's bytes take a
//!   bit flip on the way out; restore detects it via the per-page checksum
//!   and quarantines the page instead of serving corrupt KV.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::rng::{mix64, stream};

/// A failure surface faults can be injected at. `name()` doubles as the
/// RNG stream name, so each site draws from its own decorrelated function
/// of the key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Transient `KvPool::lease` denial.
    LeaseDenial,
    /// One chunked-prefill advance step errors.
    PrefillChunk,
    /// One slot's decode step errors.
    DecodeStep,
    /// A prefix-index entry fails its token verify (corruption).
    PrefixCorrupt,
    /// A snapshot write tears mid-stream (truncated on-disk state).
    SnapshotWrite,
    /// A serialized KV page takes a bit flip (caught by its checksum).
    SnapshotCorrupt,
}

/// Number of fault sites — the length of every per-site array
/// ([`FaultPlan::rates`], [`FaultStats`], the metrics mirrors).
pub const N_FAULT_SITES: usize = 6;

impl FaultSite {
    pub const ALL: [FaultSite; N_FAULT_SITES] = [
        FaultSite::LeaseDenial,
        FaultSite::PrefillChunk,
        FaultSite::DecodeStep,
        FaultSite::PrefixCorrupt,
        FaultSite::SnapshotWrite,
        FaultSite::SnapshotCorrupt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::LeaseDenial => "fault-lease",
            FaultSite::PrefillChunk => "fault-prefill",
            FaultSite::DecodeStep => "fault-decode",
            FaultSite::PrefixCorrupt => "fault-prefix",
            FaultSite::SnapshotWrite => "fault-snapwrite",
            FaultSite::SnapshotCorrupt => "fault-snapcorrupt",
        }
    }

    pub fn index(self) -> usize {
        match self {
            FaultSite::LeaseDenial => 0,
            FaultSite::PrefillChunk => 1,
            FaultSite::DecodeStep => 2,
            FaultSite::PrefixCorrupt => 3,
            FaultSite::SnapshotWrite => 4,
            FaultSite::SnapshotCorrupt => 5,
        }
    }
}

/// Combine a stable context identity (request/cache fault key) with that
/// context's own monotonically increasing draw counter into a draw key.
/// Each context owns its counter, so the key sequence is a pure function
/// of that context's behavior — independent of how contexts interleave
/// across worker threads.
pub fn draw_key(ctx: u64, seq: u64) -> u64 {
    mix64(mix64(ctx) ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15))
}

/// Per-site injection rates plus the seed the draws derive from. Pure
/// data — install it via `ServerConfig::faults` (or build a
/// [`FaultInjector`] directly in tests).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Injection probability per draw, indexed by [`FaultSite::index`].
    pub rates: [f64; N_FAULT_SITES],
}

impl FaultPlan {
    /// The same rate at every site — the chaos soak's default shape.
    pub fn uniform(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, rates: [rate; N_FAULT_SITES] }
    }

    /// The chaos soak's serving shape: every *serving-path* site armed at
    /// `rate`, snapshot sites left quiet (those are armed explicitly by the
    /// snapshot fault tests — a kill/restore equivalence run must not have
    /// its one snapshot torn by the background chaos rate).
    pub fn serving_uniform(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan::uniform(seed, rate)
            .with_rate(FaultSite::SnapshotWrite, 0.0)
            .with_rate(FaultSite::SnapshotCorrupt, 0.0)
    }

    /// Builder-style per-site override.
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        self.rates[site.index()] = rate;
        self
    }

    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    /// Is any site armed? A plan of all-zero rates is equivalent to no
    /// plan at all.
    pub fn is_armed(&self) -> bool {
        self.rates.iter().any(|&r| r > 0.0)
    }
}

/// Counter snapshot, indexed by [`FaultSite::index`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultStats {
    /// Draws taken at each site (one per hook evaluation with a live plan).
    pub drawn: [u64; N_FAULT_SITES],
    /// Faults actually injected at each site.
    pub injected: [u64; N_FAULT_SITES],
}

impl FaultStats {
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().sum()
    }

    pub fn injected_at(&self, site: FaultSite) -> u64 {
        self.injected[site.index()]
    }
}

/// The keyed draw oracle. `should_fail(site, key)` is a pure function of
/// `(plan.seed, site, key)`; the struct carries only the atomic
/// drawn/injected tallies, so a shared `Arc<FaultInjector>` is safe to
/// consult from any worker thread without perturbing any other draw.
pub struct FaultInjector {
    plan: FaultPlan,
    drawn: [AtomicU64; N_FAULT_SITES],
    injected: [AtomicU64; N_FAULT_SITES],
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            drawn: [0; N_FAULT_SITES].map(AtomicU64::new),
            injected: [0; N_FAULT_SITES].map(AtomicU64::new),
        }
    }

    /// Shared handle the server hands to the pool and the engine.
    pub fn shared(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector::new(plan))
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One deterministic draw at `site` under `key` (see [`draw_key`]).
    /// Zero-rate sites never draw (so a partially armed plan doesn't tally
    /// sites it never uses). The same `(site, key)` always yields the same
    /// verdict — callers must advance their per-context counter per draw.
    pub fn should_fail(&self, site: FaultSite, key: u64) -> bool {
        let i = site.index();
        let rate = self.plan.rates[i];
        if rate <= 0.0 {
            return false;
        }
        self.drawn[i].fetch_add(1, Ordering::Relaxed);
        // One decorrelated named stream per (seed ⊕ mixed key, site): the
        // site name folds through the same SplitMix64 finalizer the
        // traffic harness streams use, so sites stay independent under
        // identical keys.
        let hit = (stream(self.plan.seed ^ mix64(key), site.name()).f32() as f64) < rate;
        if hit {
            self.injected[i].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub fn stats(&self) -> FaultStats {
        let mut s = FaultStats::default();
        for i in 0..N_FAULT_SITES {
            s.drawn[i] = self.drawn[i].load(Ordering::Relaxed);
            s.injected[i] = self.injected[i].load(Ordering::Relaxed);
        }
        s
    }

    /// Reinstate tallies captured by a snapshot — a restored server's fault
    /// counters continue from where the snapshotted one stood, so the
    /// chaos-soak fingerprint folds identical totals whether or not the run
    /// was interrupted. The draws themselves are stateless keyed functions,
    /// so only the tallies need restoring.
    pub fn restore_stats(&self, stats: &FaultStats) {
        for i in 0..N_FAULT_SITES {
            self.drawn[i].store(stats.drawn[i], Ordering::Relaxed);
            self.injected[i].store(stats.injected[i], Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultInjector::new(FaultPlan::uniform(7, 0.25));
        let b = FaultInjector::new(FaultPlan::uniform(7, 0.25));
        for site in FaultSite::ALL {
            for seq in 0..256u64 {
                let k = draw_key(42, seq);
                assert_eq!(a.should_fail(site, k), b.should_fail(site, k));
            }
        }
        assert_eq!(a.stats().injected, b.stats().injected);
        assert!(a.stats().injected_total() > 0, "25% over 1024 draws must fire");
    }

    #[test]
    fn draw_order_does_not_matter() {
        // The worker-pool property: the same set of (site, key) draws in a
        // different order — e.g. a different thread interleaving — yields
        // the identical schedule and identical tallies.
        let fwd = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let rev = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let keys: Vec<u64> = (0..128).map(|s| draw_key(9, s)).collect();
        let a: Vec<bool> =
            keys.iter().map(|&k| fwd.should_fail(FaultSite::DecodeStep, k)).collect();
        let mut b: Vec<bool> = keys
            .iter()
            .rev()
            .map(|&k| rev.should_fail(FaultSite::DecodeStep, k))
            .collect();
        b.reverse();
        assert_eq!(a, b);
        assert_eq!(fwd.stats().injected, rev.stats().injected);
    }

    #[test]
    fn sites_draw_from_independent_streams() {
        // Drawing at one site must not perturb another site's schedule,
        // and identical keys at different sites must decorrelate.
        let interleaved = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let solo = FaultInjector::new(FaultPlan::uniform(3, 0.5));
        let mut a = Vec::new();
        for seq in 0..64u64 {
            let k = draw_key(1, seq);
            a.push(interleaved.should_fail(FaultSite::DecodeStep, k));
            interleaved.should_fail(FaultSite::LeaseDenial, k);
            interleaved.should_fail(FaultSite::PrefixCorrupt, k);
        }
        let b: Vec<bool> = (0..64u64)
            .map(|seq| solo.should_fail(FaultSite::DecodeStep, draw_key(1, seq)))
            .collect();
        assert_eq!(a, b);
        // same keys, different site ⇒ a different (decorrelated) schedule
        let c: Vec<bool> = (0..64u64)
            .map(|seq| solo.should_fail(FaultSite::LeaseDenial, draw_key(1, seq)))
            .collect();
        assert_ne!(a, c);
    }

    #[test]
    fn zero_rate_never_fires_and_never_draws() {
        let f = FaultInjector::new(FaultPlan::uniform(9, 0.0));
        for site in FaultSite::ALL {
            for seq in 0..64u64 {
                assert!(!f.should_fail(site, draw_key(0, seq)));
            }
        }
        assert_eq!(f.stats().drawn, [0; N_FAULT_SITES]);
        assert_eq!(f.stats().injected_total(), 0);
    }

    #[test]
    fn restore_stats_round_trips_tallies() {
        let a = FaultInjector::new(FaultPlan::uniform(7, 0.5));
        for seq in 0..64u64 {
            a.should_fail(FaultSite::DecodeStep, draw_key(1, seq));
            a.should_fail(FaultSite::SnapshotCorrupt, draw_key(2, seq));
        }
        let snap = a.stats();
        let b = FaultInjector::new(FaultPlan::uniform(7, 0.5));
        b.restore_stats(&snap);
        assert_eq!(b.stats().drawn, snap.drawn);
        assert_eq!(b.stats().injected, snap.injected);
        // draws continue identically after restore (stateless keyed draws)
        assert_eq!(
            a.should_fail(FaultSite::DecodeStep, draw_key(1, 64)),
            b.should_fail(FaultSite::DecodeStep, draw_key(1, 64))
        );
        assert_eq!(a.stats().injected, b.stats().injected);
    }

    #[test]
    fn serving_uniform_leaves_snapshot_sites_quiet() {
        let plan = FaultPlan::serving_uniform(3, 0.25);
        assert_eq!(plan.rate(FaultSite::SnapshotWrite), 0.0);
        assert_eq!(plan.rate(FaultSite::SnapshotCorrupt), 0.0);
        assert_eq!(plan.rate(FaultSite::DecodeStep), 0.25);
        assert!(plan.is_armed());
    }

    #[test]
    fn rate_one_always_fires() {
        let f = FaultInjector::new(FaultPlan::uniform(1, 1.0));
        assert!(f.should_fail(FaultSite::LeaseDenial, draw_key(0, 0)));
        assert_eq!(f.stats().injected_at(FaultSite::LeaseDenial), 1);
    }

    #[test]
    fn per_site_rates_compose() {
        let plan = FaultPlan::uniform(5, 0.0).with_rate(FaultSite::PrefillChunk, 1.0);
        assert!(plan.is_armed());
        assert_eq!(plan.rate(FaultSite::LeaseDenial), 0.0);
        let f = FaultInjector::new(plan);
        assert!(!f.should_fail(FaultSite::LeaseDenial, draw_key(0, 0)));
        assert!(f.should_fail(FaultSite::PrefillChunk, draw_key(0, 0)));
    }

    #[test]
    fn observed_rate_tracks_plan() {
        let f = FaultInjector::new(FaultPlan::uniform(11, 0.1));
        let mut hits = 0;
        for seq in 0..10_000u64 {
            if f.should_fail(FaultSite::DecodeStep, draw_key(17, seq)) {
                hits += 1;
            }
        }
        assert!((800..1200).contains(&hits), "10% ± 2%: got {hits}");
    }

    #[test]
    fn injector_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FaultInjector>();
        assert_send_sync::<Arc<FaultInjector>>();
    }
}
