//! Micro/macro benchmark harness (criterion is not in the offline crate
//! set; this provides warmup + repeated timing + robust summary stats and a
//! stable text format that `cargo bench` binaries print).

use std::time::Instant;

use crate::util::stats::percentile;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>9.3} ms  median {:>9.3} ms  p95 {:>9.3} ms  min {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.median_ms, self.p95_ms, self.min_ms
        )
    }
}

/// Time `f` with warmup; stops after `max_iters` or `budget_ms`, whichever
/// comes first (minimum 3 measured iterations).
pub fn bench<F: FnMut()>(name: &str, max_iters: usize, budget_ms: f64, mut f: F) -> BenchResult {
    // warmup
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters.max(3)
        && (samples.len() < 3 || start.elapsed().as_secs_f64() * 1e3 < budget_ms)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        if samples.len() >= max_iters {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ms: mean,
        median_ms: percentile(&samples, 50.0),
        p95_ms: percentile(&samples, 95.0),
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Simple fixed-width table printer for bench/experiment outputs.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn print(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i] + 2))
                .collect::<String>()
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().map(|x| x + 2).sum::<usize>()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop", 10, 50.0, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.min_ms <= r.median_ms && r.median_ms <= r.p95_ms + 1e-9);
    }

    #[test]
    fn table_prints_all_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.print();
        assert!(s.contains("demo") && s.contains("333"));
        assert_eq!(s.lines().filter(|l| !l.is_empty()).count(), 5);
    }
}
