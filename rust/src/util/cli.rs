//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number, got `{v}`")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got `{v}`")),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn subcommand(&self) -> Result<&str> {
        self.positional
            .first()
            .map(|s| s.as_str())
            .context("missing subcommand")
    }

    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["serve", "--variant=mix30", "--requests", "64", "--verbose"]);
        assert_eq!(a.subcommand().unwrap(), "serve");
        assert_eq!(a.get("variant"), Some("mix30"));
        assert_eq!(a.usize_or("requests", 1).unwrap(), 64);
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["bench"]);
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("method", "mixkvq-mix30"), "mixkvq-mix30");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&["x", "--good", "1", "--bad", "2"]);
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["good", "bad"]).is_ok());
    }
}
