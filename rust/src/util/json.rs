//! Minimal JSON parser/printer — `serde_json` is not in the offline crate
//! set, and the only JSON we touch is our own build artifacts
//! (`meta.json`, `*.inputs.json`, bench reports), all with known schemas.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    /// One-word description of the variant — so "expected X, found Y"
    /// errors name what the artifact actually contained.
    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "a bool",
            Json::Num(_) => "a number",
            Json::Str(_) => "a string",
            Json::Arr(_) => "an array",
            Json::Obj(_) => "an object",
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            v => bail!("expected a number, found {}", v.kind()),
        }
    }

    /// Strict: the number must be a finite non-negative integer — a
    /// negative count or NaN in an artifact is schema damage, not a value
    /// to silently truncate to 0.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if !n.is_finite() || n < 0.0 || n.fract() != 0.0 {
            bail!("expected a non-negative integer, found {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            v => bail!("expected a string, found {}", v.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            v => bail!("expected an array, found {}", v.kind()),
        }
    }

    pub fn print(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            // bounds-checked: a `\uXX` cut off by truncation
                            // is a parse error, not a slice panic
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape at {}", self.i))?;
                            let hex = std::str::from_utf8(hex)?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 runs; a multibyte sequence the input
                    // ends in the middle of is a parse error, not a panic
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let run = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8 sequence at {start}"))?;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(run)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\n", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.print()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn manifest_shape() {
        let src = r#"[{"name":"token","shape":[8],"dtype":"i32"}]"#;
        let v = Json::parse(src).unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), "token");
        assert_eq!(e.get("shape").unwrap().as_arr().unwrap()[0].as_usize().unwrap(), 8);
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"m":{"x":{"y":[[1,2],[3,4]]}}}"#).unwrap();
        let y = v.get("m").unwrap().get("x").unwrap().get("y").unwrap();
        assert_eq!(y.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{oops}").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éx");
    }

    #[test]
    fn truncated_inputs_error_instead_of_panicking() {
        // every cut point of a string exercising \u escapes and multibyte
        // UTF-8 must parse or error — never slice out of bounds
        let src = r#"{"k": "aéé", "n": 12}"#;
        assert!(Json::parse(src).is_ok());
        for cut in 0..src.len() {
            if !src.is_char_boundary(cut) {
                continue;
            }
            let _ = Json::parse(&src[..cut]); // must not panic
        }
        // the historical panic, pinned directly: a \u escape cut off by
        // truncation used to slice out of bounds
        assert!(Json::parse(r#""\u00"#).is_err());
        assert!(Json::parse(r#""\u"#).is_err());
    }

    #[test]
    fn accessors_name_what_they_found() {
        let v = Json::parse(r#"{"s": "hi", "neg": -3, "frac": 1.5}"#).unwrap();
        let e = v.get("s").unwrap().as_f64().unwrap_err().to_string();
        assert!(e.contains("a string"), "unhelpful error: {e}");
        let e = v.get("neg").unwrap().as_usize().unwrap_err().to_string();
        assert!(e.contains("-3"), "unhelpful error: {e}");
        assert!(v.get("frac").unwrap().as_usize().is_err());
        let e = v.get("missing").unwrap_err().to_string();
        assert!(e.contains("missing"), "unhelpful error: {e}");
    }
}
