//! Fixed-size worker pool for the multi-core engine (PR 8).
//!
//! A [`WorkerPool`] owns `n - 1` persistent OS threads plus the calling
//! thread (worker 0), each with a pre-warmed [`WorkerScratch`] arena built
//! at pool construction — the steady-state decode path allocates nothing
//! on any worker thread (the arenas are the same [`DecodeScratch`] the
//! single-threaded engine reuses). `workers = 1` spawns no threads and
//! runs every job inline on the caller's scratch: it is *exactly* the
//! pre-pool single-threaded engine, not a degenerate thread pool.
//!
//! # Determinism contract
//!
//! [`WorkerPool::run`] assigns job `i` to worker `i % n` (a pure function
//! of the job index) and returns results **in job-index order**, whatever
//! order workers finish in — the deterministic-merge rule the bit-identity
//! gate relies on (the `matmul_blocked` summation-order discipline, lifted
//! to the scheduling layer). Jobs must be data-independent: nothing in the
//! pool serializes them, and the engine's dispatchers only hand out
//! disjoint slots / disjoint head ranges.
//!
//! # Borrowed jobs
//!
//! Jobs may borrow caller state (caches, weights, output slices). `run`
//! erases the borrow lifetime to ship closures to the persistent threads,
//! which is sound because `run` blocks until every dispatched job has sent
//! its result back — no borrow outlives the call. A panicking job is
//! caught on the worker, carried home through the result channel, and
//! re-raised on the caller *after* all jobs drain, preserving the same
//! no-escape guarantee on the unwind path.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::model::config::ModelConfig;
use crate::model::reference::DecodeScratch;

/// Per-worker arena, pre-warmed at pool construction. One per worker
/// (including the caller-as-worker-0), owned by that worker for the pool's
/// lifetime — jobs receive `&mut` to *their* worker's arena only.
pub struct WorkerScratch {
    /// Worker index in `0..n`.
    pub id: usize,
    /// The fused-decode arena: slot-level decode jobs run whole steps in
    /// it; head-split jobs borrow its `qrot`/`qperm`/`w4`/`w2`/`scores`
    /// lanes. Prefill jobs don't need it — a `PrefillRun` *is* its own
    /// resumable arena, so prefill units carry their scratch with them.
    pub decode: DecodeScratch,
}

/// Busy-time snapshot for one worker (observability satellite).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerLoad {
    pub busy_ns: u64,
    pub jobs: u64,
}

type ErasedJob = Box<dyn FnOnce(&mut WorkerScratch) + Send + 'static>;

struct SpawnedWorker {
    tx: Option<Sender<ErasedJob>>,
    busy_ns: Arc<AtomicU64>,
    jobs: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

pub struct WorkerPool {
    /// Workers 1..n; worker 0 is the calling thread.
    spawned: Vec<SpawnedWorker>,
    local: WorkerScratch,
    local_busy_ns: u64,
    local_jobs: u64,
}

fn worker_main(
    rx: Receiver<ErasedJob>,
    mut scratch: WorkerScratch,
    busy: Arc<AtomicU64>,
    jobs: Arc<AtomicU64>,
) {
    // Jobs arrive pre-wrapped in catch_unwind, so this loop never unwinds;
    // it exits when the pool drops its Sender.
    while let Ok(job) = rx.recv() {
        let t0 = Instant::now();
        job(&mut scratch);
        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        jobs.fetch_add(1, Ordering::Relaxed);
    }
}

impl WorkerPool {
    /// Build a pool of `n` workers (clamped to ≥ 1), each with a decode
    /// arena sized for `mc` and `max_scores` (quantized capacity +
    /// residual + 1, same sizing as the engine's own scratch).
    pub fn new(n: usize, mc: &ModelConfig, max_scores: usize) -> WorkerPool {
        let n = n.max(1);
        let mut spawned = Vec::with_capacity(n - 1);
        for id in 1..n {
            let scratch = WorkerScratch { id, decode: DecodeScratch::new(mc, max_scores) };
            let busy = Arc::new(AtomicU64::new(0));
            let jobs = Arc::new(AtomicU64::new(0));
            let (tx, rx) = channel::<ErasedJob>();
            let (b, j) = (busy.clone(), jobs.clone());
            let handle = std::thread::Builder::new()
                .name(format!("mixkvq-worker-{id}"))
                .spawn(move || worker_main(rx, scratch, b, j))
                .expect("spawn worker thread");
            spawned.push(SpawnedWorker {
                tx: Some(tx),
                busy_ns: busy,
                jobs,
                handle: Some(handle),
            });
        }
        WorkerPool {
            spawned,
            local: WorkerScratch { id: 0, decode: DecodeScratch::new(mc, max_scores) },
            local_busy_ns: 0,
            local_jobs: 0,
        }
    }

    pub fn size(&self) -> usize {
        self.spawned.len() + 1
    }

    /// The caller-thread worker's arena — the single-threaded engine path
    /// borrows this directly so `workers = 1` reuses the same pre-warmed
    /// allocation story as before the pool existed.
    pub fn local_scratch(&mut self) -> &mut WorkerScratch {
        &mut self.local
    }

    /// Per-worker busy counters, index 0 = the calling thread.
    pub fn loads(&self) -> Vec<WorkerLoad> {
        let mut out = Vec::with_capacity(self.size());
        out.push(WorkerLoad { busy_ns: self.local_busy_ns, jobs: self.local_jobs });
        for w in &self.spawned {
            out.push(WorkerLoad {
                busy_ns: w.busy_ns.load(Ordering::Relaxed),
                jobs: w.jobs.load(Ordering::Relaxed),
            });
        }
        out
    }

    /// Run `jobs` across the pool and return their results in job-index
    /// order. Job `i` runs on worker `i % n`; worker 0 is the calling
    /// thread, which executes its share while the spawned workers drain
    /// theirs. Blocks until every job completes (the borrow-soundness
    /// barrier). With `n == 1` every job runs inline, in order.
    pub fn run<'a, T, F>(&mut self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'a,
        F: FnOnce(&mut WorkerScratch) -> T + Send + 'a,
    {
        let n = self.size();
        let total = jobs.len();
        if n == 1 || total <= 1 {
            let mut out = Vec::with_capacity(total);
            for f in jobs {
                let t0 = Instant::now();
                out.push(f(&mut self.local));
                self.local_busy_ns += t0.elapsed().as_nanos() as u64;
                self.local_jobs += 1;
            }
            return out;
        }

        let mut results: Vec<Option<T>> = (0..total).map(|_| None).collect();
        let mut panics: Vec<Box<dyn std::any::Any + Send>> = Vec::new();
        let (rtx, rrx) = channel::<(usize, std::thread::Result<T>)>();
        let mut local_jobs: Vec<(usize, F)> = Vec::new();
        let mut remote = 0usize;
        for (i, f) in jobs.into_iter().enumerate() {
            let w = i % n;
            if w == 0 {
                local_jobs.push((i, f));
                continue;
            }
            let tx = rtx.clone();
            let job: Box<dyn FnOnce(&mut WorkerScratch) + Send + 'a> =
                Box::new(move |s: &mut WorkerScratch| {
                    let r = catch_unwind(AssertUnwindSafe(|| f(s)));
                    let _ = tx.send((i, r));
                });
            // SAFETY: lifetime erasure only — we block below until every
            // dispatched job has reported back (success or panic), so no
            // borrow captured by `job` outlives this call.
            let job: ErasedJob = unsafe { std::mem::transmute(job) };
            self.spawned[w - 1]
                .tx
                .as_ref()
                .expect("worker pool already shut down")
                .send(job)
                .expect("worker thread died");
            remote += 1;
        }
        drop(rtx);
        // Worker 0's share runs here while the spawned workers execute.
        for (i, f) in local_jobs {
            let t0 = Instant::now();
            match catch_unwind(AssertUnwindSafe(|| f(&mut self.local))) {
                Ok(v) => results[i] = Some(v),
                Err(p) => panics.push(p),
            }
            self.local_busy_ns += t0.elapsed().as_nanos() as u64;
            self.local_jobs += 1;
        }
        // The barrier: every remote job must report before we return (or
        // unwind) — this is what makes the lifetime erasure above sound.
        for _ in 0..remote {
            let (i, r) = rrx.recv().expect("worker pool result channel broken");
            match r {
                Ok(v) => results[i] = Some(v),
                Err(p) => panics.push(p),
            }
        }
        if let Some(p) = panics.into_iter().next() {
            resume_unwind(p);
        }
        results
            .into_iter()
            .map(|r| r.expect("worker job produced no result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &mut self.spawned {
            w.tx.take(); // closes the channel; the worker loop exits
        }
        for w in &mut self.spawned {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Split `len` items into up to `parts` contiguous ranges, remainder
/// spread over the leading ranges — the deterministic head-split /
/// slot-split rule. Returns only non-empty ranges.
pub fn split_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1).min(len.max(1));
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut at = 0;
    for p in 0..parts {
        let take = base + usize::from(p < rem);
        if take == 0 {
            break;
        }
        out.push((at, at + take));
        at += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn pool(n: usize) -> WorkerPool {
        let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        WorkerPool::new(n, &mc, 64)
    }

    #[test]
    fn results_come_back_in_job_order() {
        let mut p = pool(4);
        for round in 0..8 {
            let jobs: Vec<_> = (0..23)
                .map(|i| move |_s: &mut WorkerScratch| i * 10 + round)
                .collect();
            let got = p.run(jobs);
            let want: Vec<_> = (0..23).map(|i| i * 10 + round).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn single_worker_runs_inline() {
        let mut p = pool(1);
        assert_eq!(p.size(), 1);
        let caller = std::thread::current().id();
        let jobs: Vec<_> = (0..4)
            .map(|i| {
                move |s: &mut WorkerScratch| {
                    assert_eq!(s.id, 0, "workers=1 must run on the caller arena");
                    assert_eq!(std::thread::current().id(), caller);
                    i
                }
            })
            .collect();
        let got = p.run(jobs);
        assert_eq!(got, vec![0, 1, 2, 3]);
        let loads = p.loads();
        assert_eq!(loads.len(), 1);
        assert_eq!(loads[0].jobs, 4);
    }

    #[test]
    fn jobs_can_borrow_and_mutate_disjoint_slices() {
        let mut p = pool(3);
        let mut data = vec![0u64; 12];
        {
            let chunks: Vec<&mut [u64]> = data.chunks_mut(4).collect();
            let jobs: Vec<_> = chunks
                .into_iter()
                .enumerate()
                .map(|(ci, chunk)| {
                    move |_s: &mut WorkerScratch| {
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = (ci * 100 + j) as u64;
                        }
                        ci
                    }
                })
                .collect();
            let ids = p.run(jobs);
            assert_eq!(ids, vec![0, 1, 2]);
        }
        assert_eq!(data[0], 0);
        assert_eq!(data[4], 100);
        assert_eq!(data[11], 203);
    }

    #[test]
    fn worker_panic_propagates_after_drain() {
        let mut p = pool(4);
        let jobs: Vec<Box<dyn FnOnce(&mut WorkerScratch) -> usize + Send>> = (0..8)
            .map(|i| {
                Box::new(move |_s: &mut WorkerScratch| {
                    if i == 5 {
                        panic!("job 5 exploded");
                    }
                    i
                }) as _
            })
            .collect();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| p.run(jobs)));
        assert!(r.is_err(), "panic must propagate to the caller");
        // pool still usable after a job panic
        let ok = p.run((0..4).map(|i| move |_s: &mut WorkerScratch| i).collect::<Vec<_>>());
        assert_eq!(ok, vec![0, 1, 2, 3]);
    }

    #[test]
    fn jobs_land_on_distinct_workers() {
        let mut p = pool(4);
        let ids = p.run(
            (0..8)
                .map(|_| {
                    |s: &mut WorkerScratch| {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        s.id
                    }
                })
                .collect::<Vec<_>>(),
        );
        // job i runs on worker i % 4, by construction
        assert_eq!(ids, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        let loads = p.loads();
        assert_eq!(loads.len(), 4);
        assert!(loads.iter().all(|l| l.jobs == 2), "{loads:?}");
    }

    #[test]
    fn split_ranges_covers_exactly() {
        assert_eq!(split_ranges(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(split_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(split_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(split_ranges(7, 1), vec![(0, 7)]);
    }
}
