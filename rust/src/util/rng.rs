//! Deterministic PCG32 RNG — no external `rand` crate in the offline set.
//!
//! Used by workload generators (harness) and the property-test sweeps, so
//! every experiment is reproducible from a seed recorded in EXPERIMENTS.md.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample k distinct values from [0, n).
    pub fn sample_distinct(&mut self, n: u32, k: usize) -> Vec<u32> {
        assert!(k as u32 <= n);
        let mut pool: Vec<u32> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((pool.len() - i) as u32) as usize;
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..10_000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn distinct_sample() {
        let mut rng = Pcg32::seeded(3);
        let s = rng.sample_distinct(32, 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(4);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
