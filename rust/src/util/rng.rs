//! Deterministic PCG32 RNG — no external `rand` crate in the offline set.
//!
//! Used by workload generators (harness) and the property-test sweeps, so
//! every experiment is reproducible from a seed recorded in EXPERIMENTS.md.
//!
//! [`stream`] derives independent named sub-streams from one root seed
//! (SplitMix64 mixing), so a single `--seed` fans out into decorrelated
//! arrival/prompt-mix/tenant streams: drawing more values from one stream
//! never perturbs another, which is what makes the traffic harness's
//! same-seed runs byte-identical.

/// SplitMix64 (Steele et al.) — the stream/seed mixer. Passes into
/// [`Pcg32`] seeds; also usable standalone where a full-period 64-bit
/// sequence is enough.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        mix64(self.state)
    }
}

/// SplitMix64 finalizer: a bijective avalanche over 64 bits. Public so the
/// fault injector can derive schedule-independent keyed draws from the same
/// mixer the named streams use.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Derive the named sub-stream of `seed`: same `(seed, name)` always yields
/// the same generator; different names yield decorrelated generators (the
/// name is folded through the SplitMix64 finalizer, not hashed ad hoc).
pub fn stream(seed: u64, name: &str) -> Pcg32 {
    let mut tag = 0x6d69_786b_7671u64; // "mixkvq"
    for &b in name.as_bytes() {
        tag = mix64(tag ^ (b as u64 + 1));
    }
    let mut sm = SplitMix64::new(seed ^ tag);
    let s = sm.next_u64();
    let inc = sm.next_u64();
    Pcg32::new(s, inc)
}

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Raw `(state, inc)` pair — the complete generator state, captured by
    /// `Server::snapshot` so a restored server's sampler continues the
    /// exact draw sequence of the one it replaces.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact `(state, inc)` position (the inverse
    /// of [`Pcg32::state`] — no warm-up draws, unlike [`Pcg32::new`]).
    pub fn from_state(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample k distinct values from [0, n).
    pub fn sample_distinct(&mut self, n: u32, k: usize) -> Vec<u32> {
        assert!(k as u32 <= n);
        let mut pool: Vec<u32> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((pool.len() - i) as u32) as usize;
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..10_000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn distinct_sample() {
        let mut rng = Pcg32::seeded(3);
        let s = rng.sample_distinct(32, 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn streams_are_deterministic_and_independent() {
        // same (seed, name) ⇒ identical stream
        let mut a = stream(7, "arrivals");
        let mut b = stream(7, "arrivals");
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        // different names ⇒ decorrelated (first draws differ), and drawing
        // from one stream never perturbs another
        let mut c = stream(7, "prompts");
        assert_ne!(stream(7, "arrivals").next_u32(), c.next_u32());
        let mut d1 = stream(7, "tenants");
        let mut d2 = stream(7, "tenants");
        let _ = c.next_u32(); // extra draws elsewhere
        for _ in 0..16 {
            assert_eq!(d1.next_u32(), d2.next_u32());
        }
        // different seeds ⇒ different streams under the same name
        assert_ne!(stream(7, "arrivals").next_u64(), stream(8, "arrivals").next_u64());
    }

    #[test]
    fn state_round_trip_continues_the_sequence() {
        let mut a = Pcg32::seeded(42);
        for _ in 0..17 {
            a.next_u32();
        }
        let (s, inc) = a.state();
        let mut b = Pcg32::from_state(s, inc);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(SplitMix64::new(1).next_u64(), SplitMix64::new(2).next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(4);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
