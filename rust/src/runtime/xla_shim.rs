//! Backend seam for the PJRT bindings.
//!
//! With the `pjrt` feature AND `--cfg pjrt_linked` (the artifact build
//! environment), this re-exports the vendored `xla` crate (the
//! artifact build environment's PJRT bindings). Without it — the default in
//! the offline build set — a stub with the same surface compiles instead:
//! every entry point type-checks, and the only reachable runtime call,
//! `PjRtClient::cpu()`, fails with a clear "PJRT unavailable" error, so the
//! non-executing layers (quantization, caches, batching, the serving
//! frontend) stay fully usable and testable.

// The real bindings need BOTH the `pjrt` feature AND the artifact build's
// `--cfg pjrt_linked` (set once the vendored xla crate is wired into
// [dependencies]); with the feature alone — e.g. CI's feature-matrix
// `cargo check --features pjrt` on a plain checkout — the stub still
// compiles, so the gated surface cannot rot unnoticed.
#[cfg(all(feature = "pjrt", pjrt_linked))]
pub use xla::*;

#[cfg(not(all(feature = "pjrt", pjrt_linked)))]
pub use stub::*;

#[cfg(not(all(feature = "pjrt", pjrt_linked)))]
mod stub {
    use std::fmt;

    #[derive(Debug)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable<T>() -> Result<T, Error> {
        let why = if cfg!(feature = "pjrt") {
            "the `pjrt` feature is on but the vendored xla crate is not \
             linked (wire it into [dependencies] and build with \
             RUSTFLAGS=\"--cfg pjrt_linked\")"
        } else {
            "built without the `pjrt` feature (rebuild with --features pjrt \
             and the vendored xla crate)"
        };
        Err(Error(format!("PJRT runtime unavailable: {why}")))
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum ElementType {
        F32,
        S32,
        U8,
    }

    #[derive(Debug)]
    pub struct Literal;

    impl Literal {
        pub fn create_from_shape_and_untyped_data(
            _ty: ElementType,
            _shape: &[usize],
            _bytes: &[u8],
        ) -> Result<Literal, Error> {
            unavailable()
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
            unavailable()
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            unavailable()
        }
    }

    #[derive(Debug)]
    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            unavailable()
        }
    }

    #[derive(Debug)]
    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    #[derive(Debug)]
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unavailable()
        }
    }

    #[derive(Debug)]
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unavailable()
        }

        pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unavailable()
        }
    }

    #[derive(Debug)]
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            unavailable()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unavailable()
        }

        pub fn buffer_from_host_literal(
            &self,
            _device: Option<usize>,
            _literal: &Literal,
        ) -> Result<PjRtBuffer, Error> {
            unavailable()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_reports_unavailable() {
            let e = PjRtClient::cpu().unwrap_err();
            assert!(e.to_string().contains("PJRT runtime unavailable"));
            assert!(Literal::create_from_shape_and_untyped_data(
                ElementType::F32,
                &[1],
                &[0, 0, 0, 0]
            )
            .is_err());
        }
    }
}
