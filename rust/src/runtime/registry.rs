//! Artifact discovery: pairs each `<name>.hlo.txt` with its
//! `<name>.inputs.json` positional manifest (the python↔rust ABI).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u8" => DType::U8,
            _ => bail!("unknown dtype `{s}`"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub hlo_path: PathBuf,
    pub manifest: Vec<InputSpec>,
}

impl Artifact {
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<Artifact> {
        let hlo_path = artifacts_dir.join(format!("{name}.hlo.txt"));
        if !hlo_path.exists() {
            bail!("missing artifact {hlo_path:?} — run `make artifacts`");
        }
        let mpath = artifacts_dir.join(format!("{name}.inputs.json"));
        let src = std::fs::read_to_string(&mpath).with_context(|| format!("reading {mpath:?}"))?;
        let j = Json::parse(&src)?;
        let mut manifest = Vec::new();
        for e in j.as_arr()? {
            manifest.push(InputSpec {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?,
                dtype: DType::parse(e.get("dtype")?.as_str()?)?,
            });
        }
        Ok(Artifact { name: name.to_string(), hlo_path, manifest })
    }

    /// Index of the first non-weight input (weights come first by ABI).
    pub fn first_dynamic(&self, n_params: usize) -> usize {
        n_params
    }
}

/// Artifact names for a serving setup.
pub fn decode_artifact(variant: &str) -> String {
    format!("decode_{variant}")
}

pub fn prefill_artifact(bucket: usize) -> String {
    format!("prefill_t{bucket}")
}

/// Smallest prefill bucket that fits `len` tokens.
pub fn pick_bucket(buckets: &[usize], len: usize) -> Result<usize> {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= len)
        .min()
        .with_context(|| format!("prompt of {len} tokens exceeds every prefill bucket"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("u8").unwrap().size(), 1);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn bucket_selection() {
        let buckets = [128usize, 512];
        assert_eq!(pick_bucket(&buckets, 60).unwrap(), 128);
        assert_eq!(pick_bucket(&buckets, 128).unwrap(), 128);
        assert_eq!(pick_bucket(&buckets, 129).unwrap(), 512);
        assert!(pick_bucket(&buckets, 513).is_err());
    }

    #[test]
    fn artifact_names() {
        assert_eq!(decode_artifact("mix30"), "decode_mix30");
        assert_eq!(prefill_artifact(128), "prefill_t128");
    }
}
