//! PJRT CPU client wrapper: HLO-text load → compile → cached executables.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::executor::Executable;
use super::registry::Artifact;
use super::xla_shim as xla;

pub struct Runtime {
    pub client: xla::PjRtClient,
    executables: HashMap<String, Executable>,
    pub compile_times_ms: Vec<(String, f64)>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, executables: HashMap::new(), compile_times_ms: Vec::new() })
    }

    /// Load + compile one artifact (no-op if already resident).
    pub fn load(&mut self, artifacts_dir: &Path, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let art = Artifact::load(artifacts_dir, name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {:?}: {e:?}", art.hlo_path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        self.compile_times_ms.push((name.to_string(), ms));
        self.executables.insert(name.to_string(), Executable::new(exe, art.manifest));
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .with_context(|| format!("executable `{name}` not loaded"))
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}
