//! Typed execution over PJRT: positional args validated against the
//! artifact manifest, outputs decomposed from the return tuple.

use anyhow::{bail, Context, Result};

use super::registry::{DType, InputSpec};
use super::xla_shim as xla;

/// A borrowed argument value; must match the manifest slot's dtype/elems.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U8(&'a [u8]),
}

impl<'a> Arg<'a> {
    fn dtype(&self) -> DType {
        match self {
            Arg::F32(_) => DType::F32,
            Arg::I32(_) => DType::I32,
            Arg::U8(_) => DType::U8,
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F32(x) => x.len(),
            Arg::I32(x) => x.len(),
            Arg::U8(x) => x.len(),
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match self {
            Arg::F32(x) => (xla::ElementType::F32, bytemuck_f32(x)),
            Arg::I32(x) => (xla::ElementType::S32, bytemuck_i32(x)),
            Arg::U8(x) => (xla::ElementType::U8, x),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e:?}"))
    }
}

/// A device buffer plus the host literal that backs it. TfrtCpu's
/// BufferFromHostLiteral copies asynchronously, so the literal MUST stay
/// alive as long as the buffer may be read (dropping it early is a
/// use-after-free SEGV). The raw-bytes upload path is unusable instead: the
/// vendored crate passes an ElementType discriminant where the C ABI wants
/// a PrimitiveType, silently mis-sizing f32 uploads.
pub struct DeviceArg {
    pub buf: xla::PjRtBuffer,
    _backing: xla::Literal,
}

/// Upload one argument to the device (dynamic-arg path of run_b).
pub fn upload(client: &xla::PjRtClient, arg: &Arg, shape: &[usize]) -> Result<DeviceArg> {
    let lit = arg.to_literal(shape)?;
    let buf = client
        .buffer_from_host_literal(None, &lit)
        .map_err(|e| anyhow::anyhow!("buffer upload: {e:?}"))?;
    Ok(DeviceArg { buf, _backing: lit })
}

fn bytemuck_f32(x: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn bytemuck_i32(x: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Vec<InputSpec>,
    /// Cumulative wall time spent inside PJRT execute (metrics).
    pub exec_ns: std::cell::Cell<u64>,
    pub exec_calls: std::cell::Cell<u64>,
}

impl Executable {
    pub fn new(exe: xla::PjRtLoadedExecutable, manifest: Vec<InputSpec>) -> Self {
        Executable { exe, manifest, exec_ns: 0.into(), exec_calls: 0.into() }
    }

    /// Run with positional args; returns the decomposed output tuple as
    /// host literals.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.manifest.len() {
            bail!("arg count {} != manifest {}", args.len(), self.manifest.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.manifest) {
            if arg.dtype() != spec.dtype || arg.len() != spec.elems() {
                bail!(
                    "arg `{}` mismatch: got {:?}x{}, want {:?}x{}",
                    spec.name,
                    arg.dtype(),
                    arg.len(),
                    spec.dtype,
                    spec.elems()
                );
            }
            literals.push(arg.to_literal(&spec.shape)?);
        }
        let t0 = std::time::Instant::now();
        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        self.exec_ns
            .set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        self.exec_calls.set(self.exec_calls.get() + 1);
        // aot.py lowers with return_tuple=True
        result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose tuple: {e:?}"))
    }

    /// Buffer-argument execution (§Perf): weights live on-device as
    /// PjRtBuffers uploaded once; only dynamic args transfer per call.
    /// `bufs[..n_static]` are the cached buffers; `args` fill the remaining
    /// manifest slots in order.
    pub fn run_b(
        &self,
        client: &xla::PjRtClient,
        static_bufs: &[DeviceArg],
        args: &[Arg],
    ) -> Result<Vec<xla::Literal>> {
        let n_static = static_bufs.len();
        if n_static + args.len() != self.manifest.len() {
            bail!(
                "static {} + dynamic {} != manifest {}",
                n_static,
                args.len(),
                self.manifest.len()
            );
        }
        let mut all: Vec<&xla::PjRtBuffer> = static_bufs.iter().map(|d| &d.buf).collect();
        let mut owned = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(self.manifest.iter().skip(n_static)) {
            if arg.dtype() != spec.dtype || arg.len() != spec.elems() {
                bail!(
                    "arg `{}` mismatch: got {:?}x{}, want {:?}x{}",
                    spec.name,
                    arg.dtype(),
                    arg.len(),
                    spec.dtype,
                    spec.elems()
                );
            }
            owned.push(upload(client, arg, &spec.shape)?);
        }
        all.extend(owned.iter().map(|d| &d.buf));
        let t0 = std::time::Instant::now();
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&all)
            .map_err(|e| anyhow::anyhow!("execute_b: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        self.exec_ns
            .set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        self.exec_calls.set(self.exec_calls.get() + 1);
        result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose tuple: {e:?}"))
    }

    pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
    }

    /// Slot index of a named input (for building positional arg vectors).
    pub fn slot(&self, name: &str) -> Result<usize> {
        self.manifest
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("no manifest input `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_metadata() {
        let f = [1.0f32, 2.0];
        let a = Arg::F32(&f);
        assert_eq!(a.dtype(), DType::F32);
        assert_eq!(a.len(), 2);
        let u = [3u8];
        assert_eq!(Arg::U8(&u).dtype(), DType::U8);
    }

    #[test]
    fn f32_bytes_little_endian() {
        let x = [1.0f32];
        assert_eq!(bytemuck_f32(&x), 1.0f32.to_le_bytes());
    }
}
