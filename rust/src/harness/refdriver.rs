//! Reference-path evaluation driver: the pure-Rust model over the same
//! RequestCache quantization machinery, with no compiled-shape constraints.
//!
//! Used where the experiment sweeps layouts beyond the compiled HLO
//! variants (Fig. 6 heatmaps, Fig. 7 Pareto search, Table 5 group-size
//! sweep). Agreement with the HLO path is enforced by tests/integration.rs
//! (invariant #8), so results are interchangeable up to float tolerance.
//!
//! Decode goes through the **fused packed-code path** by default
//! ([`RefModel::decode_step_into`]): attention streams straight off the
//! cache's packed buffers into a per-driver [`DecodeScratch`], so the
//! steady-state step never dequantizes a window and never allocates. The
//! old dequantize-then-attend path survives as [`RefDriver::step_legacy`] /
//! [`RefDriver::decode_logits_legacy`] — the numerical oracle the fused
//! path is property-tested against (tests/fused_decode.rs) and the baseline
//! benches/ref_decode.rs measures the speedup over.
//!
//! Prefill goes through the **chunked GEMM-blocked path** by default
//! ([`crate::model::reference::PrefillRun`]): group-aligned token tiles,
//! one streaming pass over each weight per tile, direct-to-page
//! quantization as each layer closes, and a last-position-only vocab
//! projection. The old full-materialization path
//! (`RefModel::forward_full` + `RequestCache::load_prefill`) survives as
//! [`RefDriver::prefill_legacy`] — the oracle tests/blocked_prefill.rs
//! checks against and the baseline benches/prefill.rs measures.

use std::cell::RefCell;

use anyhow::Result;

use crate::harness::accuracy::AccuracyReport;
use crate::harness::workloads::Task;
use crate::kvcache::cache::RequestCache;
use crate::model::config::{CacheConfig, ModelConfig};
use crate::model::reference::{DecodeScratch, LayerCtx, PrefillRun, RefModel};
use crate::model::sampler::{argmax, log_prob};
use crate::model::weights::Weights;
use crate::quant::methods::Method;
use crate::quant::window::TierSpec;

pub struct RefDriver<'a> {
    pub model: RefModel<'a>,
    pub cc: CacheConfig,
    pub specs: Vec<TierSpec>,
    pub method: Method,
    pub r_limit: usize,
    /// Per-driver decode arena, reused across every step of every request.
    scratch: RefCell<DecodeScratch>,
}

impl<'a> RefDriver<'a> {
    pub fn new(
        mc: ModelConfig,
        cc: CacheConfig,
        w: &'a Weights,
        specs: Vec<TierSpec>,
        method: Method,
        r_limit: usize,
    ) -> Self {
        let model = RefModel::new(mc, w);
        let scratch = RefCell::new(DecodeScratch::new(&model.mc, cc.capacity + cc.residual + 1));
        RefDriver { model, cc, specs, method, r_limit, scratch }
    }

    fn new_cache(&self) -> RequestCache {
        RequestCache::new(&self.model.mc, &self.cc, &self.specs, self.method.clone(), self.r_limit)
    }

    /// Run the chunked blocked prefill to completion into `cache`.
    fn prefill_chunked(&self, cache: &mut RequestCache, prompt: &[i32]) -> Result<Vec<f32>> {
        let mut run = PrefillRun::new(&self.model.mc, prompt.len(), self.cc.group);
        while !run.advance(&self.model, prompt, cache, usize::MAX)? {}
        Ok(run.last_logits().to_vec())
    }

    /// Prefill prompt into a fresh cache (private unbounded page pool)
    /// through the chunked GEMM-blocked pipeline: K/V quantize straight
    /// into pool pages as each layer closes — no full f32 prefill stash,
    /// no `T × vocab` logits. The pre-blocked path survives as
    /// [`RefDriver::prefill_legacy`] (the oracle).
    pub fn prefill(&self, prompt: &[i32]) -> Result<(RequestCache, Vec<f32>)> {
        let mut cache = self.new_cache();
        let last = self.prefill_chunked(&mut cache, prompt)?;
        Ok((cache, last))
    }

    /// Chunked prefill into a cache leasing its pages from `pool` — the
    /// serving storage configuration, used by benches/tests to
    /// measure/verify the shared-pool paths without an engine.
    pub fn prefill_pooled(
        &self,
        pool: &crate::kvcache::pool::KvPool,
        prompt: &[i32],
    ) -> Result<(RequestCache, Vec<f32>)> {
        let mut cache = RequestCache::new_in(
            pool,
            &self.model.mc,
            &self.cc,
            &self.specs,
            self.method.clone(),
            self.r_limit,
        );
        let last = self.prefill_chunked(&mut cache, prompt)?;
        Ok((cache, last))
    }

    /// The pre-blocked prefill path, kept verbatim as the oracle and bench
    /// baseline: full teacher-forced `T × vocab` logits via per-token
    /// matvecs, the `[L]`-layer f32 K/V stash, then a bulk
    /// `load_prefill` re-copy into the cache.
    pub fn prefill_legacy(&self, prompt: &[i32]) -> Result<(RequestCache, Vec<f32>)> {
        let (_, pre) = self.model.forward_full(prompt);
        let mut cache = self.new_cache();
        cache.load_prefill(&pre.k, &pre.v, &pre.qabs, prompt.len())?;
        Ok((cache, pre.last_logits))
    }

    /// One teacher-forced decode step (fused path); returns logits for the
    /// next token. Clones the vocab-sized logits out of the scratch —
    /// hot evaluation loops use the borrow-returning
    /// [`RefDriver::step_into`] instead.
    pub fn step(&self, cache: &mut RequestCache, token: i32) -> Result<Vec<f32>> {
        let mut scratch = self.scratch.borrow_mut();
        self.step_with(cache, token, &mut scratch)?;
        Ok(scratch.logits.clone())
    }

    /// Borrow-returning decode step: like [`RefDriver::step`] but hands
    /// back `&scratch.logits` instead of cloning a vocab-sized vector per
    /// step — the accuracy/perplexity harness loops (and anything else
    /// that owns a [`DecodeScratch`]) read the logits in place.
    pub fn step_into<'s>(
        &self,
        cache: &mut RequestCache,
        token: i32,
        scratch: &'s mut DecodeScratch,
    ) -> Result<&'s [f32]> {
        self.step_with(cache, token, scratch)?;
        Ok(&scratch.logits)
    }

    /// The zero-alloc step core: decode into `scratch` (fused packed-code
    /// attention), then fold the new token into the cache. At steady state
    /// (no quantization flush this step) this performs zero heap
    /// allocations — asserted by tests/fused_decode.rs with a counting
    /// global allocator. Logits land in `scratch.logits`.
    pub fn step_with(
        &self,
        cache: &mut RequestCache,
        token: i32,
        scratch: &mut DecodeScratch,
    ) -> Result<()> {
        self.model.decode_step_into(token, cache, scratch);
        cache.append(&scratch.knew, &scratch.vnew, &scratch.qabs)
    }

    /// Non-mutating fused decode: logits for `token` against the cache as
    /// is (no append) — the bench/equivalence probe.
    pub fn decode_logits_fused(&self, cache: &RequestCache, token: i32) -> Vec<f32> {
        let mut scratch = self.scratch.borrow_mut();
        self.model.decode_step_into(token, cache, &mut scratch);
        scratch.logits.clone()
    }

    /// One teacher-forced decode step through the legacy
    /// dequantize-then-attend path (the numerical oracle).
    pub fn step_legacy(&self, cache: &mut RequestCache, token: i32) -> Result<Vec<f32>> {
        let out = self.legacy_decode(cache, token);
        cache.append(&out.knew, &out.vnew, &out.qabs)?;
        Ok(out.logits)
    }

    /// Non-mutating legacy decode (no append) — bench/equivalence probe.
    pub fn decode_logits_legacy(&self, cache: &RequestCache, token: i32) -> Vec<f32> {
        self.legacy_decode(cache, token).logits
    }

    /// The pre-fused decode path, kept verbatim as the oracle: dequantize
    /// every head's full quantized window into fresh f32 buffers, then run
    /// the f32 attention over them.
    fn legacy_decode(&self, cache: &RequestCache, token: i32) -> crate::model::reference::DecodeOut {
        let mc = &self.model.mc;
        let nl = mc.n_layers;
        let hkv = mc.n_kv_heads;
        let dh = mc.d_head;
        // materialize dequantized windows + residual views
        let mut kqs = Vec::with_capacity(nl);
        let mut vqs = Vec::with_capacity(nl);
        let mut kres = Vec::with_capacity(nl);
        let mut vres = Vec::with_capacity(nl);
        let tq = cache.qlen;
        let tr = cache.rlen();
        for l in 0..nl {
            let mut kq = vec![0f32; hkv * tq * dh];
            let mut vq = vec![0f32; hkv * tq * dh];
            let mut kr = vec![0f32; hkv * tr * dh];
            let mut vr = vec![0f32; hkv * tr * dh];
            for h in 0..hkv {
                let head = &cache.heads[l][h];
                kq[h * tq * dh..(h + 1) * tq * dh].copy_from_slice(&head.dequant_keys(tq));
                vq[h * tq * dh..(h + 1) * tq * dh].copy_from_slice(&head.dequant_values(tq));
                kr[h * tr * dh..(h + 1) * tr * dh].copy_from_slice(head.res.keys());
                vr[h * tr * dh..(h + 1) * tr * dh].copy_from_slice(head.res.values());
            }
            kqs.push(kq);
            vqs.push(vq);
            kres.push(kr);
            vres.push(vr);
        }
        let ctx: Vec<LayerCtx> = (0..nl)
            .map(|l| LayerCtx {
                kq: &kqs[l],
                vq: &vqs[l],
                tq,
                kres: &kres[l],
                vres: &vres[l],
                tr,
            })
            .collect();
        self.model.decode_step(token, cache.pos, &ctx, &cache.rot)
    }

    /// Teacher-forced answer accuracy (same metric as harness::accuracy).
    /// Steps through [`RefDriver::step_into`] over the shared per-driver
    /// scratch — no vocab-sized logits clone per step.
    pub fn accuracy(&self, tasks: &[Task]) -> Result<AccuracyReport> {
        let mut rep = AccuracyReport::default();
        let mut scratch = self.scratch.borrow_mut();
        for task in tasks {
            let (mut cache, last_logits) = self.prefill(&task.prompt)?;
            let mut ok = true;
            let mut hits = 0;
            let mut check = |cursor: usize, logits: &[f32]| {
                for &(p, want) in &task.answer_positions {
                    if p == cursor {
                        if argmax(logits) == want {
                            hits += 1;
                        } else {
                            ok = false;
                        }
                    }
                }
            };
            let mut cursor = task.prompt.len();
            check(cursor, &last_logits);
            while cursor < task.gold.len() - 1 {
                let logits = self.step_into(&mut cache, task.gold[cursor], &mut scratch)?;
                cursor += 1;
                check(cursor, logits);
            }
            rep.tasks += 1;
            rep.answers += task.answer_positions.len();
            rep.answers_correct += hits;
            if ok && !task.answer_positions.is_empty() {
                rep.tasks_correct += 1;
            }
        }
        Ok(rep)
    }

    /// Teacher-forced perplexity (Table 5 sweeps); borrow-returning steps,
    /// same as [`RefDriver::accuracy`].
    pub fn perplexity(&self, seqs: &[Vec<i32>]) -> Result<f64> {
        let mut nll = 0.0;
        let mut n = 0usize;
        let mut scratch = self.scratch.borrow_mut();
        for seq in seqs {
            let (mut cache, last) = self.prefill(&seq[..1])?;
            nll += -log_prob(&last, seq[1]);
            n += 1;
            for cursor in 1..seq.len() - 1 {
                let logits = self.step_into(&mut cache, seq[cursor], &mut scratch)?;
                nll += -log_prob(logits, seq[cursor + 1]);
                n += 1;
            }
        }
        Ok((nll / n as f64).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workloads::{gen_copy, gen_kvlookup};
    use crate::util::rng::Pcg32;

    fn driver(w: &Weights, spec: TierSpec, method: Method) -> RefDriver<'_> {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        RefDriver::new(mc, cc, w, vec![spec; 2], method, 32)
    }

    #[test]
    fn bf16_reference_runs_end_to_end() {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let w = Weights::random(&mc, 5);
        let d = driver(&w, TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }, Method::bf16());
        let mut rng = Pcg32::seeded(81);
        let tasks = vec![gen_copy(&mut rng, 4), gen_kvlookup(&mut rng, 3)];
        let rep = d.accuracy(&tasks).unwrap();
        assert_eq!(rep.tasks, 2);
        // untrained weights: accuracy is whatever it is, but the loop must
        // have scored every answer position
        assert_eq!(rep.answers, 4 + 1);
    }

    #[test]
    fn fused_step_matches_legacy_oracle() {
        // The fused packed-code decode and the dequantize-then-attend
        // oracle must agree to float-reassociation tolerance; the full
        // 17-method sweep lives in tests/fused_decode.rs.
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let w = Weights::random(&mc, 7);
        let d = driver(&w, TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }, Method::mixkvq("mix30"));
        let mut rng = Pcg32::seeded(83);
        let task = crate::harness::workloads::gen_passkey(&mut rng, 100);
        let (mut cache, _) = d.prefill(&task.prompt).unwrap();
        assert!(cache.qlen > 0);
        let mut cursor = task.prompt.len();
        for _ in 0..3 {
            let tok = task.gold[cursor];
            let fused = d.decode_logits_fused(&cache, tok);
            let legacy = d.decode_logits_legacy(&cache, tok);
            let err = fused
                .iter()
                .zip(&legacy)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 1e-4, "fused/legacy diverge: {err}");
            d.step(&mut cache, tok).unwrap();
            cursor += 1;
        }
    }

    #[test]
    fn quantized_path_changes_logits_but_stays_finite() {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let w = Weights::random(&mc, 6);
        let bf = driver(&w, TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }, Method::bf16());
        let kv2 = driver(&w, TierSpec { n16: 0, n4: 0, n2: 32, v_bits: 2 }, Method::kivi("kv2"));
        let mut rng = Pcg32::seeded(82);
        // long prompt so the window actually quantizes (> r_limit = 32)
        let task = crate::harness::workloads::gen_passkey(&mut rng, 100);
        let (mut c1, _) = bf.prefill(&task.prompt).unwrap();
        let (mut c2, _) = kv2.prefill(&task.prompt).unwrap();
        assert!(c1.qlen > 0, "window must be quantized");
        let l1 = bf.step(&mut c1, task.gold[task.prompt.len()]).unwrap();
        let l2 = kv2.step(&mut c2, task.gold[task.prompt.len()]).unwrap();
        assert!(l1.iter().all(|x| x.is_finite()));
        assert!(l2.iter().all(|x| x.is_finite()));
        let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "2-bit quantization must perturb logits");
    }
}
