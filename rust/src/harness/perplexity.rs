//! Teacher-forced perplexity through the quantized cache (Table 2 / 5).
//!
//! Sequences are fed token-by-token through the decode graph starting from
//! BOS, so every position's prediction is conditioned on the *quantized*
//! past — error accumulation across the sequence is captured exactly as in
//! deployment (unlike "simulated quantization" PPL that dequantizes from
//! full-precision state).

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::harness::workloads::{sample_mixed, Task};
use crate::kvcache::cache::RequestCache;
use crate::model::sampler::log_prob;
use crate::util::rng::Pcg32;

/// Build a PPL corpus: `n` sequences of ~`len` tokens from the mixed task
/// distribution (teacher-forced; answers and structure both scored, like
/// WikiText PPL scores every token).
pub fn corpus(n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Pcg32::new(seed, 99);
    (0..n)
        .map(|_| {
            let mut toks = Vec::with_capacity(len);
            while toks.len() < len {
                let t: Task = sample_mixed(&mut rng, len - toks.len());
                toks.extend(t.gold);
            }
            toks.truncate(len);
            toks
        })
        .collect()
}

#[derive(Clone, Debug, Default)]
pub struct PplReport {
    pub nll_sum: f64,
    pub tokens: usize,
}

impl PplReport {
    pub fn ppl(&self) -> f64 {
        if self.tokens == 0 {
            f64::NAN
        } else {
            (self.nll_sum / self.tokens as f64).exp()
        }
    }
}

/// Evaluate PPL of `seqs` through the engine (batched teacher forcing).
pub fn evaluate(engine: &mut Engine, seqs: &[Vec<i32>]) -> Result<PplReport> {
    let batch = engine.meta.cache.decode_batch;
    let mut report = PplReport::default();
    for chunk in seqs.chunks(batch) {
        // each sequence starts as a 1-token "prompt" (its first token)
        let mut caches: Vec<Option<(RequestCache, usize)>> = Vec::with_capacity(batch);
        for seq in chunk {
            let pre = engine.prefill(&seq[..1])?;
            let cache = engine.quantize_prefill(&pre)?;
            report.nll_sum += -log_prob(&pre.last_logits, seq[1]);
            report.tokens += 1;
            caches.push(Some((cache, 1)));
        }
        while caches.len() < batch {
            caches.push(None);
        }
        loop {
            let mut any = false;
            let mut slots: Vec<Option<(&mut RequestCache, i32)>> = Vec::with_capacity(batch);
            for (i, c) in caches.iter_mut().enumerate() {
                match c {
                    Some((cache, cursor)) if *cursor < chunk[i].len() - 1 => {
                        any = true;
                        slots.push(Some((cache, chunk[i][*cursor])));
                    }
                    _ => slots.push(None),
                }
            }
            if !any {
                break;
            }
            let logits = engine.decode_step(&mut slots)?;
            drop(slots);
            for (i, lg) in logits.into_iter().enumerate() {
                if let (Some((_, cursor)), Some(lg)) = (caches[i].as_mut(), lg) {
                    if *cursor < chunk[i].len() - 1 {
                        *cursor += 1;
                        report.nll_sum += -log_prob(&lg, chunk[i][*cursor]);
                        report.tokens += 1;
                    }
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_seeded_and_sized() {
        let a = corpus(3, 64, 7);
        let b = corpus(3, 64, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|s| s.len() == 64));
        let c = corpus(3, 64, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn ppl_math() {
        let r = PplReport { nll_sum: 2.0 * (4.0f64).ln(), tokens: 2 };
        assert!((r.ppl() - 4.0).abs() < 1e-9);
    }
}
