//! Tier-budget Pareto search (Appendix C / Fig. 7).
//!
//! The paper runs OPTUNA-TPE over thresholds (τ_BF16, τ_UINT4); thresholds
//! map 1:1 to tier *counts* per head (salience::threshold_counts), so we
//! search the count grid directly — same frontier, no sampler dependency —
//! and evaluate each point through the reference driver.

use anyhow::Result;

use crate::harness::refdriver::RefDriver;
use crate::harness::workloads::Task;
use crate::kvcache::accountant::effective_bits;
use crate::model::config::{CacheConfig, ModelConfig};
use crate::model::weights::Weights;
use crate::quant::methods::Method;
use crate::quant::window::TierSpec;

#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub n16: usize,
    pub n4: usize,
    pub n2: usize,
    pub eff_bits: f64,
    pub accuracy: f64,
    pub on_frontier: bool,
}

/// Valid (n16, n4) grid: packing requires n4 even and n2 ≡ 0 (mod 4).
pub fn tier_grid(d: usize) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    for n16 in [0usize, 2, 4, 8] {
        for n4 in (0..=d - n16).step_by(2) {
            let n2 = d - n16 - n4;
            if n2 % 4 == 0 {
                out.push((n16, n4, n2));
            }
        }
    }
    out
}

/// Evaluate the grid and mark the Pareto frontier (max accuracy, min bits).
pub fn search(
    mc: &ModelConfig,
    cc: &CacheConfig,
    weights: &Weights,
    tasks: &[Task],
    v_bits: usize,
    r_limit: usize,
) -> Result<Vec<ParetoPoint>> {
    let mut points = Vec::new();
    for (n16, n4, n2) in tier_grid(mc.d_head) {
        let spec = TierSpec { n16, n4, n2, v_bits };
        let driver = RefDriver::new(
            mc.clone(),
            cc.clone(),
            weights,
            vec![spec; mc.n_layers],
            Method::mixkvq("grid"),
            r_limit,
        );
        let rep = driver.accuracy(tasks)?;
        points.push(ParetoPoint {
            n16,
            n4,
            n2,
            eff_bits: effective_bits(&spec, mc.d_head, cc.group),
            accuracy: rep.task_acc(),
            on_frontier: false,
        });
    }
    mark_frontier(&mut points);
    Ok(points)
}

/// A point is on the frontier iff no other point has ≤ bits AND > accuracy
/// (or < bits AND ≥ accuracy).
pub fn mark_frontier(points: &mut [ParetoPoint]) {
    for i in 0..points.len() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            j != i
                && ((q.eff_bits <= points[i].eff_bits && q.accuracy > points[i].accuracy)
                    || (q.eff_bits < points[i].eff_bits && q.accuracy >= points[i].accuracy))
        });
        points[i].on_frontier = !dominated;
    }
}

/// Pick the frontier point with max accuracy under a bits constraint
/// (App. C: "highest accuracy while keeping effective bit-width below a
/// strict constraint").
pub fn select(points: &[ParetoPoint], max_bits: f64) -> Option<&ParetoPoint> {
    points
        .iter()
        .filter(|p| p.eff_bits <= max_bits && p.on_frontier)
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_respects_packing() {
        for (n16, n4, n2) in tier_grid(32) {
            assert_eq!(n16 + n4 + n2, 32);
            assert_eq!(n4 % 2, 0);
            assert_eq!(n2 % 4, 0);
        }
        assert!(tier_grid(32).len() >= 20);
    }

    #[test]
    fn frontier_marking() {
        let mut pts = vec![
            ParetoPoint { n16: 0, n4: 0, n2: 32, eff_bits: 2.0, accuracy: 0.3, on_frontier: false },
            ParetoPoint { n16: 2, n4: 2, n2: 28, eff_bits: 3.0, accuracy: 0.8, on_frontier: false },
            ParetoPoint { n16: 2, n4: 0, n2: 28, eff_bits: 3.0, accuracy: 0.5, on_frontier: false }, // dominated
            ParetoPoint { n16: 8, n4: 8, n2: 16, eff_bits: 6.0, accuracy: 0.9, on_frontier: false },
        ];
        mark_frontier(&mut pts);
        assert!(pts[0].on_frontier);
        assert!(pts[1].on_frontier);
        assert!(!pts[2].on_frontier);
        assert!(pts[3].on_frontier);
        let sel = select(&pts, 3.5).unwrap();
        assert_eq!((sel.n16, sel.n4), (2, 2));
    }
}
