//! Task-accuracy evaluation through the full serving stack (prefill →
//! quantized cache → batched decode), teacher-forced for determinism.
//!
//! Metric: a task counts as correct iff **every** answer token is the
//! argmax at its position — for chains this is exactly the paper's
//! "one corrupted step invalidates the chain" criterion (Table 1),
//! evaluated with the same quantized-cache state the model would see
//! generatively (gold structure tokens, model-scored answers).

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::harness::workloads::Task;
use crate::kvcache::cache::RequestCache;
use crate::model::sampler::argmax;

#[derive(Clone, Debug, Default)]
pub struct AccuracyReport {
    pub tasks: usize,
    pub tasks_correct: usize,
    pub answers: usize,
    pub answers_correct: usize,
}

impl AccuracyReport {
    pub fn task_acc(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.tasks_correct as f64 / self.tasks as f64
        }
    }

    pub fn token_acc(&self) -> f64 {
        if self.answers == 0 {
            0.0
        } else {
            self.answers_correct as f64 / self.answers as f64
        }
    }
}

struct Live<'a> {
    task: &'a Task,
    cache: RequestCache,
    /// Next gold index to feed (the token at gold[cursor] is fed next).
    cursor: usize,
    ok: bool,
    hits: usize,
}

/// Evaluate tasks in batches through the engine's decode graph.
pub fn evaluate(engine: &mut Engine, tasks: &[Task]) -> Result<AccuracyReport> {
    let batch = engine.meta.cache.decode_batch;
    let mut report = AccuracyReport::default();
    for chunk in tasks.chunks(batch) {
        let mut live: Vec<Option<Live>> = Vec::with_capacity(batch);
        for task in chunk {
            let pre = engine.prefill(&task.prompt)?;
            let cache = engine.quantize_prefill(&pre)?;
            let mut l = Live { task, cache, cursor: task.prompt.len(), ok: true, hits: 0 };
            // the prefill's last logits predict gold[prompt_len]
            score_position(&pre.last_logits, &mut l);
            live.push(Some(l));
        }
        while live.len() < batch {
            live.push(None);
        }
        // teacher-forced decode until every task's gold is consumed
        loop {
            let mut any = false;
            let mut slots: Vec<Option<(&mut RequestCache, i32)>> = Vec::with_capacity(batch);
            for l in live.iter_mut() {
                match l {
                    Some(lv) if lv.cursor < lv.task.gold.len() - 1 => {
                        any = true;
                        let tok = lv.task.gold[lv.cursor];
                        slots.push(Some((&mut lv.cache, tok)));
                    }
                    _ => slots.push(None),
                }
            }
            if !any {
                break;
            }
            let logits = engine.decode_step(&mut slots)?;
            drop(slots);
            for (l, lg) in live.iter_mut().zip(logits) {
                if let (Some(lv), Some(lg)) = (l.as_mut(), lg) {
                    if lv.cursor < lv.task.gold.len() - 1 {
                        lv.cursor += 1;
                        // logits now predict gold[cursor]
                        score_position(&lg, lv);
                    }
                }
            }
        }
        for l in live.into_iter().flatten() {
            report.tasks += 1;
            report.answers += l.task.answer_positions.len();
            report.answers_correct += l.hits;
            if l.ok && !l.task.answer_positions.is_empty() {
                report.tasks_correct += 1;
            }
        }
    }
    Ok(report)
}

fn score_position(logits: &[f32], l: &mut Live) {
    for &(p, want) in &l.task.answer_positions {
        if p == l.cursor {
            if argmax(logits) == want {
                l.hits += 1;
            } else {
                l.ok = false;
            }
        }
    }
}

/// Generative rollout of one task (Table-1-style transcript): greedy decode
/// from the prompt, returning the produced tokens.
pub fn rollout(engine: &mut Engine, task: &Task, max_new: usize) -> Result<Vec<i32>> {
    let batch = engine.meta.cache.decode_batch;
    let pre = engine.prefill(&task.prompt)?;
    let mut cache = engine.quantize_prefill(&pre)?;
    let mut out = Vec::new();
    let mut tok = argmax(&pre.last_logits);
    out.push(tok);
    for _ in 0..max_new {
        if tok == crate::model::tokenizer::EOS || cache.remaining() == 0 {
            break;
        }
        let mut slots: Vec<Option<(&mut RequestCache, i32)>> = Vec::with_capacity(batch);
        slots.push(Some((&mut cache, tok)));
        for _ in 1..batch {
            slots.push(None);
        }
        let logits = engine.decode_step(&mut slots)?;
        drop(slots);
        tok = argmax(logits[0].as_ref().unwrap());
        out.push(tok);
    }
    Ok(out)
}
