//! Workload generators — the rust mirror of python/compile/corpus.py plus
//! the ShareGPT-like serving trace (Fig. 5).
//!
//! Task families map to the paper's benchmarks (DESIGN.md §2):
//! chain → AIME/MATH-500 stand-in, passkey/kvlookup/copy → LongBench
//! stand-in, `sharegpt_trace` → the Fig. 5 throughput workload.

use crate::coordinator::session::Request;
use crate::model::sampler::Sampling;
use crate::model::tokenizer::*;
use crate::quant::methods::MethodSpec;
use crate::util::rng::{stream, Pcg32};

#[derive(Clone, Debug)]
pub struct Task {
    pub prompt: Vec<i32>,
    /// Ground-truth continuation tokens (answer region only), in order.
    pub answer: Vec<i32>,
    /// The full gold sequence (prompt + continuation incl. answers + EOS)
    /// for teacher-forced evaluation.
    pub gold: Vec<i32>,
    /// (position in gold, expected token) for answer-token accuracy.
    pub answer_positions: Vec<(usize, i32)>,
    pub kind: TaskKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Chain,
    Passkey,
    KvLookup,
    Copy,
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Chain => "chain",
            TaskKind::Passkey => "passkey",
            TaskKind::KvLookup => "kvlookup",
            TaskKind::Copy => "copy",
        }
    }
}

pub const CHAIN_OPERAND_MAX: i32 = 5;

fn apply_op(op: i32, a: i32, b: i32) -> i32 {
    match op {
        OP_ADD => (a + b).rem_euclid(NUM_COUNT),
        OP_SUB => (a - b).rem_euclid(NUM_COUNT),
        OP_MUL => (a * b).rem_euclid(NUM_COUNT),
        _ => unreachable!(),
    }
}

/// Chained modular arithmetic. Prompt = everything up to the first `=`;
/// the model must produce each step's result (then we feed gold onward for
/// teacher-forced eval, or its own output for generative eval).
pub fn gen_chain(rng: &mut Pcg32, steps: usize) -> Task {
    let ops = [OP_ADD, OP_SUB];
    let mut gold = vec![BOS];
    let mut answer_positions = Vec::new();
    let mut prev = rng.below(NUM_COUNT as u32) as i32;
    gold.push(num_tok(prev));
    for _ in 0..steps {
        let op = ops[rng.below(2) as usize];
        let b = rng.range(1, CHAIN_OPERAND_MAX as u32) as i32;
        let res = apply_op(op, prev, b);
        gold.extend_from_slice(&[op, num_tok(b), EQ]);
        answer_positions.push((gold.len(), num_tok(res)));
        gold.extend_from_slice(&[num_tok(res), SEP]);
        prev = res;
    }
    gold.push(EOS);
    let first_eq = answer_positions[0].0;
    Task {
        prompt: gold[..first_eq].to_vec(),
        answer: answer_positions.iter().map(|&(_, t)| t).collect(),
        gold,
        answer_positions,
        kind: TaskKind::Chain,
    }
}

/// Passkey retrieval in a filler haystack of `context_len` tokens.
pub fn gen_passkey(rng: &mut Pcg32, context_len: usize) -> Task {
    let key_len = 2;
    let val_len = 2;
    let key: Vec<i32> = (0..key_len).map(|_| num_tok(rng.below(NUM_COUNT as u32) as i32)).collect();
    let val: Vec<i32> = (0..val_len).map(|_| num_tok(rng.below(NUM_COUNT as u32) as i32)).collect();
    let mut needle = vec![KEY];
    needle.extend(&key);
    needle.push(VAL);
    needle.extend(&val);
    let mut query = vec![QMARK];
    query.extend(&key);
    query.push(ARROW);
    let n_fill = context_len.saturating_sub(needle.len() + query.len() + val_len + 2);
    let pos = rng.below(n_fill as u32 + 1) as usize;
    let mut gold = vec![BOS];
    for i in 0..n_fill {
        if i == pos {
            gold.extend(&needle);
        }
        gold.push(FILLER_BASE + rng.below(FILLER_COUNT as u32) as i32);
    }
    if pos >= n_fill {
        gold.extend(&needle);
    }
    gold.extend(&query);
    let prompt_len = gold.len();
    let answer_positions: Vec<(usize, i32)> =
        val.iter().enumerate().map(|(i, &t)| (prompt_len + i, t)).collect();
    gold.extend(&val);
    gold.push(EOS);
    Task {
        prompt: gold[..prompt_len].to_vec(),
        answer: val,
        gold,
        answer_positions,
        kind: TaskKind::Passkey,
    }
}

/// Associative recall over `n_pairs` KEY/VAL pairs.
pub fn gen_kvlookup(rng: &mut Pcg32, n_pairs: usize) -> Task {
    let keys = rng.sample_distinct(NUM_COUNT as u32, n_pairs);
    let vals: Vec<i32> = (0..n_pairs).map(|_| rng.below(NUM_COUNT as u32) as i32).collect();
    let mut gold = vec![BOS];
    for (k, v) in keys.iter().zip(&vals) {
        gold.extend_from_slice(&[KEY, num_tok(*k as i32), VAL, num_tok(*v), SEP]);
    }
    let i = rng.below(n_pairs as u32) as usize;
    gold.extend_from_slice(&[QMARK, num_tok(keys[i] as i32), ARROW]);
    let prompt_len = gold.len();
    let ans = num_tok(vals[i]);
    gold.push(ans);
    gold.push(EOS);
    Task {
        prompt: gold[..prompt_len].to_vec(),
        answer: vec![ans],
        gold,
        answer_positions: vec![(prompt_len, ans)],
        kind: TaskKind::KvLookup,
    }
}

/// Verbatim copy of `n` number tokens.
pub fn gen_copy(rng: &mut Pcg32, n: usize) -> Task {
    let seq: Vec<i32> = (0..n).map(|_| num_tok(rng.below(NUM_COUNT as u32) as i32)).collect();
    let mut gold = vec![BOS, COPY];
    gold.extend(&seq);
    gold.push(ARROW);
    let prompt_len = gold.len();
    let answer_positions: Vec<(usize, i32)> =
        seq.iter().enumerate().map(|(i, &t)| (prompt_len + i, t)).collect();
    gold.extend(&seq);
    gold.push(EOS);
    Task {
        prompt: gold[..prompt_len].to_vec(),
        answer: seq,
        gold,
        answer_positions,
        kind: TaskKind::Copy,
    }
}

/// Mixed training-distribution sample (mirrors corpus.sample_example) —
/// used for perplexity corpora.
pub fn sample_mixed(rng: &mut Pcg32, max_len: usize) -> Task {
    let kind = rng.below(4);
    let mut t = match kind {
        0 => {
            let steps = rng.range(2, 9) as usize;
            gen_chain(rng, steps)
        }
        1 => {
            let hi = (max_len as u32).max(25).saturating_sub(10).max(25);
            let ctx = rng.range(24, hi) as usize;
            gen_passkey(rng, ctx)
        }
        2 => {
            let n = rng.range(2, 13) as usize;
            gen_kvlookup(rng, n)
        }
        _ => {
            let n = rng.range(2, 13) as usize;
            gen_copy(rng, n)
        }
    };
    t.gold.truncate(max_len);
    t.answer_positions.retain(|&(p, _)| p < max_len);
    t
}

/// ShareGPT-like trace: input/output lengths drawn from a mixture matching
/// the published ShareGPT statistics shape (log-normal-ish, long tail),
/// scaled to our context window. Prompts are synthetic passkey contexts so
/// the decode path does real retrieval work.
pub fn sharegpt_trace(rng: &mut Pcg32, n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            // length mixture: 60% short (32-96), 30% medium (96-256), 10% long (256-480)
            let r = rng.f32();
            let ctx = if r < 0.6 {
                rng.range(32, 96)
            } else if r < 0.9 {
                rng.range(96, 256)
            } else {
                rng.range(256, 480)
            } as usize;
            let out = 2 + (rng.f32().powi(2) * (max_new - 2) as f32) as usize;
            let task = gen_passkey(rng, ctx);
            Request {
                id: i as u64,
                prompt: task.prompt,
                max_new_tokens: out.max(task.answer.len() + 2),
                sampling: Sampling::Greedy,
                method: None,
                tenant: 0,
                deadline_ticks: None,
            }
        })
        .collect()
}

/// [`sharegpt_trace`] from a root seed via the shared named-stream
/// derivation ([`crate::util::rng::stream`]) — one `--seed` reproduces the
/// whole trace regardless of what else drew from other streams.
pub fn sharegpt_trace_seeded(seed: u64, n: usize, max_new: usize) -> Vec<Request> {
    let mut rng = stream(seed, "sharegpt");
    sharegpt_trace(&mut rng, n, max_new)
}

/// Assign tenant ids round-robin — the multi-tenant counterpart of
/// [`assign_methods`] for traces built outside `harness::traffic`.
pub fn assign_tenants(requests: &mut [Request], n_tenants: u32) {
    if n_tenants == 0 {
        return;
    }
    for (i, r) in requests.iter_mut().enumerate() {
        r.tenant = i as u32 % n_tenants;
    }
}

/// Assign per-request quantization policies round-robin — the multi-tenant
/// mixed-precision workload (each tenant pins its own `MethodSpec`; the
/// server batches them per-variant).
pub fn assign_methods(requests: &mut [Request], specs: &[MethodSpec]) {
    if specs.is_empty() {
        return;
    }
    for (i, r) in requests.iter_mut().enumerate() {
        r.method = Some(specs[i % specs.len()]);
    }
}

/// The per-benchmark suites of Table 3/4 (fixed sizes, seeded). Each task
/// family draws from its own named sub-stream of `seed`, so adding a
/// family (or drawing more from one) never perturbs the others.
pub fn suite(kind: TaskKind, n: usize, seed: u64, long: bool) -> Vec<Task> {
    let mut rng = stream(seed, kind.name());
    (0..n)
        .map(|_| match kind {
            // sizes chosen so the quantized window (R=32 residual) holds a
            // meaningful share of each context
            TaskKind::Chain => gen_chain(&mut rng, if long { 20 } else { 12 }),
            TaskKind::Passkey => gen_passkey(&mut rng, if long { 460 } else { 100 }),
            TaskKind::KvLookup => gen_kvlookup(&mut rng, if long { 24 } else { 16 }),
            TaskKind::Copy => gen_copy(&mut rng, if long { 20 } else { 12 }),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_answers_consistent() {
        let mut rng = Pcg32::seeded(71);
        for _ in 0..50 {
            let t = gen_chain(&mut rng, 5);
            assert_eq!(t.answer.len(), 5);
            for &(p, tok) in &t.answer_positions {
                assert_eq!(t.gold[p], tok);
                assert_eq!(t.gold[p - 1], EQ);
            }
            // chain property: each step result feeds the next step
            assert_eq!(t.prompt[0], BOS);
        }
    }

    #[test]
    fn passkey_answer_is_needle_value() {
        let mut rng = Pcg32::seeded(72);
        for _ in 0..30 {
            let t = gen_passkey(&mut rng, 80);
            // the VAL tokens appear right after the KEY tokens in the context
            let vpos = t.gold.iter().position(|&x| x == VAL).unwrap();
            assert_eq!(&t.gold[vpos + 1..vpos + 3], t.answer.as_slice());
            assert!(t.prompt.len() <= 82, "{}", t.prompt.len());
            assert_eq!(*t.gold.last().unwrap(), EOS);
        }
    }

    #[test]
    fn kvlookup_answer_matches_pair() {
        let mut rng = Pcg32::seeded(73);
        for _ in 0..30 {
            let t = gen_kvlookup(&mut rng, 6);
            let qpos = t.gold.iter().position(|&x| x == QMARK).unwrap();
            let qkey = t.gold[qpos + 1];
            // find that key's VAL in the context
            let mut found = None;
            let mut i = 1;
            while t.gold[i] == KEY {
                if t.gold[i + 1] == qkey {
                    found = Some(t.gold[i + 3]);
                }
                i += 5;
            }
            assert_eq!(found, Some(t.answer[0]));
        }
    }

    #[test]
    fn copy_roundtrip() {
        let mut rng = Pcg32::seeded(74);
        let t = gen_copy(&mut rng, 7);
        assert_eq!(t.answer.len(), 7);
        assert_eq!(&t.gold[2..9], t.answer.as_slice());
    }

    #[test]
    fn trace_is_deterministic_and_bounded() {
        let mut a = Pcg32::seeded(75);
        let mut b = Pcg32::seeded(75);
        let ta = sharegpt_trace(&mut a, 20, 64);
        let tb = sharegpt_trace(&mut b, 20, 64);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        assert!(ta.iter().all(|r| r.prompt.len() <= 482 && r.max_new_tokens <= 64));
    }

    #[test]
    fn assign_methods_round_robins() {
        let mut rng = Pcg32::seeded(76);
        let mut reqs = sharegpt_trace(&mut rng, 5, 16);
        assign_methods(
            &mut reqs,
            &[MethodSpec::Bf16, MethodSpec::MixKvq { op: crate::quant::methods::MixOp::Mix30 }],
        );
        assert_eq!(reqs[0].method, Some(MethodSpec::Bf16));
        assert!(matches!(reqs[1].method, Some(MethodSpec::MixKvq { .. })));
        assert_eq!(reqs[2].method, Some(MethodSpec::Bf16));
        assert_eq!(reqs[4].method, Some(MethodSpec::Bf16));
        assign_methods(&mut reqs[..1], &[]); // no-op
        assert_eq!(reqs[0].method, Some(MethodSpec::Bf16));
    }

    #[test]
    fn suites_are_seed_stable() {
        let s1 = suite(TaskKind::Chain, 5, 42, false);
        let s2 = suite(TaskKind::Chain, 5, 42, false);
        assert_eq!(s1[3].gold, s2[3].gold);
        let long = suite(TaskKind::Passkey, 2, 1, true);
        assert!(long[0].prompt.len() > 400);
        // different families draw decorrelated streams of the same seed
        let other = suite(TaskKind::Copy, 5, 42, false);
        assert_ne!(s1[0].gold, other[0].gold);
    }

    #[test]
    fn seeded_trace_reproduces_prompt_mix() {
        // same root seed ⇒ identical prompts, lengths, and budgets
        let a = sharegpt_trace_seeded(9, 16, 32);
        let b = sharegpt_trace_seeded(9, 16, 32);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let c = sharegpt_trace_seeded(10, 16, 32);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn assign_tenants_round_robins() {
        let mut reqs = sharegpt_trace_seeded(3, 5, 8);
        assign_tenants(&mut reqs, 2);
        assert_eq!(
            reqs.iter().map(|r| r.tenant).collect::<Vec<_>>(),
            vec![0, 1, 0, 1, 0]
        );
        assign_tenants(&mut reqs[..1], 0); // no-op
        assert_eq!(reqs[0].tenant, 0);
    }
}
