//! Production traffic harness — seeded, deterministic load generation
//! driven through the real serving stack (`Server::submit/tick/poll`).
//!
//! Three arrival families cover the shapes production fleets see:
//! Poisson-with-bursts (steady arrivals punctuated by spikes), diurnal
//! ramps (smooth load swell/ebb over a period), and closed-loop sessions
//! (a fixed user population that thinks, submits, waits, resubmits).
//! Prompt mix, tenant assignment, and per-request method pins are drawn
//! from decorrelated named RNG streams ([`crate::util::rng::stream`]), so
//! one seed fixes the entire workload and two runs with the same seed must
//! produce byte-identical schedules AND byte-identical serving outcomes —
//! the harness folds every finished request's id, finish reason, and token
//! stream into an FNV-1a fingerprint (never wall-clock values) that the
//! bench gate compares across a same-seed double run.
//!
//! Per-tenant SLO tracking (p50/p99 TTFT/latency, queue wait, park/evict
//! fairness) comes straight from [`Metrics`]' tenant reservoirs; the
//! report serializes to `BENCH_traffic.json` via [`report_json`].

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::events::{Event, RequestStatus};
use crate::coordinator::metrics::count_for;
use crate::coordinator::router::{Server, ServerConfig};
use crate::coordinator::session::{FinishReason, Request};
use crate::model::sampler::Sampling;
use crate::quant::methods::MethodSpec;
use crate::quant::policy::PrecisionPolicy;
use crate::util::faults::{FaultPlan, N_FAULT_SITES};
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::{stream, Pcg32};

/// Arrival process shaping when sessions hit `Server::submit`.
#[derive(Clone, Debug)]
pub enum Arrival {
    /// Poisson arrivals at `rate` per tick, with a burst window of
    /// `burst_len` ticks at `burst_rate` every `burst_every` ticks.
    PoissonBurst { rate: f64, burst_every: usize, burst_len: usize, burst_rate: f64 },
    /// Smooth sinusoidal ramp between `lo` and `hi` arrivals/tick over
    /// `period` ticks — the diurnal load curve.
    DiurnalRamp { lo: f64, hi: f64, period: usize },
    /// Closed loop: `concurrency` users each submit, wait for their
    /// session to finish, think for `think_ticks`, and submit again until
    /// the session budget is spent. In-flight never exceeds `concurrency`.
    ClosedLoop { concurrency: usize, think_ticks: usize },
}

#[derive(Clone, Debug)]
pub struct TrafficConfig {
    pub seed: u64,
    /// Total sessions to run through the server.
    pub sessions: usize,
    /// Tenant population; requests are assigned by draw from the seeded
    /// "tenants" stream. 0 or 1 means single-tenant.
    pub tenants: u32,
    pub arrival: Arrival,
    /// Upper bound on per-session decode length (each session draws its
    /// own `max_new_tokens` in `2..=max_new`).
    pub max_new: usize,
    /// Distinct prompts in the pool — a small pool exercises cross-request
    /// prefix sharing the way production template traffic does.
    pub prompt_pool: usize,
    /// Prompt length range `[prompt_lo, prompt_hi)`.
    pub prompt_lo: usize,
    pub prompt_hi: usize,
    /// Per-request method pins drawn uniformly from this list; empty means
    /// every request is unpinned (the server's policy decides).
    pub method_mix: Vec<MethodSpec>,
    pub memory_budget_bytes: usize,
    /// Server-side precision policy under test (`None` = engine default).
    pub policy: Option<PrecisionPolicy>,
    pub max_prefills_per_cycle: usize,
    /// Hard tick ceiling — a stuck run terminates with whatever completed.
    pub max_ticks: usize,
    /// Chaos soak: per-draw fault probability injected at every fault site
    /// (lease denial, prefill chunk, decode step, prefix corruption) via a
    /// `FaultPlan` seeded from `seed`. 0.0 disables injection entirely;
    /// > 0.0 also runs `Server::check_invariants` after every tick and
    /// audits for leaked pages at drain.
    pub chaos: f64,
    /// Tick deadline stamped on every generated request (`None` = no
    /// deadline). Ticks, not wall-clock — fingerprints stay deterministic.
    pub deadline_ticks: Option<u64>,
    /// Worker-pool size threaded to [`ServerConfig::workers`]. Outcomes
    /// are bit-identical at every value (crate docs, "Threading model"),
    /// so the fingerprint never depends on it — only wall time does.
    pub workers: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            seed: 7,
            sessions: 200,
            tenants: 4,
            arrival: Arrival::PoissonBurst {
                rate: 8.0,
                burst_every: 40,
                burst_len: 8,
                burst_rate: 64.0,
            },
            max_new: 6,
            prompt_pool: 8,
            prompt_lo: 32,
            prompt_hi: 96,
            method_mix: Vec::new(),
            memory_budget_bytes: 64 << 20,
            policy: None,
            max_prefills_per_cycle: 8,
            max_ticks: 100_000,
            chaos: 0.0,
            deadline_ticks: None,
            workers: crate::coordinator::router::default_workers(),
        }
    }
}

/// Knuth's product-of-uniforms Poisson sampler — exact for the small
/// per-tick rates the harness uses, and deterministic given the stream.
fn poisson(rng: &mut Pcg32, lambda: f64) -> usize {
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.f32() as f64;
        if p <= l || k > 4096 {
            return k;
        }
        k += 1;
    }
}

fn rate_at(arrival: &Arrival, tick: usize) -> f64 {
    match *arrival {
        Arrival::PoissonBurst { rate, burst_every, burst_len, burst_rate } => {
            if burst_every > 0 && tick % burst_every < burst_len {
                burst_rate
            } else {
                rate
            }
        }
        Arrival::DiurnalRamp { lo, hi, period } => {
            let phase = tick as f64 / period.max(1) as f64;
            lo + (hi - lo) * 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos())
        }
        Arrival::ClosedLoop { .. } => 0.0,
    }
}

/// Open-loop arrival schedule: the submit tick of each session, ascending.
/// Empty for closed-loop traffic (arrivals are event-driven there). Same
/// seed ⇒ identical schedule.
pub fn build_schedule(cfg: &TrafficConfig) -> Vec<usize> {
    if matches!(cfg.arrival, Arrival::ClosedLoop { .. }) {
        return Vec::new();
    }
    let mut rng = stream(cfg.seed, "arrivals");
    let mut out = Vec::with_capacity(cfg.sessions);
    let mut tick = 0usize;
    while out.len() < cfg.sessions {
        let lam = rate_at(&cfg.arrival, tick).max(0.01);
        let k = poisson(&mut rng, lam).min(cfg.sessions - out.len());
        for _ in 0..k {
            out.push(tick);
        }
        tick += 1;
        if tick >= cfg.max_ticks {
            out.resize(cfg.sessions, tick);
            break;
        }
    }
    out
}

/// The full request list, ids `0..sessions`, drawn from decorrelated named
/// streams so prompt mix / tenant mix / method mix are individually stable
/// under config changes to the others.
pub fn gen_requests(cfg: &TrafficConfig) -> Vec<Request> {
    let pool_n = cfg.prompt_pool.max(1);
    let mut prng = stream(cfg.seed, "prompts");
    let hi = cfg.prompt_hi.max(cfg.prompt_lo + 1);
    let pool: Vec<Vec<i32>> = (0..pool_n)
        .map(|_| {
            let ctx = prng.range(cfg.prompt_lo as u32, hi as u32) as usize;
            crate::harness::workloads::gen_passkey(&mut prng, ctx).prompt
        })
        .collect();
    let mut pick = stream(cfg.seed, "mix");
    let mut trng = stream(cfg.seed, "tenants");
    let mut mrng = stream(cfg.seed, "methods");
    let n_tenants = cfg.tenants.max(1);
    (0..cfg.sessions)
        .map(|i| Request {
            id: i as u64,
            prompt: pool[pick.below(pool_n as u32) as usize].clone(),
            max_new_tokens: 2 + pick.below(cfg.max_new.max(3) as u32 - 1) as usize,
            sampling: Sampling::Greedy,
            method: if cfg.method_mix.is_empty() {
                None
            } else {
                Some(cfg.method_mix[mrng.below(cfg.method_mix.len() as u32) as usize])
            },
            tenant: trng.below(n_tenants),
            deadline_ticks: cfg.deadline_ticks,
        })
        .collect()
}

/// FNV-1a over u64 words — the deterministic outcome fingerprint.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn fold(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

fn reason_code(r: FinishReason) -> u64 {
    match r {
        FinishReason::Eos => 1,
        FinishReason::MaxTokens => 2,
        FinishReason::CacheFull => 3,
        FinishReason::Cancelled => 4,
        FinishReason::Rejected => 5,
        FinishReason::Error => 6,
        FinishReason::DeadlineExceeded => 7,
    }
}

/// Per-tenant slice of the report — reservoir percentiles plus the
/// fairness counters (who absorbed parks/preemptions).
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub tenant: u32,
    pub served: u64,
    pub unserved: u64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub p99_queue_ms: f64,
    pub parks: u64,
    pub preemptions: u64,
}

#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub seed: u64,
    pub sessions: usize,
    /// Sessions that reached a terminal state (includes rejected).
    pub completed: usize,
    pub rejected: u64,
    pub ticks: usize,
    /// Peak submitted-but-not-finished sessions — the concurrency the run
    /// actually sustained.
    pub max_in_flight: usize,
    /// Peak simultaneously *decoding* sessions (batch occupancy).
    pub max_concurrent_decode: usize,
    pub policy_degradations: u64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub tenants: Vec<TenantSummary>,
    // --- chaos soak (all zero when `TrafficConfig::chaos` is 0.0) --------
    /// The per-site fault probability this run injected with.
    pub chaos_rate: f64,
    /// Ticks after which `Server::check_invariants` reported a violation.
    pub invariant_violations: u64,
    /// Pool pages still leased but pinned by nobody after every session
    /// reached a terminal state (must be 0).
    pub leaked_pages: u64,
    /// Per-site injected-fault counts (lease, prefill, decode, prefix,
    /// snapshot-write, snapshot-corrupt).
    pub faults_injected: [u64; N_FAULT_SITES],
    /// Failed prefill runs that re-queued for a backoff retry.
    pub prefill_retries: u64,
    /// Requests that completed cleanly after at least one failed attempt.
    pub fault_recoveries: u64,
    /// Requests retired as `Error` (exhausted retries + decode failures).
    pub errors: u64,
    /// Requests retired at their tick deadline (admitted + shed-in-queue).
    pub deadline_retirements: u64,
    /// FNV-1a over (id, reason, token stream) of every finished session
    /// plus the per-tenant served/unserved and fairness counters, and —
    /// under chaos — the fault/retry/deadline counters. Contains no
    /// wall-clock material: same seed ⇒ same fingerprint, always.
    pub fingerprint: u64,
    /// Human-readable metrics summary (wall-clock figures live here only).
    pub summary: String,
}

/// The `ServerConfig` a traffic run derives from its workload config —
/// shared by [`run`] and [`run_with_kill`] so an interrupted-and-restored
/// run serves under exactly the same regime as an uninterrupted one.
fn server_cfg_for(cfg: &TrafficConfig) -> ServerConfig {
    let chaos = cfg.chaos > 0.0;
    // the chaos fault plan shares the workload seed: one seed fixes the
    // schedule, the prompts, AND the fault sequence. Serving sites only —
    // snapshot torn-write/bit-flip faults are exercised by the dedicated
    // snapshot tests, not the soak. Fields not pinned here (prefix cache,
    // frozen plan, snapshot target) resolve their env defaults inside
    // ServerConfigBuilder::build().
    ServerConfig::builder()
        .memory_budget_bytes(cfg.memory_budget_bytes)
        .max_prefills_per_cycle(cfg.max_prefills_per_cycle)
        .seed(cfg.seed)
        .policy(cfg.policy.clone())
        .faults(chaos.then(|| FaultPlan::serving_uniform(cfg.seed, cfg.chaos)))
        .workers(cfg.workers.max(1))
        .build()
}

/// Harness-side run state: everything `run`'s loop tracks OUTSIDE the
/// server. Factored out so [`run_with_kill`] can drive the identical loop
/// while swapping the server underneath it at the kill tick — the driver
/// deliberately survives the "crash" (it plays the role of the clients,
/// who exist in other processes and notice nothing).
struct Driver<'a> {
    cfg: &'a TrafficConfig,
    chaos: bool,
    reqs: Vec<Request>,
    schedule: Vec<usize>,
    closed: bool,
    concurrency: usize,
    think_ticks: usize,
    next: usize,        // next unsubmitted request index
    due: Vec<usize>,    // closed-loop resubmit ticks
    in_flight: usize,
    max_in_flight: usize,
    finished: usize,
    fp: Fnv,
    tick: usize,
    invariant_violations: u64,
}

impl<'a> Driver<'a> {
    fn new(cfg: &'a TrafficConfig) -> Driver<'a> {
        let (closed, concurrency, think_ticks) = match cfg.arrival {
            Arrival::ClosedLoop { concurrency, think_ticks } => {
                (true, concurrency.max(1), think_ticks)
            }
            _ => (false, 0, 0),
        };
        Driver {
            cfg,
            chaos: cfg.chaos > 0.0,
            reqs: gen_requests(cfg),
            schedule: build_schedule(cfg),
            closed,
            concurrency,
            think_ticks,
            next: 0,
            due: Vec::new(),
            in_flight: 0,
            max_in_flight: 0,
            finished: 0,
            fp: Fnv::new(),
            tick: 0,
            invariant_violations: 0,
        }
    }

    /// One loop iteration: submit what's due, advance the server a tick,
    /// fold outcomes. Returns `false` once every session is terminal (or
    /// the tick ceiling is hit).
    fn step(&mut self, server: &mut Server) -> Result<bool> {
        let cfg = self.cfg;
        // -- submissions due this tick --------------------------------
        if self.closed {
            if self.tick == 0 {
                for _ in 0..self.concurrency.min(cfg.sessions) {
                    server.submit(self.reqs[self.next].clone())?;
                    self.next += 1;
                    self.in_flight += 1;
                }
            }
            let mut i = 0;
            while i < self.due.len() {
                if self.due[i] <= self.tick && self.next < cfg.sessions {
                    self.due.swap_remove(i);
                    server.submit(self.reqs[self.next].clone())?;
                    self.next += 1;
                    self.in_flight += 1;
                } else {
                    i += 1;
                }
            }
        } else {
            while self.next < cfg.sessions && self.schedule[self.next] <= self.tick {
                server.submit(self.reqs[self.next].clone())?;
                self.next += 1;
                self.in_flight += 1;
            }
        }
        self.max_in_flight = self.max_in_flight.max(self.in_flight);

        if self.next >= cfg.sessions && self.in_flight == 0 && !server.has_work() {
            return Ok(false);
        }

        server.tick()?;
        if self.chaos {
            // the soak's core claim: the books balance after EVERY tick,
            // not just at drain
            if let Err(e) = server.check_invariants() {
                if self.invariant_violations == 0 {
                    eprintln!("mixkvq: chaos tick {}: {e:#}", self.tick);
                }
                self.invariant_violations += 1;
            }
        }

        // -- fold outcomes; feed the closed loop ----------------------
        for e in server.drain_events() {
            if let Event::Finished { id, reason, tokens } = e {
                self.finished += 1;
                self.in_flight = self.in_flight.saturating_sub(1);
                self.fp.fold(id);
                self.fp.fold(reason_code(reason));
                self.fp.fold(tokens as u64);
                if let RequestStatus::Finished { tokens: toks, .. } = server.poll(id) {
                    for t in toks {
                        self.fp.fold(t as u64);
                    }
                }
                if self.closed && self.next + self.due.len() < cfg.sessions {
                    self.due.push(self.tick + self.think_ticks.max(1));
                }
            }
        }

        self.tick += 1;
        Ok(self.tick < cfg.max_ticks)
    }

    /// Post-drain tail: tenant folding, the page-leak audit, and report
    /// assembly — identical for interrupted and uninterrupted runs.
    fn report(mut self, mut server: Server) -> TrafficReport {
        let cfg = self.cfg;
        // Tenant SLO counters are deterministic (no wall-clock input), so
        // they join the fingerprint: same-seed runs must agree on who got
        // served, who got parked, and who got preempted — not just on
        // token streams.
        let m = &server.metrics;
        let mut tenants = Vec::new();
        for t in m.tenants() {
            self.fp.fold(t.tenant as u64);
            self.fp.fold(t.completed);
            self.fp.fold(t.unserved);
            let parks = count_for(&m.tenant_parks, t.tenant);
            let preemptions = count_for(&m.tenant_preemptions, t.tenant);
            self.fp.fold(parks);
            self.fp.fold(preemptions);
            tenants.push(TenantSummary {
                tenant: t.tenant,
                served: t.completed,
                unserved: t.unserved,
                p50_ttft_ms: t.ttft.percentile(50.0),
                p99_ttft_ms: t.ttft.percentile(99.0),
                p50_latency_ms: t.latency.percentile(50.0),
                p99_latency_ms: t.latency.percentile(99.0),
                p99_queue_ms: t.queue_wait.percentile(99.0),
                parks,
                preemptions,
            });
        }
        self.fp.fold(m.policy_degradations);

        // Post-drain page audit: every session is terminal, so the only
        // pages the pool may still lease are the ones the radix tree pins.
        let pinned = server
            .engine
            .prefix_tree()
            .map(|ix| ix.borrow().pages_pinned())
            .unwrap_or(0);
        let leaked_before_clear = server.pool.leased().saturating_sub(pinned) as u64;
        // Then release those pins too: between the two same-seed runs the
        // pool must sit at EXACTLY zero leases — a pin the tree forgot to
        // count (or a clear that fails to return pages) is a leak, not
        // bookkeeping.
        if let Some(ix) = server.engine.prefix_tree() {
            ix.borrow_mut().clear();
        }
        let leaked_pages = leaked_before_clear.max(server.pool.leased() as u64);
        let m = &server.metrics;
        let errors = m.decode_errors + m.retries_exhausted + m.internal_errors;
        let deadline_retirements = m.deadline_exceeded + m.deadline_shed;
        if self.chaos {
            // recovery/deadline outcomes are seeded-deterministic too: fold
            // them so a same-seed pair must agree on the whole failure story
            for x in m.faults_injected {
                self.fp.fold(x);
            }
            self.fp.fold(m.prefill_retries);
            self.fp.fold(m.fault_recoveries);
            self.fp.fold(errors);
            self.fp.fold(deadline_retirements);
            self.fp.fold(self.invariant_violations);
            self.fp.fold(leaked_pages);
        }

        TrafficReport {
            seed: cfg.seed,
            sessions: cfg.sessions,
            completed: self.finished,
            rejected: m.rejected,
            ticks: self.tick,
            max_in_flight: self.max_in_flight,
            max_concurrent_decode: m.max_concurrent,
            policy_degradations: m.policy_degradations,
            p50_ttft_ms: m.completed.ttft_percentile(50.0),
            p99_ttft_ms: m.completed.ttft_percentile(99.0),
            p50_latency_ms: m.completed.latency_percentile(50.0),
            p99_latency_ms: m.completed.latency_percentile(99.0),
            tenants,
            chaos_rate: cfg.chaos,
            invariant_violations: self.invariant_violations,
            leaked_pages,
            faults_injected: m.faults_injected,
            prefill_retries: m.prefill_retries,
            fault_recoveries: m.fault_recoveries,
            errors,
            deadline_retirements,
            fingerprint: self.fp.0,
            summary: m.summary(),
        }
    }
}

/// Drive `cfg.sessions` seeded sessions through a real `Server` built on
/// `engine`, and report outcomes + per-tenant SLOs. Deterministic modulo
/// wall-clock ms fields: the fingerprint covers everything else.
pub fn run(engine: Engine, cfg: &TrafficConfig) -> Result<TrafficReport> {
    let mut server = Server::new(engine, server_cfg_for(cfg));
    let mut d = Driver::new(cfg);
    while d.step(&mut server)? {}
    Ok(d.report(server))
}

/// Wall-clock figures from one kill-and-restore cycle — the raw material
/// of `BENCH_restore.json`'s latency gate.
#[derive(Clone, Copy, Debug, Default)]
pub struct RestoreStats {
    /// Serialized snapshot size.
    pub snapshot_bytes: u64,
    /// Wall time of `Server::snapshot` into a memory buffer.
    pub snapshot_ms: f64,
    /// Wall time of engine rebuild + `Server::restore` + the post-restore
    /// invariant check.
    pub restore_ms: f64,
    /// The LONGEST single driver step observed after the restore — the
    /// yardstick the gate compares `restore_ms` against (restore must cost
    /// no more than ~2 ticks of service).
    pub tick_ms: f64,
}

/// [`run`], except the server is snapshotted at the `kill_at_tick`
/// boundary, torn down entirely (engine included), and rebuilt from the
/// snapshot via `mk_engine` — then the run continues to drain. The driver
/// persists across the kill on purpose: it stands in for the client
/// population, which lives in other processes and must notice nothing.
///
/// The returned report must be byte-identical (fingerprint and all folded
/// counters) to an uninterrupted [`run`] with the same `cfg`.
pub fn run_with_kill(
    mk_engine: &dyn Fn() -> Result<Engine>,
    cfg: &TrafficConfig,
    kill_at_tick: u64,
) -> Result<(TrafficReport, RestoreStats)> {
    let server_cfg = server_cfg_for(cfg);
    let mut server = Server::new(mk_engine()?, server_cfg.clone());
    let mut d = Driver::new(cfg);
    let mut stats = RestoreStats::default();
    let mut killed = false;
    loop {
        if !killed && d.tick as u64 >= kill_at_tick {
            killed = true;
            let t0 = std::time::Instant::now();
            let mut buf: Vec<u8> = Vec::new();
            stats.snapshot_bytes = server
                .snapshot(&mut buf)
                .map_err(|e| anyhow::anyhow!("snapshot at tick {}: {e}", d.tick))?;
            stats.snapshot_ms = t0.elapsed().as_secs_f64() * 1e3;
            // the "crash": the server AND its engine (weights, prefix
            // index, method caches) drop; nothing survives but the bytes
            drop(server);
            let t1 = std::time::Instant::now();
            server = Server::restore(mk_engine()?, server_cfg.clone(), buf.as_slice())
                .map_err(|e| anyhow::anyhow!("restore at tick {}: {e}", d.tick))?;
            server.check_invariants()?;
            stats.restore_ms = t1.elapsed().as_secs_f64() * 1e3;
        }
        let t0 = std::time::Instant::now();
        let more = d.step(&mut server)?;
        if killed {
            stats.tick_ms = stats.tick_ms.max(t0.elapsed().as_secs_f64() * 1e3);
        }
        if !more {
            break;
        }
    }
    Ok((d.report(server), stats))
}

/// One `--kill-at-tick` trial for the restore report.
#[derive(Clone, Debug)]
pub struct RestoreTrial {
    pub workers: usize,
    pub stats: RestoreStats,
    /// Uninterrupted same-seed fingerprint.
    pub fingerprint: u64,
    /// Fingerprint of the killed-and-restored run.
    pub fingerprint_restored: u64,
    /// `fingerprint != fingerprint_restored` — any drift fails the gate.
    pub drift: bool,
}

/// `BENCH_restore.json` payload (schema `restore-v1`): one kill-and-restore
/// trial per worker width, each judged against its uninterrupted twin.
pub fn restore_report_json(sessions: usize, trials: &[RestoreTrial]) -> Json {
    let runs: Vec<Json> = trials
        .iter()
        .map(|t| {
            obj(vec![
                ("workers", num(t.workers as f64)),
                ("snapshot_bytes", num(t.stats.snapshot_bytes as f64)),
                ("snapshot_ms", num(t.stats.snapshot_ms)),
                ("restore_ms", num(t.stats.restore_ms)),
                ("tick_ms", num(t.stats.tick_ms)),
                ("fingerprint", s(&format!("{:016x}", t.fingerprint))),
                (
                    "fingerprint_restored",
                    s(&format!("{:016x}", t.fingerprint_restored)),
                ),
                ("drift", Json::Bool(t.drift)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", s("restore-v1")),
        ("sessions", num(sessions as f64)),
        ("runs", Json::Arr(runs)),
        (
            "deterministic",
            Json::Bool(trials.iter().all(|t| !t.drift)),
        ),
    ])
}

/// Same-seed agreement: fingerprints (which fold ids, reasons, token
/// streams, and tenant counters) must match exactly.
pub fn deterministic_pair(a: &TrafficReport, b: &TrafficReport) -> bool {
    a.fingerprint == b.fingerprint && a.completed == b.completed && a.ticks == b.ticks
}

/// `BENCH_traffic.json` payload. `repeat` is the same-seed re-run used for
/// the determinism bit; ms percentiles come from run `a` (wall-clock, so
/// excluded from the fingerprint and from any equality check).
pub fn report_json(a: &TrafficReport, repeat: &TrafficReport) -> Json {
    let tenants: Vec<Json> = a
        .tenants
        .iter()
        .map(|t| {
            obj(vec![
                ("tenant", num(t.tenant as f64)),
                ("served", num(t.served as f64)),
                ("unserved", num(t.unserved as f64)),
                ("p50_ttft_ms", num(t.p50_ttft_ms)),
                ("p99_ttft_ms", num(t.p99_ttft_ms)),
                ("p50_latency_ms", num(t.p50_latency_ms)),
                ("p99_latency_ms", num(t.p99_latency_ms)),
                ("p99_queue_ms", num(t.p99_queue_ms)),
                ("parks", num(t.parks as f64)),
                ("preemptions", num(t.preemptions as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", s("traffic-v1")),
        ("seed", num(a.seed as f64)),
        ("sessions", num(a.sessions as f64)),
        ("completed", num(a.completed as f64)),
        ("rejected", num(a.rejected as f64)),
        ("ticks", num(a.ticks as f64)),
        ("max_in_flight", num(a.max_in_flight as f64)),
        ("max_concurrent_decode", num(a.max_concurrent_decode as f64)),
        ("policy_degradations", num(a.policy_degradations as f64)),
        ("p50_ttft_ms", num(a.p50_ttft_ms)),
        ("p99_ttft_ms", num(a.p99_ttft_ms)),
        ("p50_latency_ms", num(a.p50_latency_ms)),
        ("p99_latency_ms", num(a.p99_latency_ms)),
        ("chaos_rate", num(a.chaos_rate)),
        ("invariant_violations", num(a.invariant_violations as f64)),
        ("leaked_pages", num(a.leaked_pages as f64)),
        (
            "faults_injected",
            Json::Arr(a.faults_injected.iter().map(|&x| num(x as f64)).collect()),
        ),
        ("prefill_retries", num(a.prefill_retries as f64)),
        ("fault_recoveries", num(a.fault_recoveries as f64)),
        ("errors", num(a.errors as f64)),
        ("deadline_retirements", num(a.deadline_retirements as f64)),
        ("fingerprint", s(&format!("{:016x}", a.fingerprint))),
        ("fingerprint_repeat", s(&format!("{:016x}", repeat.fingerprint))),
        (
            "deterministic",
            Json::Bool(deterministic_pair(a, repeat)),
        ),
        ("tenants", Json::Arr(tenants)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{Meta, ModelConfig};
    use crate::quant::methods::Method;

    fn small_meta() -> Meta {
        let mut meta = Meta::default_build();
        meta.model = ModelConfig { n_layers: 2, ..meta.model };
        for v in &mut meta.variants {
            v.layers.truncate(2);
            while v.layers.len() < 2 {
                let last = *v.layers.last().unwrap();
                v.layers.push(last);
            }
        }
        meta
    }

    fn small_cfg() -> TrafficConfig {
        TrafficConfig {
            sessions: 24,
            tenants: 3,
            arrival: Arrival::PoissonBurst {
                rate: 4.0,
                burst_every: 10,
                burst_len: 2,
                burst_rate: 12.0,
            },
            max_new: 3,
            prompt_pool: 4,
            prompt_lo: 24,
            prompt_hi: 48,
            ..TrafficConfig::default()
        }
    }

    fn engine() -> Engine {
        Engine::new_reference(small_meta(), 11, Method::bf16(), 32).unwrap()
    }

    #[test]
    fn schedule_is_seeded_and_sorted() {
        let cfg = small_cfg();
        let a = build_schedule(&cfg);
        let b = build_schedule(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), cfg.sessions);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        let other = build_schedule(&TrafficConfig { seed: 8, ..cfg });
        assert_ne!(a, other);
    }

    #[test]
    fn requests_are_seeded_and_tenanted() {
        let cfg = small_cfg();
        let a = gen_requests(&cfg);
        let b = gen_requests(&cfg);
        assert_eq!(a.len(), cfg.sessions);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        assert!(a.iter().all(|r| r.tenant < cfg.tenants));
        assert!(a.iter().any(|r| r.tenant != a[0].tenant));
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut rng = Pcg32::new(3, 4);
        let n = 2000;
        let total: usize = (0..n).map(|_| poisson(&mut rng, 3.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 3.0).abs() < 0.3, "poisson mean {mean}");
    }

    #[test]
    fn open_loop_run_completes_and_repeats() {
        let cfg = small_cfg();
        let a = run(engine(), &cfg).unwrap();
        let b = run(engine(), &cfg).unwrap();
        assert_eq!(a.completed, cfg.sessions);
        assert_eq!(a.rejected, 0);
        assert!(deterministic_pair(&a, &b), "same-seed runs diverged");
        assert!(!a.tenants.is_empty());
        let served: u64 = a.tenants.iter().map(|t| t.served).sum();
        assert_eq!(served as usize, cfg.sessions);
        let j = report_json(&a, &b);
        assert_eq!(j.get("deterministic").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("schema").unwrap(), &Json::Str("traffic-v1".into()));
    }

    #[test]
    fn chaos_soak_recovers_and_balances_books() {
        let cfg = TrafficConfig { chaos: 0.1, ..small_cfg() };
        let a = run(engine(), &cfg).unwrap();
        let b = run(engine(), &cfg).unwrap();
        // every session reaches a terminal state despite injected faults,
        // the books balance after every tick, and nothing leaks at drain
        assert_eq!(a.completed, cfg.sessions, "{}", a.summary);
        assert_eq!(a.invariant_violations, 0, "{}", a.summary);
        assert_eq!(a.leaked_pages, 0, "{}", a.summary);
        assert!(
            a.faults_injected.iter().sum::<u64>() > 0,
            "10% chaos never fired: {:?}",
            a.faults_injected
        );
        // the fault schedule is seeded: the entire failure story repeats
        assert!(deterministic_pair(&a, &b), "same-seed chaos runs diverged");
        assert_eq!(a.faults_injected, b.faults_injected);
        let j = report_json(&a, &b);
        assert_eq!(j.get("deterministic").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("leaked_pages").unwrap(), &num(0.0));
    }

    #[test]
    fn worker_count_does_not_change_fingerprint() {
        // the tentpole bit-identity claim, end to end through the harness:
        // the same seed at workers=1 and workers=4 must agree on every
        // deterministic outcome (ids, reasons, token streams, tenant
        // counters), not merely "both complete"
        let narrow = TrafficConfig { workers: 1, ..small_cfg() };
        let wide = TrafficConfig { workers: 4, ..small_cfg() };
        let a = run(engine(), &narrow).unwrap();
        let b = run(engine(), &wide).unwrap();
        assert!(
            deterministic_pair(&a, &b),
            "workers=4 drifted from workers=1: {:016x} vs {:016x}",
            a.fingerprint,
            b.fingerprint
        );
    }

    #[test]
    fn chaos_fingerprint_is_worker_count_invariant() {
        // fault draws are keyed to (request, ordinal), never to thread
        // schedule: the entire failure story must survive a width change
        let narrow = TrafficConfig { chaos: 0.1, workers: 1, ..small_cfg() };
        let wide = TrafficConfig { chaos: 0.1, workers: 4, ..small_cfg() };
        let a = run(engine(), &narrow).unwrap();
        let b = run(engine(), &wide).unwrap();
        assert!(
            deterministic_pair(&a, &b),
            "chaos at workers=4 drifted from workers=1: {:016x} vs {:016x}",
            a.fingerprint,
            b.fingerprint
        );
        assert_eq!(a.faults_injected, b.faults_injected);
        assert_eq!((a.leaked_pages, b.leaked_pages), (0, 0));
    }

    #[test]
    fn clean_run_reports_zero_failure_counters() {
        let cfg = small_cfg();
        let r = run(engine(), &cfg).unwrap();
        assert_eq!(r.chaos_rate, 0.0);
        assert_eq!(r.faults_injected, [0; N_FAULT_SITES]);
        assert_eq!(r.errors, 0);
        assert_eq!((r.prefill_retries, r.fault_recoveries), (0, 0));
    }

    #[test]
    fn zero_tick_deadline_sheds_every_session() {
        let cfg = TrafficConfig { deadline_ticks: Some(0), ..small_cfg() };
        let r = run(engine(), &cfg).unwrap();
        // nothing can be admitted before the deadline pass sheds it, yet
        // every session still reaches a terminal record
        assert_eq!(r.completed, cfg.sessions);
        assert_eq!(r.deadline_retirements as usize, cfg.sessions, "{}", r.summary);
    }

    #[test]
    fn kill_and_restore_matches_uninterrupted_run() {
        let cfg = small_cfg();
        let clean = run(engine(), &cfg).unwrap();
        let (restored, stats) =
            run_with_kill(&|| Ok(engine()), &cfg, 3).unwrap();
        assert!(
            deterministic_pair(&clean, &restored),
            "restore drifted: {:016x} vs {:016x}\n{}",
            clean.fingerprint,
            restored.fingerprint,
            restored.summary
        );
        assert!(stats.snapshot_bytes > 0, "kill tick never reached");
        let j = restore_report_json(
            cfg.sessions,
            &[RestoreTrial {
                workers: cfg.workers,
                stats,
                fingerprint: clean.fingerprint,
                fingerprint_restored: restored.fingerprint,
                drift: clean.fingerprint != restored.fingerprint,
            }],
        );
        assert_eq!(j.get("schema").unwrap(), &Json::Str("restore-v1".into()));
        assert_eq!(j.get("deterministic").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn kill_and_restore_under_chaos_matches_uninterrupted_run() {
        // the fault schedule is keyed, not positional: tearing the server
        // down mid-soak and restoring must replay the identical failure
        // story (counters fold into the fingerprint under chaos)
        let cfg = TrafficConfig { chaos: 0.1, ..small_cfg() };
        let clean = run(engine(), &cfg).unwrap();
        let (restored, _) = run_with_kill(&|| Ok(engine()), &cfg, 5).unwrap();
        assert!(
            deterministic_pair(&clean, &restored),
            "chaos restore drifted: {:016x} vs {:016x}",
            clean.fingerprint,
            restored.fingerprint
        );
        assert_eq!(clean.faults_injected, restored.faults_injected);
        assert_eq!((clean.leaked_pages, restored.leaked_pages), (0, 0));
    }

    #[test]
    fn closed_loop_bounds_concurrency() {
        let cfg = TrafficConfig {
            sessions: 12,
            arrival: Arrival::ClosedLoop { concurrency: 4, think_ticks: 1 },
            ..small_cfg()
        };
        let r = run(engine(), &cfg).unwrap();
        assert_eq!(r.completed, cfg.sessions);
        assert!(r.max_in_flight <= 4, "closed loop leaked: {}", r.max_in_flight);
    }
}
