//! One driver per paper table/figure (the DESIGN.md experiment index).
//! Each returns a `Table` whose rows mirror the paper's rows; `cargo bench`
//! binaries and the `repro bench --id <id>` CLI both call into here.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::breakdown;
use crate::coordinator::router::{Server, ServerConfig};
use crate::harness::accuracy::{self, rollout};
use crate::harness::pareto;
use crate::harness::perplexity;
use crate::harness::refdriver::RefDriver;
use crate::harness::workloads::{self, suite, TaskKind};
use crate::kvcache::accountant::MemoryAccountant;
use crate::model::config::Meta;
use crate::model::tokenizer;
use crate::model::weights::Weights;
use crate::quant::asym;
use crate::quant::methods::{Method, MethodSpec};
use crate::quant::salience;
use crate::quant::window::TierSpec;
use crate::util::bench::Table;
use crate::util::rng::Pcg32;
use crate::util::stats::{mean, pearson};

pub struct ExpCtx {
    pub artifacts: PathBuf,
    /// Reduced task counts for quick runs (tests / smoke).
    pub quick: bool,
    pub seed: u64,
}

impl ExpCtx {
    pub fn new(artifacts: &Path, quick: bool) -> ExpCtx {
        ExpCtx { artifacts: artifacts.to_path_buf(), quick, seed: 42 }
    }

    fn n_tasks(&self) -> usize {
        if self.quick {
            8
        } else {
            24
        }
    }

    fn engine(&self, method: Method, r_limit: usize) -> Result<Engine> {
        Engine::new(&self.artifacts, method, r_limit)
    }
}

const SUITES: [TaskKind; 4] =
    [TaskKind::Chain, TaskKind::Passkey, TaskKind::KvLookup, TaskKind::Copy];

fn suite_accuracy(
    engine: &mut Engine,
    ctx: &ExpCtx,
    long: bool,
) -> Result<(Vec<f64>, f64)> {
    let mut per = Vec::new();
    for kind in SUITES {
        let tasks = suite(kind, ctx.n_tasks(), ctx.seed, long);
        let rep = accuracy::evaluate(engine, &tasks)?;
        per.push(100.0 * rep.task_acc());
    }
    let avg = per.iter().sum::<f64>() / per.len() as f64;
    Ok((per, avg))
}

fn roster_table(
    ctx: &ExpCtx,
    title: &str,
    methods: &[Method],
    long: bool,
) -> Result<Table> {
    let mut t = Table::new(
        title,
        &["method", "variant", "key-bits", "chain", "passkey", "kvlookup", "copy", "avg"],
    );
    // R=32: with our short synthetic contexts, a 128-token residual
    // would keep everything full-precision (paper contexts are 1000s of
    // tokens); R=32 matches the paper's ablated lower setting (Table 5).
    let mut engine = ctx.engine(methods[0].clone(), 32)?;
    for m in methods {
        engine.set_method(m.clone())?;
        let kb = engine.variant.key_bits;
        let (per, avg) = suite_accuracy(&mut engine, ctx, long)?;
        t.row(vec![
            m.name.clone(),
            m.variant.clone(),
            format!("{kb:.2}"),
            format!("{:.1}", per[0]),
            format!("{:.1}", per[1]),
            format!("{:.1}", per[2]),
            format!("{:.1}", per[3]),
            format!("{avg:.1}"),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig. 1: reasoning score of ~2-bit methods (the headline comparison).
pub fn fig1(ctx: &ExpCtx) -> Result<Table> {
    let methods = vec![
        Method::kvquant("kv2"),
        Method::kivi("kv2"),
        Method::skvq("kv2"),
        Method::rotatekv("kv2"),
        Method::kvtuner(),
        Method::mixkvq("mix225"),
        Method::bf16(),
    ];
    roster_table(ctx, "Fig.1  reasoning score @ ~2-bit budgets (long suites)", &methods, true)
}

/// Fig. 2: per-channel absolute quantization error, key vs value, 2-bit.
pub fn fig2(ctx: &ExpCtx) -> Result<Table> {
    let mut engine = ctx.engine(Method::bf16(), 128)?;
    let mut rng = Pcg32::seeded(ctx.seed);
    let task = workloads::gen_passkey(&mut rng, 380);
    let pre = engine.prefill(&task.prompt)?;
    let mc = engine.meta.model.clone();
    let (t, d, g) = (pre.t, mc.d_head, engine.meta.cache.group);
    let tq = t / g * g;
    let mut table = Table::new(
        "Fig.2  per-channel 2-bit |error| (layer 0, head 0) — key outliers vs flat value",
        &["channel", "K mean|err|", "K max|err|", "K range", "V mean|err|", "V max|err|"],
    );
    let k = &pre.k[0][..tq * d];
    let v = &pre.v[0][..tq * d];
    let (kc, ks, kz) = asym::quantize_key_channelwise(k, tq, d, g, 2, 1.0);
    let kd = asym::dequantize_key_channelwise(&kc, &ks, &kz, tq, d, g);
    let (vc, vs, vz) = asym::quantize_value_tokenwise(v, tq, d, g, 2);
    let vd = asym::dequantize_value_tokenwise(&vc, &vs, &vz, tq, d, g);
    for ch in 0..d {
        let col = |m: &[f32], de: &[f32]| -> (f32, f32) {
            let mut s = 0.0;
            let mut mx = 0.0f32;
            for tok in 0..tq {
                let e = (m[tok * d + ch] - de[tok * d + ch]).abs();
                s += e;
                mx = mx.max(e);
            }
            (s / tq as f32, mx)
        };
        let (kmean, kmax) = col(k, &kd);
        let (vmean, vmax) = col(v, &vd);
        let range = {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for tok in 0..tq {
                lo = lo.min(k[tok * d + ch]);
                hi = hi.max(k[tok * d + ch]);
            }
            hi - lo
        };
        table.row(vec![
            format!("{ch}"),
            format!("{kmean:.4}"),
            format!("{kmax:.4}"),
            format!("{range:.3}"),
            format!("{vmean:.4}"),
            format!("{vmax:.4}"),
        ]);
    }
    Ok(table)
}

/// Fig. 3: query magnitude I_d vs key scale S_d — correlation + tiering.
pub fn fig3(ctx: &ExpCtx) -> Result<Table> {
    let mut engine = ctx.engine(Method::bf16(), 128)?;
    let mut rng = Pcg32::seeded(ctx.seed);
    let task = workloads::gen_passkey(&mut rng, 380);
    let pre = engine.prefill(&task.prompt)?;
    let mc = engine.meta.model.clone();
    let (d, g) = (mc.d_head, engine.meta.cache.group);
    let tq = pre.t / g * g;
    let mut table = Table::new(
        "Fig.3  I_d vs S_d per (layer, head): Pearson r + mix30 tier counts",
        &["layer", "head", "pearson(I,S)", "S p10", "S p90", "A-top2 (BF16 tier)", "I-only top2", "S-only top2"],
    );
    for l in 0..mc.n_layers {
        for h in 0..mc.n_kv_heads {
            let imp = &pre.qabs[l][h * d..(h + 1) * d];
            let k = &pre.k[l][h * pre.t * d..h * pre.t * d + tq * d];
            let sens = salience::sensitivity(k, tq, d, 2);
            let r = pearson(imp, &sens);
            let a = salience::salience(imp, &sens);
            let top2 = |xs: &[f32]| -> Vec<usize> {
                let mut idx: Vec<usize> = (0..d).collect();
                idx.sort_by(|&x, &y| xs[y].partial_cmp(&xs[x]).unwrap());
                idx[..2].to_vec()
            };
            let mut s_sorted: Vec<f32> = sens.clone();
            s_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            table.row(vec![
                format!("{l}"),
                format!("{h}"),
                format!("{r:.3}"),
                format!("{:.3}", s_sorted[d / 10]),
                format!("{:.3}", s_sorted[d * 9 / 10]),
                format!("{:?}", top2(&a)),
                format!("{:?}", top2(imp)),
                format!("{:?}", top2(&sens)),
            ]);
        }
    }
    Ok(table)
}

/// Fig. 5: memory + throughput vs the 16-bit baseline on a ShareGPT-like
/// trace under a fixed KV-memory budget — driven through the session
/// frontend (`submit`/`tick`), including a mixed-precision row where two
/// tenants with different `MethodSpec`s share one server.
pub fn fig5(ctx: &ExpCtx) -> Result<Table> {
    let n_req = if ctx.quick { 12 } else { 48 };
    let max_new = if ctx.quick { 16 } else { 48 };
    let budget: usize = 24 << 20;
    let mut table = Table::new(
        "Fig.5  serving under a fixed KV budget (ShareGPT-like trace)",
        &[
            "method", "R", "max-batch", "peak KV MB", "throughput tok/s",
            "occupancy", "latency p50 ms", "vs bf16",
        ],
    );
    let mut base_tps = 0.0;
    // per-request method overrides: None = the engine default for the row;
    // the mixed row alternates tenants between mix225 and bf16
    let none: &[Option<MethodSpec>] = &[];
    let mixed: &[Option<MethodSpec>] = &[None, Some(MethodSpec::Bf16)];
    for (label, method, r_limit, overrides) in [
        ("bf16", Method::bf16(), 128usize, none),
        ("mixkvq-mix225", Method::mixkvq("mix225"), 32, none),
        ("mixkvq-mix225", Method::mixkvq("mix225"), 128, none),
        ("mixed mix225+bf16", Method::mixkvq("mix225"), 128, mixed),
    ] {
        let engine = ctx.engine(method.clone(), r_limit)?;
        let per_req = MemoryAccountant::worst_case_request_bytes(
            &engine.meta.model,
            &engine.meta.cache,
            &engine.variant.layers,
        );
        let mut server = Server::new(
            engine,
            ServerConfig {
                memory_budget_bytes: budget,
                max_prefills_per_cycle: 2,
                seed: ctx.seed,
                reserve_pages: None,
                ..ServerConfig::default()
            },
        );
        let mut rng = Pcg32::seeded(ctx.seed);
        let mut trace = workloads::sharegpt_trace(&mut rng, n_req, max_new);
        if !overrides.is_empty() {
            for (i, r) in trace.iter_mut().enumerate() {
                r.method = overrides[i % overrides.len()];
            }
        }
        // session frontend: submit everything, tick until drained
        server.metrics.start();
        for r in trace {
            server.submit(r)?;
        }
        while server.has_work() {
            server.tick()?;
            server.drain_events(); // no consumer in this driver
        }
        server.metrics.stop();
        let m = &server.metrics;
        let tps = m.throughput_tps();
        if method.name == "bf16" {
            base_tps = tps;
        }
        let (lat50, _) = m.latency_ms();
        table.row(vec![
            label.to_string(),
            format!("{r_limit}"),
            format!("{}", budget / per_req),
            format!("{:.2}", m.peak_mem_bytes as f64 / 1e6),
            format!("{tps:.1}"),
            format!("{:.2}", m.batch_occupancy()),
            format!("{lat50:.0}"),
            format!("{:.2}x", if base_tps > 0.0 { tps / base_tps } else { 0.0 }),
        ]);
    }
    Ok(table)
}

/// Fig. 6: KVTuner's static layer policy leaves outlier channels at 2-bit —
/// per-layer per-channel error under the kvtuner spec vs mixkvq.
pub fn fig6(ctx: &ExpCtx) -> Result<Table> {
    let meta = Meta::load(&ctx.artifacts)?;
    let weights = Weights::load(&ctx.artifacts, &meta.model)?;
    let mut rng = Pcg32::seeded(ctx.seed);
    let task = workloads::gen_passkey(&mut rng, 380);
    let model = crate::model::reference::RefModel::new(meta.model.clone(), &weights);
    let (_, pre) = model.forward_full(&task.prompt);
    let (d, g) = (meta.model.d_head, meta.cache.group);
    let tq = task.prompt.len() / g * g;
    let kvt = meta.variant("kvtuner")?;
    let mix = meta.variant("mix30")?;
    let mut table = Table::new(
        "Fig.6  mean |K err| per layer: KVTuner static K2 layers leave outlier channels exposed",
        &["layer", "kvtuner spec", "kvtuner mean|err|", "kvtuner max-chan|err|", "mix30 mean|err|", "mix30 max-chan|err|"],
    );
    for l in 0..meta.model.n_layers {
        let k = &pre.k[l][..tq * d];
        let imp = &pre.qabs[l][..d];
        let err_for = |spec: TierSpec, ordering| -> (f32, f32) {
            let order = crate::quant::window::plan_order(ordering, imp, k, tq, d);
            let w = crate::quant::window::quantize_key_window(
                k, tq, d, spec,
                &order,
                crate::quant::window::KeyQuantOpts { clip: 1.0, global_scales: false, group: g },
            );
            let back = crate::quant::window::dequantize_key_window(&w, d, g);
            let mut chan_err = vec![0f32; d];
            for tok in 0..tq {
                for ch in 0..d {
                    chan_err[ch] += (back[tok * d + ch] - k[tok * d + ch]).abs();
                }
            }
            for e in chan_err.iter_mut() {
                *e /= tq as f32;
            }
            (mean(&chan_err), chan_err.iter().cloned().fold(0.0, f32::max))
        };
        let (km, kx) = err_for(kvt.layers[l], salience::Ordering::Natural);
        let (mm, mx) = err_for(mix.layers[l], salience::Ordering::Salience);
        let spec = kvt.layers[l];
        table.row(vec![
            format!("{l}"),
            format!("K{}V{}", if spec.n4 > 0 { 4 } else { 2 }, spec.v_bits),
            format!("{km:.4}"),
            format!("{kx:.4}"),
            format!("{mm:.4}"),
            format!("{mx:.4}"),
        ]);
    }
    Ok(table)
}

/// Fig. 7: accuracy-vs-bits Pareto frontier over the tier grid.
pub fn fig7(ctx: &ExpCtx) -> Result<Table> {
    let meta = Meta::load(&ctx.artifacts)?;
    let weights = Weights::load(&ctx.artifacts, &meta.model)?;
    let n = if ctx.quick { 4 } else { 10 };
    // long passkey + long chains: the two tasks whose accuracy actually
    // moves with cache fidelity at this model scale
    let mut tasks = suite(TaskKind::Passkey, n, ctx.seed, true);
    tasks.extend(suite(TaskKind::Chain, n, ctx.seed, true));
    let points = pareto::search(&meta.model, &meta.cache, &weights, &tasks, 2, 32)?;
    let mut table = Table::new(
        "Fig.7  Pareto frontier: task accuracy vs effective key bits (GSM8K-slice analogue)",
        &["n16", "n4", "n2", "eff-bits", "accuracy %", "frontier"],
    );
    for p in &points {
        table.row(vec![
            format!("{}", p.n16),
            format!("{}", p.n4),
            format!("{}", p.n2),
            format!("{:.2}", p.eff_bits),
            format!("{:.1}", 100.0 * p.accuracy),
            if p.on_frontier { "*".into() } else { "".into() },
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 1: error-accumulation transcript — one chain rolled out under
/// BF16 / MixKVQ / KIVI-2bit / KVTuner.
pub fn tab1(ctx: &ExpCtx) -> Result<Table> {
    let mut rng = Pcg32::seeded(ctx.seed + 3);
    // ~96 steps ≈ 480 generated tokens: long enough that the quantized
    // window dominates and 2-bit flips surface (cf. Table 4 chain-long)
    let task = workloads::gen_chain(&mut rng, 96);
    let mut table = Table::new(
        "Table 1  chained-arithmetic rollouts (greedy): arithmetic self-consistency \
         (the model picks its own ops; each `a OP b = r` step is checked exactly)",
        &["method", "output (truncated)", "steps ok", "first error"],
    );
    let mut engine = ctx.engine(Method::bf16(), 32)?;
    for m in [
        Method::bf16(),
        Method::mixkvq("mix30"),
        Method::kivi("kv4"),
        Method::kivi("kv2"),
        Method::kvtuner(),
    ] {
        engine.set_method(m.clone())?;
        let out = rollout(&mut engine, &task, 500)?;
        let (ok, total, first_bad) = chain_self_consistency(task.prompt[1], &out);
        let mut rendered = tokenizer::render(&out);
        rendered.truncate(90);
        table.row(vec![
            m.name.clone(),
            rendered,
            format!("{ok}/{total}"),
            first_bad.map(|i| format!("step {i}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    Ok(table)
}

/// Parse a greedy chain rollout `OP b = r ; OP b = r ; ...` starting from
/// `start_tok` and count arithmetically-correct steps. This is the Table 1
/// criterion: a single corrupted value invalidates the running chain.
fn chain_self_consistency(start_tok: i32, out: &[i32]) -> (usize, usize, Option<usize>) {
    use crate::model::tokenizer::{tok_num, EQ, OP_ADD, OP_SUB, SEP};
    let _ = start_tok; // the prompt's operand; step 1 is model-structured
    // the rollout opens with step 1's result (the prompt ends at `=`):
    //   r1 ; OP b = r2 ; OP b = r3 ; ...
    let Some(mut prev) = out.first().copied().and_then(tok_num) else {
        return (0, 0, Some(0));
    };
    let mut i = 1;
    if i < out.len() && out[i] == SEP {
        i += 1;
    }
    let mut ok = 0;
    let mut total = 0;
    let mut first_bad = None;
    while i + 3 < out.len() {
        let (op, b, eq, r) = (out[i], out[i + 1], out[i + 2], out[i + 3]);
        if !(op == OP_ADD || op == OP_SUB) || eq != EQ {
            break;
        }
        let (Some(bv), Some(rv)) = (tok_num(b), tok_num(r)) else { break };
        total += 1;
        let want = if op == OP_ADD {
            (prev + bv).rem_euclid(crate::model::tokenizer::NUM_COUNT)
        } else {
            (prev - bv).rem_euclid(crate::model::tokenizer::NUM_COUNT)
        };
        if rv == want {
            ok += 1;
        } else if first_bad.is_none() {
            first_bad = Some(total);
        }
        prev = rv;
        i += 4;
        if i < out.len() && out[i] == SEP {
            i += 1;
        }
    }
    (ok, total, first_bad)
}

/// Table 2: PPL under K/V bit asymmetry — key precision matters more.
pub fn tab2(ctx: &ExpCtx) -> Result<Table> {
    let n = if ctx.quick { 4 } else { 12 };
    let len = if ctx.quick { 160 } else { 320 };
    let seqs = perplexity::corpus(n, len, ctx.seed);
    let mut table = Table::new(
        "Table 2  perplexity, KIVI-style fixed precision (K/V asymmetry)",
        &["method", "K bits", "V bits", "PPL"],
    );
    let mut engine = ctx.engine(Method::bf16(), 32)?;
    for (name, variant, kb, vb) in [
        ("BF16", "bf16", 16, 16),
        ("KIVI-KV4", "kv4", 4, 4),
        ("KIVI-K4V2", "k4v2", 4, 2),
        ("KIVI-K2V4", "k2v4", 2, 4),
        ("KIVI-KV2", "kv2", 2, 2),
    ] {
        engine.set_method(Method::kivi(variant).renamed(name))?;
        let rep = perplexity::evaluate(&mut engine, &seqs)?;
        table.row(vec![
            name.into(),
            format!("{kb}"),
            format!("{vb}"),
            format!("{:.3}", rep.ppl()),
        ]);
    }
    Ok(table)
}

/// Table 3 (and Fig. 1's numbers at 4-bit too): full roster accuracy.
pub fn tab3(ctx: &ExpCtx) -> Result<Table> {
    roster_table(
        ctx,
        "Table 3  reasoning accuracy across methods (teacher-forced pass@1, long suites)",
        &Method::table3_roster("mix30"),
        true,
    )
}

/// Table 4: long-context retrieval (LongBench analogue).
pub fn tab4(ctx: &ExpCtx) -> Result<Table> {
    let methods = vec![
        Method::bf16(),
        Method::kvquant("kv4"),
        Method::kvquant("kv2"),
        Method::kivi("kv4"),
        Method::kivi("kv2"),
        Method::skvq("kv4"),
        Method::skvq("kv2"),
        Method::rotatekv("kv4"),
        Method::rotatekv("kv2"),
        Method::mixkvq("mix225"),
    ];
    roster_table(ctx, "Table 4  long-context suite (LongBench analogue)", &methods, true)
}

/// Table 5: group size G and residual length R ablations (PPL).
pub fn tab5(ctx: &ExpCtx) -> Result<Table> {
    let meta = Meta::load(&ctx.artifacts)?;
    let weights = Weights::load(&ctx.artifacts, &meta.model)?;
    let n = if ctx.quick { 2 } else { 6 };
    let len = if ctx.quick { 160 } else { 256 };
    let seqs = perplexity::corpus(n, len, ctx.seed);
    let spec = meta.variant("mix30")?.layers.clone();
    let mut table = Table::new(
        "Table 5  ablations: group size G and residual length R (PPL, mix30)",
        &["knob", "value", "PPL"],
    );
    for g in [32usize, 64, 128] {
        let mut cc = meta.cache.clone();
        cc.group = g;
        // capacity must stay a multiple of g; 512 is.
        // R = G (the smallest group-aligned residual) so most of each
        // sequence sits in the quantized window for every G
        let driver = RefDriver::new(
            meta.model.clone(), cc, &weights, spec.clone(), Method::mixkvq("mix30"), g,
        );
        let ppl = driver.perplexity(&seqs)?;
        table.row(vec!["G".into(), format!("{g}"), format!("{ppl:.3}")]);
    }
    for r in [32usize, 64, 96, 128] {
        let driver = RefDriver::new(
            meta.model.clone(), meta.cache.clone(), &weights, spec.clone(),
            Method::mixkvq("mix30"), r,
        );
        let ppl = driver.perplexity(&seqs)?;
        table.row(vec!["R".into(), format!("{r}"), format!("{ppl:.3}")]);
    }
    Ok(table)
}

/// Table 6: the query-aware component ablation (A = I·S vs A = S).
pub fn tab6(ctx: &ExpCtx) -> Result<Table> {
    let mut table = Table::new(
        "Table 6  salience ablation: error-only (A=S) vs query-aware (A=I*S)",
        &["method", "chain", "passkey", "kvlookup", "copy", "avg"],
    );
    let mut engine = ctx.engine(Method::mixkvq_error_only("mix225"), 32)?;
    for m in [Method::mixkvq_error_only("mix225"), Method::mixkvq("mix225")] {
        engine.set_method(m.clone())?;
        // long suites: the short ones do not stress the quantized window
        let (per, avg) = suite_accuracy(&mut engine, ctx, true)?;
        table.row(vec![
            m.name.clone(),
            format!("{:.1}", per[0]),
            format!("{:.1}", per[1]),
            format!("{:.1}", per[2]),
            format!("{:.1}", per[3]),
            format!("{avg:.1}"),
        ]);
    }
    Ok(table)
}

/// Table 7: operation-level time breakdown + call rates.
pub fn tab7(ctx: &ExpCtx) -> Result<Table> {
    let n_req = if ctx.quick { 8 } else { 24 };
    let mut engine = ctx.engine(Method::mixkvq("mix30"), 32)?;
    engine.timers = Default::default();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut rng = Pcg32::seeded(ctx.seed);
    let trace = workloads::sharegpt_trace(&mut rng, n_req, 48);
    server.run(trace)?;
    let t = server.engine.timers.clone();
    let b = breakdown(&t);
    let mut table = Table::new(
        "Table 7  per-step time breakdown (decode phase)",
        &["operation", "time %", "calls per step %"],
    );
    table.row(vec![
        "channel selection + quantize".into(),
        format!("{:.2}", b.quantize_pct),
        format!("{:.2}", b.quantize_call_rate_pct),
    ]);
    table.row(vec![
        "model execute (attention+MLP)".into(),
        format!("{:.2}", b.model_exec_pct),
        "100".into(),
    ]);
    table.row(vec![
        "host batch assembly".into(),
        format!("{:.2}", b.assemble_pct),
        "100".into(),
    ]);
    Ok(table)
}

/// Table 8: the "sensitive model" operating point (higher bits, mix325).
pub fn tab8(ctx: &ExpCtx) -> Result<Table> {
    let methods = vec![
        Method::bf16(),
        Method::kivi("kv4"),
        Method::kivi("kv2"),
        Method::kvquant("kv4"),
        Method::kvquant("kv2"),
        Method::rotatekv("kv4"),
        Method::kvtuner(),
        Method::mixkvq("mix325"),
    ];
    roster_table(ctx, "Table 8  sensitive operating point (mix325 / key 3.25 bits, long suites)", &methods, true)
}

impl Method {
    fn renamed(mut self, name: &str) -> Method {
        self.name = name.to_string();
        self
    }
}

/// Extension 1 (beyond the paper): MixKVQ composed with StreamingLLM-style
/// sink + sliding-window eviction (kvcache::eviction) on a deliberately
/// small cache (C=128, R=32), decoding 100-step chains (~500 tokens).
/// Stop dies when the window fills; the sliding window keeps answering.
pub fn ext1(ctx: &ExpCtx) -> Result<Table> {
    use crate::kvcache::eviction::CachePolicy;
    let meta = Meta::load(&ctx.artifacts)?;
    let weights = Weights::load(&ctx.artifacts, &meta.model)?;
    let mut cc = meta.cache.clone();
    cc.capacity = 128;
    cc.residual = 32;
    let spec = meta.variant("mix30")?.layers.clone();
    let n = if ctx.quick { 4 } else { 10 };
    let mut rng = Pcg32::seeded(ctx.seed);
    let tasks: Vec<_> = (0..n).map(|_| workloads::gen_chain(&mut rng, 96)).collect();
    let mut table = Table::new(
        "Ext.1  MixKVQ + sink/sliding-window eviction (C=128, R=32; ~490-token chains)",
        &["policy", "answer acc %", "completed tokens %", "evictions happen"],
    );
    for (name, policy) in [
        ("stop (paper default)", CachePolicy::Stop),
        ("sliding sink=32 evict=32", CachePolicy::SlidingWindow { sink: 32, evict: 32 }),
    ] {
        let driver = RefDriver::new(
            meta.model.clone(), cc.clone(), &weights, spec.clone(),
            Method::mixkvq("mix30"), 32,
        );
        let mut hits = 0usize;
        let mut total = 0usize;
        let mut fed = 0usize;
        let mut want_fed = 0usize;
        let mut evicted_any = false;
        for task in &tasks {
            let (mut cache, last) = driver.prefill(&task.prompt)?;
            cache.policy = policy;
            let mut cursor = task.prompt.len();
            let mut logits = last;
            loop {
                for &(p, want) in &task.answer_positions {
                    if p == cursor {
                        total += 1;
                        if crate::model::sampler::argmax(&logits) == want {
                            hits += 1;
                        }
                    }
                }
                if cursor >= task.gold.len() - 1 {
                    break;
                }
                match driver.step(&mut cache, task.gold[cursor]) {
                    Ok(lg) => {
                        logits = lg;
                        cursor += 1;
                        fed += 1;
                        if cache.evicted_tokens > 0 {
                            evicted_any = true;
                        }
                    }
                    Err(_) => {
                        // cache exhausted: remaining answers are unanswerable
                        total += task.answer_positions.iter().filter(|&&(p, _)| p > cursor).count();
                        break;
                    }
                }
            }
            want_fed += task.gold.len() - 1 - task.prompt.len();
        }
        table.row(vec![
            name.into(),
            format!("{:.1}", 100.0 * hits as f64 / total.max(1) as f64),
            format!("{:.1}", 100.0 * fed as f64 / want_fed.max(1) as f64),
            if evicted_any { "yes" } else { "no" }.into(),
        ]);
    }
    Ok(table)
}

/// Dispatch by experiment id (the CLI surface).
pub fn run(ctx: &ExpCtx, id: &str) -> Result<Table> {
    match id {
        "fig1" => fig1(ctx),
        "fig2" => fig2(ctx),
        "fig3" => fig3(ctx),
        "fig5" => fig5(ctx),
        "fig6" => fig6(ctx),
        "fig7" => fig7(ctx),
        "tab1" => tab1(ctx),
        "tab2" => tab2(ctx),
        "tab3" => tab3(ctx),
        "tab4" => tab4(ctx),
        "tab5" => tab5(ctx),
        "tab6" => tab6(ctx),
        "tab7" => tab7(ctx),
        "tab8" => tab8(ctx),
        "ext1" => ext1(ctx),
        _ => bail!("unknown experiment id `{id}` (fig1-3,5-7, tab1-8)"),
    }
}

pub const ALL_IDS: [&str; 15] = [
    "fig1", "fig2", "fig3", "fig5", "fig6", "fig7",
    "tab1", "tab2", "tab3", "tab4", "tab5", "tab6", "tab7", "tab8", "ext1",
];
