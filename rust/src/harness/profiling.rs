//! Offline sensitivity profiling — the measurement half of the adaptive
//! precision policy (`quant::policy`).
//!
//! KVTuner-style one-layer-at-a-time sweep: for each [`MethodSpec`] and each
//! layer `l`, run teacher-forced perplexity on a seeded calibration corpus
//! (`harness::perplexity::corpus`) through [`RefDriver`] with every layer
//! pinned at bf16 *except* `l`, which takes the spec's tier layout for that
//! layer. The mean-NLL delta vs the all-bf16 baseline is that (spec, layer)
//! sensitivity; summing over layers predicts the full-spec error, and
//! [`SensitivityProfile::predicted_bound`] adds compounding slack to turn
//! the prediction into a quotable bound.
//!
//! The sweep is O(|specs| × n_layers) perplexity evaluations, so it runs
//! once per model via `mixkvq profile` and is cached as a JSON artifact
//! (default `profile.json`) that `PrecisionPolicy::LayerSensitivity` loads
//! at serving time.

use anyhow::{bail, Result};

use crate::harness::perplexity::corpus;
use crate::harness::refdriver::RefDriver;
use crate::kvcache::accountant::MemoryAccountant;
use crate::model::config::Meta;
use crate::model::weights::Weights;
use crate::quant::methods::{Method, MethodSpec};
use crate::quant::policy::{ProfileEntry, SensitivityProfile};
use crate::quant::window::TierSpec;

/// Calibration workload shape. Defaults are sized so the sweep finishes in
/// seconds on the build-default model while still engaging quantization
/// (`seq_len` > `r_limit`, so the window actually flushes past the
/// full-precision residual).
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    /// Calibration sequences.
    pub seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Corpus seed (recorded in the artifact for reproducibility).
    pub seed: u64,
    /// Residual limit for the reference driver — kept small so most of the
    /// context lives in the quantized window.
    pub r_limit: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig { seqs: 4, seq_len: 96, seed: 1234, r_limit: 32 }
    }
}

fn bf16_layer(d_head: usize) -> TierSpec {
    TierSpec { n16: d_head, n4: 0, n2: 0, v_bits: 16 }
}

/// Mean NLL (nats/token) of `specs`' layer layout under `method`.
fn mean_nll(
    meta: &Meta,
    weights: &Weights,
    layers: Vec<TierSpec>,
    method: Method,
    cfg: &ProfileConfig,
    seqs: &[Vec<i32>],
) -> Result<f64> {
    let driver = RefDriver::new(
        meta.model.clone(),
        meta.cache.clone(),
        weights,
        layers,
        method,
        cfg.r_limit,
    );
    Ok(driver.perplexity(seqs)?.ln())
}

/// Run the sensitivity sweep for `specs` and assemble the profile.
/// Unknown-variant specs are an error (the caller picked them); `Bf16` is
/// accepted and short-circuits to zero error without re-running the sweep.
pub fn profile(
    meta: &Meta,
    weights: &Weights,
    specs: &[MethodSpec],
    cfg: &ProfileConfig,
) -> Result<SensitivityProfile> {
    if cfg.seq_len <= cfg.r_limit {
        bail!(
            "seq_len {} must exceed r_limit {} or quantization never engages",
            cfg.seq_len,
            cfg.r_limit
        );
    }
    let nl = meta.model.n_layers;
    let bf16 = bf16_layer(meta.model.d_head);
    let seqs = corpus(cfg.seqs, cfg.seq_len, cfg.seed);
    let baseline_nll = mean_nll(meta, weights, vec![bf16; nl], Method::bf16(), cfg, &seqs)?;
    let mut entries = Vec::with_capacity(specs.len());
    for &spec in specs {
        let variant = meta.variant(spec.variant())?.clone();
        let worst_case_bytes =
            MemoryAccountant::worst_case_request_bytes(&meta.model, &meta.cache, &variant.layers);
        let layer_err = if spec == MethodSpec::Bf16 {
            vec![0.0; nl]
        } else {
            let method = spec.build();
            let mut errs = Vec::with_capacity(nl);
            for l in 0..nl {
                let mut layers = vec![bf16; nl];
                layers[l] = variant.layers[l];
                let nll = mean_nll(meta, weights, layers, method.clone(), cfg, &seqs)?;
                errs.push((nll - baseline_nll).max(0.0));
            }
            errs
        };
        entries.push(ProfileEntry { spec, layer_err, worst_case_bytes });
    }
    Ok(SensitivityProfile {
        baseline_nll,
        n_layers: nl,
        calib_seed: cfg.seed,
        entries,
    })
}

/// Measured full-spec error (mean-NLL delta vs bf16, all layers quantized
/// at once) on the *same* calibration corpus the profile was built from —
/// the quantity `predicted_bound` must cover. Used by the E2E policy test
/// and by `mixkvq profile --check`.
pub fn measured_error(
    meta: &Meta,
    weights: &Weights,
    spec: MethodSpec,
    profile: &SensitivityProfile,
    cfg: &ProfileConfig,
) -> Result<f64> {
    let variant = meta.variant(spec.variant())?.clone();
    let seqs = corpus(cfg.seqs, cfg.seq_len, profile.calib_seed);
    let nll = mean_nll(meta, weights, variant.layers, spec.build(), cfg, &seqs)?;
    Ok((nll - profile.baseline_nll).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn small_meta() -> Meta {
        let mut meta = Meta::default_build();
        meta.model = ModelConfig { n_layers: 2, ..meta.model };
        for v in &mut meta.variants {
            v.layers.truncate(2);
            while v.layers.len() < 2 {
                let last = *v.layers.last().unwrap();
                v.layers.push(last);
            }
        }
        meta
    }

    #[test]
    fn profile_shapes_and_bf16_is_zero() {
        let meta = small_meta();
        let w = Weights::random(&meta.model, 11);
        let cfg = ProfileConfig { seqs: 2, seq_len: 64, ..ProfileConfig::default() };
        let specs = [MethodSpec::Bf16, MethodSpec::Kivi { bits: crate::quant::methods::KiviBits::Kv2 }];
        let p = profile(&meta, &w, &specs, &cfg).unwrap();
        assert_eq!(p.n_layers, 2);
        assert_eq!(p.entries.len(), 2);
        assert!(p.baseline_nll.is_finite());
        assert_eq!(p.predicted_error(MethodSpec::Bf16), Some(0.0));
        let kv2 = p.predicted_error(specs[1]).unwrap();
        assert!(kv2.is_finite() && kv2 >= 0.0);
        // per-layer deltas are individually non-negative and finite
        assert!(p.entries[1].layer_err.iter().all(|e| e.is_finite() && *e >= 0.0));
    }

    #[test]
    fn seq_len_must_engage_quantization() {
        let meta = small_meta();
        let w = Weights::random(&meta.model, 11);
        let cfg = ProfileConfig { seq_len: 16, r_limit: 32, ..ProfileConfig::default() };
        assert!(profile(&meta, &w, &[MethodSpec::Bf16], &cfg).is_err());
    }
}
