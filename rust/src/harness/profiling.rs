//! Offline sensitivity profiling — the measurement half of the adaptive
//! precision policy (`quant::policy`).
//!
//! KVTuner-style one-layer-at-a-time sweep: for each [`MethodSpec`] and each
//! layer `l`, run teacher-forced perplexity on a seeded calibration corpus
//! (`harness::perplexity::corpus`) through [`RefDriver`] with every layer
//! pinned at bf16 *except* `l`, which takes the spec's tier layout for that
//! layer. The mean-NLL delta vs the all-bf16 baseline is that (spec, layer)
//! sensitivity; summing over layers predicts the full-spec error, and
//! [`SensitivityProfile::predicted_bound`] adds compounding slack to turn
//! the prediction into a quotable bound.
//!
//! The sweep is O(|specs| × n_layers) perplexity evaluations, so it runs
//! once per model via `mixkvq profile` and is cached as a JSON artifact
//! (default `profile.json`) that `PrecisionPolicy::LayerSensitivity` loads
//! at serving time.

use anyhow::{bail, Result};

use crate::harness::perplexity::corpus;
use crate::harness::refdriver::RefDriver;
use crate::kvcache::accountant::MemoryAccountant;
use crate::model::config::Meta;
use crate::model::weights::Weights;
use crate::quant::methods::{Method, MethodSpec};
use crate::quant::policy::{ProfileEntry, SensitivityProfile};
use crate::quant::window::TierSpec;

/// Calibration workload shape. Defaults are sized so the sweep finishes in
/// seconds on the build-default model while still engaging quantization
/// (`seq_len` > `r_limit`, so the window actually flushes past the
/// full-precision residual).
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    /// Calibration sequences.
    pub seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Corpus seed (recorded in the artifact for reproducibility).
    pub seed: u64,
    /// Residual limit for the reference driver — kept small so most of the
    /// context lives in the quantized window.
    pub r_limit: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig { seqs: 4, seq_len: 96, seed: 1234, r_limit: 32 }
    }
}

fn bf16_layer(d_head: usize) -> TierSpec {
    TierSpec { n16: d_head, n4: 0, n2: 0, v_bits: 16 }
}

/// Mean NLL (nats/token) of `specs`' layer layout under `method`.
fn mean_nll(
    meta: &Meta,
    weights: &Weights,
    layers: Vec<TierSpec>,
    method: Method,
    cfg: &ProfileConfig,
    seqs: &[Vec<i32>],
) -> Result<f64> {
    let driver = RefDriver::new(
        meta.model.clone(),
        meta.cache.clone(),
        weights,
        layers,
        method,
        cfg.r_limit,
    );
    Ok(driver.perplexity(seqs)?.ln())
}

/// Run the sensitivity sweep for `specs` and assemble the profile.
/// Unknown-variant specs are an error (the caller picked them); `Bf16` is
/// accepted and short-circuits to zero error without re-running the sweep.
pub fn profile(
    meta: &Meta,
    weights: &Weights,
    specs: &[MethodSpec],
    cfg: &ProfileConfig,
) -> Result<SensitivityProfile> {
    if cfg.seq_len <= cfg.r_limit {
        bail!(
            "seq_len {} must exceed r_limit {} or quantization never engages",
            cfg.seq_len,
            cfg.r_limit
        );
    }
    let nl = meta.model.n_layers;
    let bf16 = bf16_layer(meta.model.d_head);
    let seqs = corpus(cfg.seqs, cfg.seq_len, cfg.seed);
    let baseline_nll = mean_nll(meta, weights, vec![bf16; nl], Method::bf16(), cfg, &seqs)?;
    let mut entries = Vec::with_capacity(specs.len());
    for &spec in specs {
        let variant = meta.variant(spec.variant())?.clone();
        let worst_case_bytes =
            MemoryAccountant::worst_case_request_bytes(&meta.model, &meta.cache, &variant.layers);
        let layer_err = if spec == MethodSpec::Bf16 {
            vec![0.0; nl]
        } else {
            let method = spec.build();
            let mut errs = Vec::with_capacity(nl);
            for l in 0..nl {
                let mut layers = vec![bf16; nl];
                layers[l] = variant.layers[l];
                let nll = mean_nll(meta, weights, layers, method.clone(), cfg, &seqs)?;
                errs.push((nll - baseline_nll).max(0.0));
            }
            errs
        };
        entries.push(ProfileEntry { spec, layer_err, worst_case_bytes });
    }
    Ok(SensitivityProfile {
        baseline_nll,
        n_layers: nl,
        calib_seed: cfg.seed,
        entries,
    })
}

/// Measured full-spec error (mean-NLL delta vs bf16, all layers quantized
/// at once) on the *same* calibration corpus the profile was built from —
/// the quantity `predicted_bound` must cover. Used by the E2E policy test
/// and by `mixkvq profile --check`.
pub fn measured_error(
    meta: &Meta,
    weights: &Weights,
    spec: MethodSpec,
    profile: &SensitivityProfile,
    cfg: &ProfileConfig,
) -> Result<f64> {
    let variant = meta.variant(spec.variant())?.clone();
    let seqs = corpus(cfg.seqs, cfg.seq_len, profile.calib_seed);
    let nll = mean_nll(meta, weights, variant.layers, spec.build(), cfg, &seqs)?;
    Ok((nll - profile.baseline_nll).max(0.0))
}

// --- frozen-plan error-budget ablation ---------------------------------
//
// A frozen-plan partial hit serves the matched prefix from the producer's
// quantized pages (bit-identical bytes for an identical prefix), adopts
// the producer's channel plan and scale state, and resumes chunked
// prefill from the divergence seam under the consumer's own plan. The
// resumed tail attends over the *dequantized* prefix rows
// (`RequestCache::dequant_prefix_into`) where an exact private prefill
// attends over the raw full-precision rows, so the served logits carry a
// small quantization-class delta even for methods whose plan state is a
// pure function of the shared prefix (`global_scales == false`).
// Globally-scaled methods (KVQuant) additionally adopt scale state that
// embeds the *producer's whole prompt*, so their delta is unbounded by
// construction — they default OFF and carry no budget promise. This sweep
// MEASURES the delta per [`MethodSpec`] on a seeded workload — the
// verdict justifies the per-method serving default
// (`coordinator::engine::frozen_plan_default`), and the bench gate holds
// every default-on method to [`FROZEN_PLAN_NLL_BUDGET`].

/// Error budget a method must meet for frozen-plan partial hits to be on
/// by default: the last-position NLL delta (nats, at the exact run's
/// argmax token) between a frozen-plan partial hit and an exact private
/// prefill of the same prompt. Sized as 2× the profile machinery's
/// absolute slack ([`crate::quant::policy::PREDICTED_BOUND_EPS`]) because
/// this is a single-position measurement, not a corpus mean.
pub const FROZEN_PLAN_NLL_BUDGET: f64 = 0.5;

/// One method's frozen-plan ablation measurement.
#[derive(Clone, Debug)]
pub struct FrozenPlanEntry {
    pub spec: MethodSpec,
    /// The serving default (`frozen_plan_default`) for this method.
    pub default_on: bool,
    /// Max-abs last-position logit delta, frozen-plan vs exact.
    pub logit_err: f64,
    /// Last-position NLL delta (nats) at the exact run's argmax token.
    pub nll_delta: f64,
    /// `nll_delta <= FROZEN_PLAN_NLL_BUDGET` — the sweep's verdict.
    pub within_budget: bool,
}

/// Shape of the frozen-plan ablation workload. The producer prompt is
/// `shared_tokens + r_limit` long so its quantized window ends exactly at
/// the shared boundary; the consumer shares `shared_tokens` and then
/// diverges for `tail_tokens`.
#[derive(Clone, Debug)]
pub struct FrozenPlanConfig {
    pub seed: u64,
    pub r_limit: usize,
    /// Shared prefix length (must be a whole number of quant groups).
    pub shared_tokens: usize,
    /// Divergent consumer tail.
    pub tail_tokens: usize,
}

impl Default for FrozenPlanConfig {
    fn default() -> Self {
        FrozenPlanConfig { seed: 4242, r_limit: 32, shared_tokens: 64, tail_tokens: 64 }
    }
}

fn last_nll_at(logits: &[f32], tok: usize) -> f64 {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&x| ((x as f64) - mx).exp()).sum();
    -((logits[tok] as f64 - mx) - z.ln())
}

fn run_prefill_to_done(
    engine: &mut crate::coordinator::engine::Engine,
    prompt: &[i32],
    method: &Method,
) -> Result<(crate::coordinator::engine::PrefillAdmission, crate::coordinator::engine::ChunkedPrefill)>
{
    let (adm, mut cp) = engine.admit_prefill(prompt, method)?;
    while !engine.advance_prefill_chunked(&mut cp, prompt, usize::MAX)? {}
    Ok((adm, cp))
}

/// Measure one method's frozen-plan error: producer registers its prompt
/// into a radix tree, a consumer sharing `shared_tokens` takes a forced
/// frozen-plan partial hit, and the consumer's last-position logits are
/// compared against an exact private prefill of the identical prompt on an
/// identically-seeded engine with no tree.
pub fn frozen_plan_error(meta: &Meta, spec: MethodSpec, cfg: &FrozenPlanConfig) -> Result<FrozenPlanEntry> {
    use crate::coordinator::engine::{frozen_plan_default, Engine, PrefillAdmission};
    use crate::kvcache::radix::RadixTree;
    use crate::util::rng::Pcg32;
    use std::cell::RefCell;
    use std::rc::Rc;

    let group = meta.cache.group;
    if cfg.shared_tokens == 0 || cfg.shared_tokens % group != 0 {
        bail!(
            "shared_tokens {} must be a positive multiple of the quant group {group}",
            cfg.shared_tokens
        );
    }
    let method = spec.build();
    let mut rng = Pcg32::seeded(cfg.seed);
    let vocab = meta.model.vocab as i32;
    let mut toks = |n: usize| -> Vec<i32> {
        (0..n).map(|_| (rng.next_u32() as i32).rem_euclid(vocab)).collect()
    };
    let shared = toks(cfg.shared_tokens);
    // producer ends exactly r_limit past the boundary: its quantized window
    // closes at shared_tokens, so the registered chain covers the shared
    // prefix precisely
    let producer: Vec<i32> = shared.iter().copied().chain(toks(cfg.r_limit)).collect();
    let consumer: Vec<i32> = shared.iter().copied().chain(toks(cfg.tail_tokens + cfg.r_limit)).collect();

    // frozen path: tree installed, frozen-plan FORCED on so even methods
    // that default off get measured
    let mut frozen_engine = Engine::new_reference(meta.clone(), cfg.seed, method.clone(), cfg.r_limit)?;
    let pool = frozen_engine.build_shared_pool(64 << 20);
    let page_bytes = pool.page_deploy_bytes();
    frozen_engine.set_kv_pool(pool);
    frozen_engine.set_prefix_tree(Rc::new(RefCell::new(RadixTree::new(1 << 20, page_bytes))));
    frozen_engine.set_frozen_plan(Some(true));
    let (adm, mut pcp) = run_prefill_to_done(&mut frozen_engine, &producer, &method)?;
    if adm != PrefillAdmission::Miss {
        bail!("producer prompt unexpectedly hit the empty tree");
    }
    let last = pcp.run.last_logits().to_vec();
    if !frozen_engine.register_prefix(&mut pcp.cache, &producer, &method, &last) {
        bail!("producer registration refused");
    }
    let (adm, ccp) = run_prefill_to_done(&mut frozen_engine, &consumer, &method)?;
    match adm {
        PrefillAdmission::PartialHit { matched_tokens, .. } if matched_tokens == cfg.shared_tokens => {}
        other => bail!(
            "consumer expected a partial hit at {} tokens, got {other:?}",
            cfg.shared_tokens
        ),
    }
    let frozen_logits = ccp.run.last_logits().to_vec();

    // exact path: identically seeded engine, no tree — a private prefill
    let mut exact_engine = Engine::new_reference(meta.clone(), cfg.seed, method.clone(), cfg.r_limit)?;
    let (_, ecp) = run_prefill_to_done(&mut exact_engine, &consumer, &method)?;
    let exact_logits = ecp.run.last_logits();

    let logit_err = frozen_logits
        .iter()
        .zip(exact_logits)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    let argmax = exact_logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let nll_delta = (last_nll_at(&frozen_logits, argmax) - last_nll_at(exact_logits, argmax)).abs();
    Ok(FrozenPlanEntry {
        spec,
        default_on: frozen_plan_default(&method),
        logit_err,
        nll_delta,
        within_budget: nll_delta <= FROZEN_PLAN_NLL_BUDGET,
    })
}

/// Run [`frozen_plan_error`] for every spec. The serving contract the
/// bench gate holds: every method whose default is ON measures within
/// [`FROZEN_PLAN_NLL_BUDGET`].
pub fn frozen_plan_sweep(
    meta: &Meta,
    specs: &[MethodSpec],
    cfg: &FrozenPlanConfig,
) -> Result<Vec<FrozenPlanEntry>> {
    specs.iter().map(|&s| frozen_plan_error(meta, s, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn small_meta() -> Meta {
        let mut meta = Meta::default_build();
        meta.model = ModelConfig { n_layers: 2, ..meta.model };
        for v in &mut meta.variants {
            v.layers.truncate(2);
            while v.layers.len() < 2 {
                let last = *v.layers.last().unwrap();
                v.layers.push(last);
            }
        }
        meta
    }

    #[test]
    fn profile_shapes_and_bf16_is_zero() {
        let meta = small_meta();
        let w = Weights::random(&meta.model, 11);
        let cfg = ProfileConfig { seqs: 2, seq_len: 64, ..ProfileConfig::default() };
        let specs = [MethodSpec::Bf16, MethodSpec::Kivi { bits: crate::quant::methods::KiviBits::Kv2 }];
        let p = profile(&meta, &w, &specs, &cfg).unwrap();
        assert_eq!(p.n_layers, 2);
        assert_eq!(p.entries.len(), 2);
        assert!(p.baseline_nll.is_finite());
        assert_eq!(p.predicted_error(MethodSpec::Bf16), Some(0.0));
        let kv2 = p.predicted_error(specs[1]).unwrap();
        assert!(kv2.is_finite() && kv2 >= 0.0);
        // per-layer deltas are individually non-negative and finite
        assert!(p.entries[1].layer_err.iter().all(|e| e.is_finite() && *e >= 0.0));
    }

    #[test]
    fn seq_len_must_engage_quantization() {
        let meta = small_meta();
        let w = Weights::random(&meta.model, 11);
        let cfg = ProfileConfig { seq_len: 16, r_limit: 32, ..ProfileConfig::default() };
        assert!(profile(&meta, &w, &[MethodSpec::Bf16], &cfg).is_err());
    }

    #[test]
    fn frozen_plan_default_on_methods_measure_within_budget() {
        let meta = Meta::default_build();
        let cfg = FrozenPlanConfig::default();
        let specs: Vec<MethodSpec> = ["mixkvq-mix30", "kivi-kv2", "kvquant-kv2", "kvtuner"]
            .iter()
            .map(|n| n.parse::<MethodSpec>().unwrap())
            .collect();
        let entries = frozen_plan_sweep(&meta, &specs, &cfg).unwrap();
        assert_eq!(entries.len(), specs.len());
        for e in &entries {
            assert!(e.logit_err.is_finite() && e.nll_delta.is_finite(), "{:?}", e.spec);
            // the serving contract: every method whose frozen-plan default
            // is ON must measure inside the error budget (globally-scaled
            // methods default OFF and carry no such promise)
            if e.default_on {
                assert!(
                    e.within_budget,
                    "{:?}: frozen-plan nll delta {} exceeds budget {}",
                    e.spec, e.nll_delta, FROZEN_PLAN_NLL_BUDGET
                );
            }
        }
        // the plan-locality split the serving default encodes: the paper
        // method plans from the shared prefix alone and defaults ON;
        // KVQuant's whole-prompt scale state defaults OFF
        assert!(entries[0].default_on, "mixkvq must default frozen-plan ON");
        assert!(!entries[2].default_on, "kvquant must default frozen-plan OFF");
    }

    #[test]
    fn frozen_plan_config_rejects_unaligned_prefix() {
        let meta = Meta::default_build();
        let cfg = FrozenPlanConfig { shared_tokens: 33, ..FrozenPlanConfig::default() };
        let spec = "mixkvq-mix30".parse::<MethodSpec>().unwrap();
        assert!(frozen_plan_error(&meta, spec, &cfg).is_err());
    }
}
