//! MixKVQ CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve   --method <name[,name,...]> --requests N --max-new N --r-limit N --budget-mb N
//!   bench   --id <fig1|...|tab8|all> [--quick]
//!   demo    --id tab1            (error-accumulation transcript)
//!   search  [--quick]            (Fig. 7 Pareto threshold search)
//!   info                         (methods + artifacts + variants)
//!   profile --out profile.json   (offline sensitivity sweep -> policy artifact)
//!   traffic --sessions N --seed S --out BENCH_traffic.json
//!           (seeded multi-tenant load through the real server; runs the
//!            same seed twice and records the determinism verdict)
//!           --chaos R injects seeded faults at rate R at every fault site
//!           and audits invariants each tick (writes BENCH_chaos.json);
//!           --deadline-ticks D stamps a tick deadline on every request;
//!           --kill-at-tick N snapshots/tears down/restores mid-run and
//!           demands zero fingerprint drift (writes BENCH_restore.json)
//!
//! `serve` drives the session frontend (`submit`/`tick`/`drain_events`).
//! `--method` takes one or more comma-separated method names: the first is
//! the server default, and with several names the trace's requests are
//! routed round-robin across them per-request — one server, multiple
//! precision policies, batched per decode variant.

use std::path::PathBuf;

use anyhow::Result;

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::router::{default_workers, Server, ServerConfig};
use mixkvq::harness::experiments::{self, ExpCtx, ALL_IDS};
use mixkvq::harness::workloads;
use mixkvq::model::config::Meta;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::cli::Args;
use mixkvq::util::rng::Pcg32;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand().unwrap_or("help") {
        "serve" => serve(&args),
        "bench" => bench(&args),
        "demo" => {
            let ctx = ExpCtx::new(&artifacts_dir(&args), args.has("quick"));
            let id = args.get_or("id", "tab1");
            println!("{}", experiments::run(&ctx, &id)?.print());
            Ok(())
        }
        "search" => {
            let ctx = ExpCtx::new(&artifacts_dir(&args), args.has("quick"));
            println!("{}", experiments::run(&ctx, "fig7")?.print());
            Ok(())
        }
        "info" => info(&args),
        "profile" => profile(&args),
        "traffic" => traffic(&args),
        _ => {
            println!(
                "mixkvq — query-aware mixed-precision KV cache quantization\n\n\
                 USAGE: mixkvq <serve|bench|demo|search|info|profile|traffic> [options]\n\n\
                 serve   --method mixkvq-mix30 --requests 32 --max-new 48 --r-limit 128 --budget-mb 64\n\
                 \x20       [--snapshot-path state.snap --snapshot-every-ticks 50] write a\n\
                 \x20       crash-safe mixkvq-snap-v2 image of the live server every N ticks\n\
                 \x20       (write-then-rename; a failed write never clobbers the last good\n\
                 \x20       image). Add --restore to resume from the image instead of\n\
                 \x20       starting cold — corrupt pages quarantine and retire only their\n\
                 \x20       owning requests.\n\
                 \x20       [--workers N]  worker-pool lanes for per-tick compute sharding\n\
                 \x20       (default: MIXKVQ_WORKERS env or available parallelism; 1 = the\n\
                 \x20       single-threaded path; outputs are bit-identical at every N)\n\
                 \x20       [--frozen-plan on|off]  serve partial prefix-tree hits by\n\
                 \x20       adopting the producer's frozen quantization plan (default:\n\
                 \x20       MIXKVQ_FROZEN_PLAN env, else per-method ablation verdict).\n\
                 \x20       Unset flags fall back to env defaults resolved by\n\
                 \x20       ServerConfig::builder(): MIXKVQ_WORKERS, MIXKVQ_FROZEN_PLAN,\n\
                 \x20       MIXKVQ_PREFIX_CACHE_PAGES, MIXKVQ_SNAPSHOT_PATH,\n\
                 \x20       MIXKVQ_SNAPSHOT_EVERY_TICKS.\n\
                 \x20       --method accepts a comma-separated list (e.g. mixkvq-mix30,bf16):\n\
                 \x20       the first name is the server default, and requests are routed\n\
                 \x20       round-robin across the list per-request — the server batches\n\
                 \x20       each decode variant separately, so mixed-precision tenants\n\
                 \x20       share one process. Internally serve uses the session API:\n\
                 \x20       submit() -> id, tick() per cycle, poll(id), cancel(id), and\n\
                 \x20       drain_events() (Queued -> Admitted -> FirstToken -> Token* ->\n\
                 \x20       Finished). Method names are listed by `mixkvq info`.\n\
                 bench   --id all|fig1|fig2|fig3|fig5|fig6|fig7|tab1..tab8 [--quick]\n\
                 demo    --id tab1\n\
                 search  [--quick]\n\
                 info\n\
                 profile --out profile.json --seqs 4 --len 96 --seed 1234 --r-limit 32\n\
                 \x20       one-layer-at-a-time sensitivity sweep over every MethodSpec;\n\
                 \x20       the JSON artifact feeds PrecisionPolicy::LayerSensitivity.\n\
                 traffic --sessions 200 --tenants 4 --seed 7 --max-new 6 --budget-mb 64\n\
                 \x20       --arrival poisson|diurnal|closed --out BENCH_traffic.json\n\
                 \x20       [--policy slo:<mb>|profile:<path>|fixed:<method>]\n\
                 \x20       [--chaos 0.05] [--deadline-ticks 500] [--workers N]\n\
                 \x20       seeded multi-tenant load through submit/tick/poll on the\n\
                 \x20       reference engine (no artifacts needed); same seed runs twice\n\
                 \x20       and the report records per-tenant p50/p99 SLOs plus the\n\
                 \x20       determinism verdict. --chaos injects seeded faults at every\n\
                 \x20       site (lease/prefill/decode/prefix), audits invariants each\n\
                 \x20       tick, and fails on any violation, leak, or stranded session\n\
                 \x20       (default artifact becomes BENCH_chaos.json).\n\
                 \x20       --kill-at-tick N snapshots the server at tick N, tears it\n\
                 \x20       down completely, restores from the bytes, and drains — at\n\
                 \x20       workers 1 and 4 — failing on any fingerprint drift vs the\n\
                 \x20       uninterrupted run (writes BENCH_restore.json; --restore is\n\
                 \x20       implied).\n\n\
                 Global: --artifacts <dir> (default: artifacts)"
            );
            Ok(())
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let method_arg = args.get_or("method", "mixkvq-mix30");
    let specs = method_arg
        .split(',')
        .map(|name| {
            name.trim()
                .parse::<MethodSpec>()
                .map_err(|e| anyhow::anyhow!("{e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let default_method = specs[0].build();
    let n_requests = args.usize_or("requests", 32)?;
    let max_new = args.usize_or("max-new", 48)?;
    let r_limit = args.usize_or("r-limit", 128)?;
    let budget_mb = args.usize_or("budget-mb", 64)?;
    let seed = args.u64_or("seed", 0)?;

    eprintln!("loading engine (default {})...", default_method.name);
    let engine = Engine::new(&artifacts_dir(args), default_method, r_limit)?;
    // everything not set by a CLI flag resolves to its env default inside
    // ServerConfigBuilder::build() — MIXKVQ_WORKERS, MIXKVQ_FROZEN_PLAN,
    // MIXKVQ_PREFIX_CACHE_PAGES, MIXKVQ_SNAPSHOT_PATH /
    // MIXKVQ_SNAPSHOT_EVERY_TICKS — in exactly one place
    let mut cfg_b = ServerConfig::builder()
        .memory_budget_bytes(budget_mb << 20)
        .max_prefills_per_cycle(2)
        .seed(seed);
    if args.get("workers").is_some() {
        cfg_b = cfg_b.workers(args.usize_or("workers", 1)?);
    }
    if let Some(v) = args.get("frozen-plan") {
        cfg_b = cfg_b.frozen_plan(Some(match v.as_str() {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => anyhow::bail!("--frozen-plan takes on|off, got {other}"),
        }));
    }
    // crash safety: --snapshot-path (+ --snapshot-every-ticks N) writes a
    // mixkvq-snap-v2 image of the live server every N ticks; --restore
    // resumes from that image instead of starting cold
    if args.get("snapshot-path").is_some() || args.get("snapshot-every-ticks").is_some() {
        cfg_b = cfg_b.snapshot(
            args.get("snapshot-path").map(PathBuf::from),
            args.u64_or("snapshot-every-ticks", 0)?,
        );
    }
    let server_cfg = cfg_b.build();
    let snap_path = server_cfg.snapshot_path.clone();
    let snap_every = server_cfg.snapshot_every_ticks;
    let mut server = match (&snap_path, args.has("restore")) {
        (Some(p), true) => {
            let f = std::fs::File::open(p)
                .map_err(|e| anyhow::anyhow!("--restore: cannot open {}: {e}", p.display()))?;
            let s = Server::restore(engine, server_cfg, std::io::BufReader::new(f))
                .map_err(|e| anyhow::anyhow!("--restore from {}: {e}", p.display()))?;
            eprintln!("restored server state from {}", p.display());
            s
        }
        (None, true) => anyhow::bail!("--restore requires --snapshot-path <file>"),
        _ => Server::new(engine, server_cfg),
    };
    let resumed = args.has("restore");
    let mut rng = Pcg32::seeded(seed);
    let mut trace = workloads::sharegpt_trace(&mut rng, n_requests, max_new);
    if specs.len() > 1 {
        workloads::assign_methods(&mut trace, &specs);
        eprintln!(
            "routing {n_requests} requests round-robin across {} methods",
            specs.len()
        );
    }
    if resumed {
        // a resumed server already owns the interrupted work (queued,
        // prefilling, decoding); drain that instead of re-submitting
        eprintln!("draining restored work (max_new={max_new}, R={r_limit})...");
    } else {
        eprintln!("serving {n_requests} requests (max_new={max_new}, R={r_limit})...");
        server.metrics.start();
        for r in trace {
            server.submit(r)?;
        }
    }
    let mut n_events = 0usize;
    let mut ticks_since_snap = 0u64;
    while server.has_work() {
        server.tick()?;
        n_events += server.drain_events().len();
        ticks_since_snap += 1;
        if let (Some(p), true) = (&snap_path, snap_every > 0 && ticks_since_snap >= snap_every) {
            ticks_since_snap = 0;
            // write-then-rename so a crash mid-write never clobbers the
            // last good image
            let tmp = PathBuf::from(format!("{}.tmp", p.display()));
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            match server.snapshot(&mut f) {
                Ok(bytes) => {
                    use std::io::Write as _;
                    f.flush()?;
                    drop(f);
                    std::fs::rename(&tmp, p)?;
                    eprintln!("snapshot: {bytes} B -> {}", p.display());
                }
                Err(e) => {
                    drop(f);
                    let _ = std::fs::remove_file(&tmp);
                    eprintln!("snapshot failed (serving continues): {e}");
                }
            }
        }
    }
    server.metrics.stop();
    n_events += server.drain_events().len();
    println!("{}", server.metrics.summary());
    let b = mixkvq::coordinator::metrics::breakdown(&server.engine.timers);
    println!(
        "breakdown: model_exec {:.1}%  quantize {:.1}%  assemble {:.1}%  (quant events/step {:.1}%)",
        b.model_exec_pct, b.quantize_pct, b.assemble_pct, b.quantize_call_rate_pct
    );
    println!(
        "arg scratch pool: {:.1}% of steps reused pooled buffers ({} KB pooled across variants)",
        b.assemble_reuse_pct,
        b.scratch_bytes_pooled / 1024
    );
    println!(
        "worker pool: {} lanes, effective speedup {:.2}x, dispatch imbalance {:.1}% \
         ({} parallel ticks)",
        b.workers, b.parallel_speedup, b.dispatch_imbalance_pct, b.parallel_ticks
    );
    let t = &server.engine.timers;
    if t.prefill_chunks > 0 {
        println!(
            "prefill: {} tokens in {} chunks, {:.0} tok/s (chunked direct-to-page)",
            t.prefill_tokens, b.prefill_chunks, b.prefill_tok_s
        );
    }
    let ps = server.pool.stats();
    println!(
        "kv page pool: {} pages x {} B, high water {} ({} lease failures, \
         {} parks / {} resumes / {} preemptions)",
        ps.max_pages.unwrap_or(0),
        ps.page_deploy_bytes,
        ps.high_water,
        ps.lease_failures,
        server.metrics.pool_parks,
        server.metrics.pool_resumes,
        server.metrics.pool_preemptions,
    );
    let m = &server.metrics;
    println!(
        "prefix sharing: {} full + {} partial hits / {} misses, {} tails \
         ({} nodes) pinning {} pages \
         ({:.2} MB deduped, {} prefill chunks skipped, {} reorder ticks, \
         {} entries shed, {} KB sidecar)",
        m.prefix_hits,
        m.prefix_partial_hits,
        m.prefix_misses,
        m.prefix_entries,
        m.prefix_nodes,
        m.prefix_pages_pinned,
        m.prefix_bytes_deduped as f64 / 1e6,
        t.prefill_chunks_skipped,
        t.prefill_reorders,
        m.prefix_evictions,
        m.prefix_sidecar_bytes / 1024,
    );
    // per-method completion counts (the routing receipt)
    for (m, n) in server.metrics.completed_by_method() {
        println!("  {m}: {n} requests");
    }
    println!(
        "completed {} requests ({n_events} lifecycle events)",
        server.metrics.completed.total()
    );
    Ok(())
}

/// Offline sensitivity sweep — writes the policy artifact
/// `PrecisionPolicy::LayerSensitivity` loads at serving time.
fn profile(args: &Args) -> Result<()> {
    use mixkvq::harness::profiling;
    use mixkvq::model::weights::Weights;

    let out = args.get_or("out", "profile.json");
    let cfg = profiling::ProfileConfig {
        seqs: args.usize_or("seqs", 4)?,
        seq_len: args.usize_or("len", 96)?,
        seed: args.u64_or("seed", 1234)?,
        r_limit: args.usize_or("r-limit", 32)?,
    };
    let dir = artifacts_dir(args);
    let meta = match Meta::load(&dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("(artifacts/ not built — profiling the build-default model)");
            Meta::default_build()
        }
    };
    let weights = Weights::load(&dir, &meta.model)
        .unwrap_or_else(|_| Weights::random(&meta.model, args.u64_or("weights-seed", 11).unwrap_or(11)));
    let specs: Vec<MethodSpec> = MethodSpec::all()
        .into_iter()
        .filter(|s| meta.variant(s.variant()).is_ok())
        .collect();
    eprintln!(
        "profiling {} specs x {} layers (seqs={}, len={}, seed={})...",
        specs.len(),
        meta.model.n_layers,
        cfg.seqs,
        cfg.seq_len,
        cfg.seed
    );
    let prof = profiling::profile(&meta, &weights, &specs, &cfg)?;
    for e in &prof.entries {
        let name = e.spec.to_string();
        println!(
            "  {name:<18} predicted_err={:.4} bound={:.4} worst_case={} KB",
            prof.predicted_error(e.spec).unwrap_or(0.0),
            prof.predicted_bound(e.spec).unwrap_or(0.0),
            e.worst_case_bytes / 1024,
        );
    }
    prof.save(std::path::Path::new(&out))?;
    println!(
        "wrote {out} (baseline_nll={:.4}, {} specs)",
        prof.baseline_nll,
        prof.entries.len()
    );
    Ok(())
}

/// Seeded multi-tenant traffic through the real server on the reference
/// engine (artifact-free). Runs the same seed twice; the JSON report
/// carries both fingerprints and the determinism verdict the bench gate
/// checks.
fn traffic(args: &Args) -> Result<()> {
    use mixkvq::harness::traffic::{self as tr, Arrival, TrafficConfig};
    use mixkvq::quant::policy::{PrecisionPolicy, SensitivityProfile};

    let chaos = args.f64_or("chaos", 0.0)?;
    if !(0.0..=1.0).contains(&chaos) {
        anyhow::bail!("--chaos takes a fault rate in [0, 1], got {chaos}");
    }
    // chaos soaks get their own artifact so the bench gate can hold both
    // the clean-traffic and the chaos bars at once
    let default_out = if chaos > 0.0 { "BENCH_chaos.json" } else { "BENCH_traffic.json" };
    let out = args.get_or("out", default_out);
    let deadline = args.u64_or("deadline-ticks", 0)?;
    let arrival = match args.get_or("arrival", "poisson").as_str() {
        "diurnal" => Arrival::DiurnalRamp { lo: 2.0, hi: 24.0, period: 64 },
        "closed" => Arrival::ClosedLoop {
            concurrency: args.usize_or("concurrency", 32)?,
            think_ticks: args.usize_or("think", 2)?,
        },
        _ => Arrival::PoissonBurst {
            rate: 8.0,
            burst_every: 40,
            burst_len: 8,
            burst_rate: 64.0,
        },
    };
    let policy = if let Some(p) = args.get("policy") {
        Some(match p.split_once(':') {
            Some(("slo", mb)) => PrecisionPolicy::MemorySlo {
                budget_bytes: mb.parse::<usize>().map_err(|e| anyhow::anyhow!("bad --policy slo:<mb>: {e}"))? << 20,
            },
            Some(("profile", path)) => PrecisionPolicy::LayerSensitivity {
                profile: SensitivityProfile::load(std::path::Path::new(path))?,
            },
            Some(("fixed", name)) => PrecisionPolicy::Fixed(
                name.parse::<MethodSpec>().map_err(|e| anyhow::anyhow!("{e}"))?,
            ),
            _ => anyhow::bail!("--policy takes slo:<mb> | profile:<path> | fixed:<method>"),
        })
    } else {
        None
    };
    let cfg = TrafficConfig {
        seed: args.u64_or("seed", 7)?,
        sessions: args.usize_or("sessions", 200)?,
        tenants: args.usize_or("tenants", 4)? as u32,
        arrival,
        max_new: args.usize_or("max-new", 6)?,
        memory_budget_bytes: args.usize_or("budget-mb", 64)? << 20,
        policy,
        chaos,
        deadline_ticks: (deadline > 0).then_some(deadline),
        workers: args.usize_or("workers", default_workers())?.max(1),
        ..TrafficConfig::default()
    };
    let r_limit = args.usize_or("r-limit", 32)?;
    let engine_seed = args.u64_or("weights-seed", 11)?;
    let mk_engine = || Engine::new_reference(Meta::default_build(), engine_seed, Method::bf16(), r_limit);

    // kill-and-restore smoke: snapshot the server at a tick boundary, tear
    // it down (engine included), restore from the bytes, drain — at worker
    // widths 1 and 4 — and demand zero fingerprint drift vs uninterrupted
    // same-seed runs. (--restore is implied and accepted as a flag.)
    let kill_at = args.u64_or("kill-at-tick", 0)?;
    if kill_at > 0 {
        let out = args.get_or("out", "BENCH_restore.json");
        let mut trials: Vec<tr::RestoreTrial> = Vec::new();
        for workers in [1usize, 4] {
            let wcfg = TrafficConfig { workers, ..cfg.clone() };
            eprintln!(
                "kill-restore: {} sessions, workers={workers}, kill at tick {kill_at}...",
                wcfg.sessions
            );
            let clean = tr::run(mk_engine()?, &wcfg)?;
            let (restored, stats) = tr::run_with_kill(&mk_engine, &wcfg, kill_at)?;
            let drift = clean.fingerprint != restored.fingerprint
                || !tr::deterministic_pair(&clean, &restored);
            println!(
                "workers={workers}: snapshot {} B in {:.2} ms, restore {:.2} ms \
                 (worst post-restore tick {:.2} ms), drift={drift}",
                stats.snapshot_bytes, stats.snapshot_ms, stats.restore_ms, stats.tick_ms
            );
            trials.push(tr::RestoreTrial {
                workers,
                stats,
                fingerprint: clean.fingerprint,
                fingerprint_restored: restored.fingerprint,
                drift,
            });
        }
        let j = tr::restore_report_json(cfg.sessions, &trials);
        std::fs::write(&out, j.print())?;
        println!("wrote {out}");
        if trials.iter().any(|t| t.drift) {
            anyhow::bail!("kill-and-restore drifted from the uninterrupted run");
        }
        return Ok(());
    }

    eprintln!(
        "traffic: {} sessions, {} tenants, seed {} (running twice for determinism)...",
        cfg.sessions, cfg.tenants, cfg.seed
    );
    let a = tr::run(mk_engine()?, &cfg)?;
    let b = tr::run(mk_engine()?, &cfg)?;
    let j = tr::report_json(&a, &b);
    std::fs::write(&out, j.print())?;
    println!("{}", a.summary);
    println!(
        "traffic: completed {}/{} (rejected {}), {} ticks, max in-flight {}, \
         p99 ttft {:.1} ms, policy degradations {}, deterministic={}",
        a.completed,
        a.sessions,
        a.rejected,
        a.ticks,
        a.max_in_flight,
        a.p99_ttft_ms,
        a.policy_degradations,
        tr::deterministic_pair(&a, &b),
    );
    if chaos > 0.0 {
        println!(
            "chaos: rate {:.3}, faults injected {:?}, prefill retries {}, \
             recovered {}, errors {}, deadline retirements {}, \
             invariant violations {}, leaked pages {}",
            a.chaos_rate,
            a.faults_injected,
            a.prefill_retries,
            a.fault_recoveries,
            a.errors,
            a.deadline_retirements,
            a.invariant_violations,
            a.leaked_pages,
        );
    }
    println!("wrote {out}");
    if !tr::deterministic_pair(&a, &b) {
        anyhow::bail!("same-seed traffic runs diverged: {:016x} vs {:016x}", a.fingerprint, b.fingerprint);
    }
    if chaos > 0.0 {
        // the soak's hard assertions: chaos must never corrupt the books
        if a.invariant_violations > 0 {
            anyhow::bail!("chaos soak hit {} invariant violations", a.invariant_violations);
        }
        if a.leaked_pages > 0 {
            anyhow::bail!("chaos soak leaked {} pool pages at drain", a.leaked_pages);
        }
        if a.completed != a.sessions {
            anyhow::bail!(
                "chaos soak stranded {} sessions short of a terminal state",
                a.sessions - a.completed
            );
        }
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let ctx = ExpCtx::new(&artifacts_dir(args), args.has("quick"));
    let id = args.get_or("id", "all");
    if id == "all" {
        for id in ALL_IDS {
            match experiments::run(&ctx, id) {
                Ok(t) => println!("{}", t.print()),
                Err(e) => println!("[{id}] FAILED: {e:#}"),
            }
        }
    } else {
        println!("{}", experiments::run(&ctx, &id)?.print());
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    println!("methods (per-request routable via serve --method / Request.method):");
    for m in Method::all() {
        println!(
            "  {:<18} variant={:<8} ordering={:?}{}{}",
            m.name,
            m.variant,
            m.ordering,
            if m.rotate { " rotated" } else { "" },
            if m.clip < 1.0 { " clipped" } else { "" },
        );
    }
    let dir = artifacts_dir(args);
    let meta = match Meta::load(&dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("(artifacts/ not built — reporting the build-default shapes)");
            Meta::default_build()
        }
    };
    println!("model: {:?}", meta.model);
    println!("cache: {:?}", meta.cache);
    println!("variants:");
    for v in &meta.variants {
        println!(
            "  {:<8} key_bits={:.2} avg_bits={:.2} layers={:?}",
            v.name,
            v.key_bits,
            v.avg_bits,
            v.layers.iter().map(|l| (l.n16, l.n4, l.n2, l.v_bits)).collect::<Vec<_>>()
        );
    }
    // paged-pool geometry: what each method costs the shared page pool
    // (kvcache::pool). One page = one quantization group (G tokens) for one
    // (layer, kv-head); bytes are the deployment layout the accountant
    // charges. pages@C = pages a request leases with the window full.
    let cc = &meta.cache;
    let d = meta.model.d_head;
    let pages_at_c = (cc.capacity / cc.group) * meta.model.n_layers * meta.model.n_kv_heads;
    println!(
        "page pool (G={} tokens/page, {} layers x {} kv-heads):",
        cc.group, meta.model.n_layers, meta.model.n_kv_heads
    );
    for spec in MethodSpec::all() {
        let m = spec.build();
        let Ok(v) = meta.variant(&m.variant) else { continue };
        let bytes_per_page = v
            .layers
            .iter()
            .map(|&l| mixkvq::kvcache::pool::PageLayout::new(l, d, cc.group).deploy_bytes())
            .max()
            .unwrap_or(0);
        println!(
            "  {:<18} bytes/page={:<6} pages/request@C={} ({} KB resident at C)",
            m.name,
            bytes_per_page,
            pages_at_c,
            bytes_per_page * pages_at_c / 1024,
        );
    }
    // cross-request prefix sharing: what one retained prompt costs beyond
    // its (shared, charged-once) pool pages. Keyed by (method, R, G, C,
    // model geometry) x a G-token rolling hash chain over the full prompt;
    // K requests over one prompt hold ~1/K of private-mode prefix pages and
    // skip their prefill compute entirely.
    let mc = &meta.model;
    // residual K/V snapshot + last logits + per-head plans and |Q| state;
    // the retained prompt copy adds 4 B/token on top
    let heads = mc.n_layers * mc.n_kv_heads;
    let sidecar = 4 * mc.vocab
        + 2 * 4 * cc.residual * heads * d
        + 4 * d * heads // plans
        + 4 * (d + 1) * heads; // |Q| sums + count
    println!(
        "prefix sharing: key=(method,R,G,C) x {}-token hash chain; \
         <= {} KB + 4 B/prompt-token sidecar/entry (residual snapshot, \
         last logits, plans, |Q| state, prompt copy) on top of the shared \
         pages above",
        cc.group,
        sidecar / 1024,
    );
    // crash-safe serving: what a snapshot of one capacity-full request
    // costs per method. Serialized page = f32 arena + byte arena + length
    // prefixes + FNV checksum; arenas are host-layout (PageLayout), so the
    // estimate is exact per page and a floor per request (scalars, plans,
    // and metrics sections add a few KB per server on top).
    println!(
        "snapshot ABI: {} (schema v{}, per-page FNV-1a checksums, quarantine on mismatch)",
        String::from_utf8_lossy(mixkvq::util::snapshot::SNAP_MAGIC).trim_end(),
        mixkvq::util::snapshot::SNAP_VERSION,
    );
    for spec in MethodSpec::all() {
        let m = spec.build();
        let Ok(v) = meta.variant(&m.variant) else { continue };
        let page_snap_bytes = v
            .layers
            .iter()
            .map(|&l| {
                let lay = mixkvq::kvcache::pool::PageLayout::new(l, d, cc.group);
                lay.host_bytes() + 24 // two length prefixes + checksum
            })
            .max()
            .unwrap_or(0);
        println!(
            "  {:<18} snapshot bytes/page={:<6} ~{} KB/request@C",
            m.name,
            page_snap_bytes,
            page_snap_bytes * pages_at_c / 1024,
        );
    }
    Ok(())
}
