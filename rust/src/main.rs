//! MixKVQ CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve   --method <name> --requests N --max-new N --r-limit N --budget-mb N
//!   bench   --id <fig1|...|tab8|all> [--quick]
//!   demo    --id tab1            (error-accumulation transcript)
//!   search  [--quick]            (Fig. 7 Pareto threshold search)
//!   info                         (artifacts + variants + compile times)

use std::path::PathBuf;

use anyhow::{bail, Result};

use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::harness::experiments::{self, ExpCtx, ALL_IDS};
use mixkvq::harness::workloads;
use mixkvq::model::config::Meta;
use mixkvq::quant::methods::Method;
use mixkvq::util::cli::Args;
use mixkvq::util::rng::Pcg32;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand().unwrap_or("help") {
        "serve" => serve(&args),
        "bench" => bench(&args),
        "demo" => {
            let ctx = ExpCtx::new(&artifacts_dir(&args), args.has("quick"));
            let id = args.get_or("id", "tab1");
            println!("{}", experiments::run(&ctx, &id)?.print());
            Ok(())
        }
        "search" => {
            let ctx = ExpCtx::new(&artifacts_dir(&args), args.has("quick"));
            println!("{}", experiments::run(&ctx, "fig7")?.print());
            Ok(())
        }
        "info" => info(&args),
        _ => {
            println!(
                "mixkvq — query-aware mixed-precision KV cache quantization\n\n\
                 USAGE: mixkvq <serve|bench|demo|search|info> [options]\n\n\
                 serve   --method mixkvq-mix30 --requests 32 --max-new 48 --r-limit 128 --budget-mb 64\n\
                 bench   --id all|fig1|fig2|fig3|fig5|fig6|fig7|tab1..tab8 [--quick]\n\
                 demo    --id tab1\n\
                 search  [--quick]\n\
                 info\n\n\
                 Global: --artifacts <dir> (default: artifacts)"
            );
            Ok(())
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let method_name = args.get_or("method", "mixkvq-mix30");
    let Some(method) = Method::by_name(&method_name) else {
        bail!("unknown method `{method_name}` — see quant::methods::Method::by_name");
    };
    let n_requests = args.usize_or("requests", 32)?;
    let max_new = args.usize_or("max-new", 48)?;
    let r_limit = args.usize_or("r-limit", 128)?;
    let budget_mb = args.usize_or("budget-mb", 64)?;
    let seed = args.u64_or("seed", 0)?;

    eprintln!("loading engine ({method_name})...");
    let engine = Engine::new(&artifacts_dir(args), method, r_limit)?;
    let mut server = Server::new(
        engine,
        ServerConfig {
            memory_budget_bytes: budget_mb << 20,
            max_prefills_per_cycle: 2,
            seed,
        },
    );
    let mut rng = Pcg32::seeded(seed);
    let trace = workloads::sharegpt_trace(&mut rng, n_requests, max_new);
    eprintln!("serving {n_requests} requests (max_new={max_new}, R={r_limit})...");
    let completed = server.run(trace)?;
    println!("{}", server.metrics.summary());
    let b = mixkvq::coordinator::metrics::breakdown(&server.engine.timers);
    println!(
        "breakdown: model_exec {:.1}%  quantize {:.1}%  assemble {:.1}%  (quant events/step {:.1}%)",
        b.model_exec_pct, b.quantize_pct, b.assemble_pct, b.quantize_call_rate_pct
    );
    println!("completed {} requests", completed.len());
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let ctx = ExpCtx::new(&artifacts_dir(args), args.has("quick"));
    let id = args.get_or("id", "all");
    if id == "all" {
        for id in ALL_IDS {
            match experiments::run(&ctx, id) {
                Ok(t) => println!("{}", t.print()),
                Err(e) => println!("[{id}] FAILED: {e:#}"),
            }
        }
    } else {
        println!("{}", experiments::run(&ctx, &id)?.print());
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let meta = Meta::load(&dir)?;
    println!("model: {:?}", meta.model);
    println!("cache: {:?}", meta.cache);
    println!("variants:");
    for v in &meta.variants {
        println!(
            "  {:<8} key_bits={:.2} avg_bits={:.2} layers={:?}",
            v.name,
            v.key_bits,
            v.avg_bits,
            v.layers.iter().map(|l| (l.n16, l.n4, l.n2, l.v_bits)).collect::<Vec<_>>()
        );
    }
    Ok(())
}
