//! Synthetic-task vocabulary — mirrors python/compile/config.py exactly.

pub const VOCAB: usize = 128;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const EQ: i32 = 4;
pub const ARROW: i32 = 5;
pub const QMARK: i32 = 6;
pub const KEY: i32 = 7;
pub const VAL: i32 = 8;
pub const COPY: i32 = 9;
pub const OP_ADD: i32 = 10;
pub const OP_SUB: i32 = 11;
pub const OP_MUL: i32 = 12;
pub const NUM_BASE: i32 = 16;
pub const NUM_COUNT: i32 = 32;
pub const FILLER_BASE: i32 = 80;
pub const FILLER_COUNT: i32 = 48;

pub fn num_tok(v: i32) -> i32 {
    debug_assert!((0..NUM_COUNT).contains(&v));
    NUM_BASE + v
}

pub fn tok_num(t: i32) -> Option<i32> {
    if (NUM_BASE..NUM_BASE + NUM_COUNT).contains(&t) {
        Some(t - NUM_BASE)
    } else {
        None
    }
}

pub fn is_filler(t: i32) -> bool {
    (FILLER_BASE..FILLER_BASE + FILLER_COUNT).contains(&t)
}

/// Human-readable rendering for demos / Table-1-style transcripts.
pub fn render(tokens: &[i32]) -> String {
    let mut out = String::new();
    for &t in tokens {
        let s = match t {
            PAD => continue,
            BOS => "<bos>".to_string(),
            EOS => "<eos>".to_string(),
            SEP => ";".to_string(),
            EQ => "=".to_string(),
            ARROW => "->".to_string(),
            QMARK => "?".to_string(),
            KEY => "KEY".to_string(),
            VAL => "VAL".to_string(),
            COPY => "COPY".to_string(),
            OP_ADD => "+".to_string(),
            OP_SUB => "-".to_string(),
            OP_MUL => "*".to_string(),
            t if tok_num(t).is_some() => tok_num(t).unwrap().to_string(),
            t if is_filler(t) => {
                char::from(b'a' + ((t - FILLER_BASE) % 26) as u8).to_string()
            }
            t => format!("<{t}>"),
        };
        if !out.is_empty() {
            out.push(' ');
        }
        out.push_str(&s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_roundtrip() {
        for v in 0..NUM_COUNT {
            assert_eq!(tok_num(num_tok(v)), Some(v));
        }
        assert_eq!(tok_num(BOS), None);
    }

    #[test]
    fn render_chain() {
        let toks = vec![BOS, num_tok(3), OP_ADD, num_tok(4), EQ, num_tok(7), SEP, EOS];
        assert_eq!(render(&toks), "<bos> 3 + 4 = 7 ; <eos>");
    }

    #[test]
    fn vocab_ranges_disjoint() {
        assert!(NUM_BASE + NUM_COUNT <= FILLER_BASE);
        assert!(FILLER_BASE + FILLER_COUNT <= VOCAB as i32);
    }
}
