//! weights.bin loader — flat little-endian f32 in `param_spec` order, the
//! ABI shared with python/compile/model.py::param_spec.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::config::ModelConfig;

/// Canonical (name, shape) ordering — must mirror python param_spec().
pub fn param_spec(mc: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let (hq, hkv, dh) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head);
    let mut spec = vec![("embed".to_string(), vec![mc.vocab, mc.d_model])];
    for l in 0..mc.n_layers {
        spec.push((format!("l{l}.ln1"), vec![mc.d_model]));
        spec.push((format!("l{l}.wq"), vec![mc.d_model, hq * dh]));
        spec.push((format!("l{l}.wk"), vec![mc.d_model, hkv * dh]));
        spec.push((format!("l{l}.wv"), vec![mc.d_model, hkv * dh]));
        spec.push((format!("l{l}.wo"), vec![hq * dh, mc.d_model]));
        spec.push((format!("l{l}.ln2"), vec![mc.d_model]));
        spec.push((format!("l{l}.w1"), vec![mc.d_model, mc.d_ff]));
        spec.push((format!("l{l}.w2"), vec![mc.d_ff, mc.d_model]));
    }
    spec.push(("ln_f".to_string(), vec![mc.d_model]));
    spec
}

#[derive(Clone)]
pub struct Weights {
    /// Tensors in param_spec order (the positional HLO inputs).
    pub flat: Vec<Vec<f32>>,
    pub shapes: Vec<(String, Vec<usize>)>,
    index: HashMap<String, usize>,
}

impl Weights {
    pub fn load(artifacts_dir: &Path, mc: &ModelConfig) -> Result<Weights> {
        let path = artifacts_dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::from_bytes(&bytes, mc)
    }

    pub fn from_bytes(bytes: &[u8], mc: &ModelConfig) -> Result<Weights> {
        let spec = param_spec(mc);
        let total: usize = spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        if bytes.len() != total * 4 {
            bail!(
                "weights.bin is {} bytes, expected {} ({} f32 params)",
                bytes.len(),
                total * 4,
                total
            );
        }
        let mut flat = Vec::with_capacity(spec.len());
        let mut off = 0;
        for (_, shape) in &spec {
            let n: usize = shape.iter().product();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            flat.push(v);
        }
        let index = spec
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        Ok(Weights { flat, shapes: spec, index })
    }

    /// Random-init weights (tests without artifacts); matches the python
    /// init distributionally, not bit-for-bit.
    pub fn random(mc: &ModelConfig, seed: u64) -> Weights {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(seed);
        let spec = param_spec(mc);
        let mut flat = Vec::new();
        for (name, shape) in &spec {
            let n: usize = shape.iter().product();
            if name.ends_with("ln1") || name.ends_with("ln2") || name == "ln_f" {
                flat.push(vec![1.0; n]);
            } else {
                let std = (shape[0] as f32).powf(-0.5);
                flat.push((0..n).map(|_| rng.normal() * std).collect());
            }
        }
        let index = spec
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        Weights { flat, shapes: spec, index }
    }

    pub fn get(&self, name: &str) -> &[f32] {
        &self.flat[self.index[name]]
    }

    pub fn n_params(&self) -> usize {
        self.flat.iter().map(|v| v.len()).sum()
    }

    /// Resolve a name to its `flat` position (for [`ParamIndex`]).
    pub fn position(&self, name: &str) -> usize {
        self.index[name]
    }
}

/// One layer's tensor positions in `Weights::flat`.
#[derive(Clone, Copy, Debug)]
pub struct LayerParams {
    pub ln1: usize,
    pub wq: usize,
    pub wk: usize,
    pub wv: usize,
    pub wo: usize,
    pub ln2: usize,
    pub w1: usize,
    pub w2: usize,
}

/// Name→position index resolved once per model: hot decode paths index
/// `Weights::flat` directly instead of hashing a `format!`-ed name per
/// tensor per step (which also allocates — the fused decode path must not).
#[derive(Clone, Debug)]
pub struct ParamIndex {
    pub embed: usize,
    pub ln_f: usize,
    pub layers: Vec<LayerParams>,
}

impl ParamIndex {
    pub fn new(w: &Weights, mc: &ModelConfig) -> ParamIndex {
        let layers = (0..mc.n_layers)
            .map(|l| LayerParams {
                ln1: w.position(&format!("l{l}.ln1")),
                wq: w.position(&format!("l{l}.wq")),
                wk: w.position(&format!("l{l}.wk")),
                wv: w.position(&format!("l{l}.wv")),
                wo: w.position(&format!("l{l}.wo")),
                ln2: w.position(&format!("l{l}.ln2")),
                w1: w.position(&format!("l{l}.w1")),
                w2: w.position(&format!("l{l}.w2")),
            })
            .collect();
        ParamIndex {
            embed: w.position("embed"),
            ln_f: w.position("ln_f"),
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_matches_python_ordering() {
        let mc = ModelConfig::default_build();
        let spec = param_spec(&mc);
        assert_eq!(spec[0].0, "embed");
        assert_eq!(spec[1].0, "l0.ln1");
        assert_eq!(spec[2].0, "l0.wq");
        assert_eq!(spec.last().unwrap().0, "ln_f");
        assert_eq!(spec.len(), 2 + 8 * mc.n_layers);
    }

    #[test]
    fn from_bytes_roundtrip() {
        let mc = ModelConfig::default_build();
        let w = Weights::random(&mc, 9);
        let mut bytes = Vec::new();
        for t in &w.flat {
            for x in t {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        let w2 = Weights::from_bytes(&bytes, &mc).unwrap();
        assert_eq!(w.flat, w2.flat);
        assert_eq!(w.n_params(), w2.n_params());
    }

    #[test]
    fn rejects_wrong_size() {
        let mc = ModelConfig::default_build();
        assert!(Weights::from_bytes(&[0u8; 16], &mc).is_err());
    }

    #[test]
    fn param_index_agrees_with_named_lookup() {
        let mc = ModelConfig::default_build();
        let w = Weights::random(&mc, 2);
        let idx = ParamIndex::new(&w, &mc);
        assert_eq!(w.flat[idx.embed].as_slice(), w.get("embed"));
        assert_eq!(w.flat[idx.ln_f].as_slice(), w.get("ln_f"));
        assert_eq!(idx.layers.len(), mc.n_layers);
        for l in 0..mc.n_layers {
            assert_eq!(w.flat[idx.layers[l].wq].as_slice(), w.get(&format!("l{l}.wq")));
            assert_eq!(w.flat[idx.layers[l].w2].as_slice(), w.get(&format!("l{l}.w2")));
        }
    }

    #[test]
    fn named_lookup() {
        let mc = ModelConfig::default_build();
        let w = Weights::random(&mc, 1);
        assert_eq!(w.get("embed").len(), mc.vocab * mc.d_model);
        assert_eq!(w.get("l2.w1").len(), mc.d_model * mc.d_ff);
        assert!(w.get("ln_f").iter().all(|&x| x == 1.0));
    }
}
