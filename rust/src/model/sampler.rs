//! Token sampling: greedy + temperature/top-p (the paper evaluates with
//! temperature 0.6, top-p 0.95; our accuracy harnesses default to greedy so
//! runs are deterministic, matching pass@1 with a single sample).

use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    TopP { temperature: f32, top_p: f32 },
}

pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Pcg32) -> i32 {
    match mode {
        Sampling::Greedy => argmax(logits),
        Sampling::TopP { temperature, top_p } => top_p_sample(logits, temperature, top_p, rng),
    }
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

fn top_p_sample(logits: &[f32], temperature: f32, top_p: f32, rng: &mut Pcg32) -> i32 {
    let t = temperature.max(1e-4);
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut probs: Vec<(usize, f32)> = logits
        .iter()
        .enumerate()
        .map(|(i, &v)| (i, ((v - max) / t).exp()))
        .collect();
    let z: f32 = probs.iter().map(|(_, p)| p).sum();
    for p in probs.iter_mut() {
        p.1 /= z;
    }
    probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut cum = 0.0;
    let mut cut = probs.len();
    for (i, (_, p)) in probs.iter().enumerate() {
        cum += p;
        if cum >= top_p {
            cut = i + 1;
            break;
        }
    }
    let kept = &probs[..cut];
    let zk: f32 = kept.iter().map(|(_, p)| p).sum();
    let mut r = rng.f32() * zk;
    for (i, p) in kept {
        r -= p;
        if r <= 0.0 {
            return *i as i32;
        }
    }
    kept.last().unwrap().0 as i32
}

/// log-softmax probability of `target` — the perplexity building block.
pub fn log_prob(logits: &[f32], target: i32) -> f64 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let lse: f64 = logits.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
    logits[target as usize] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(argmax(&logits), 1);
    }

    #[test]
    fn top_p_1_temperature_low_is_greedy() {
        let logits = vec![0.0, 10.0, 0.0];
        let mut rng = Pcg32::seeded(1);
        for _ in 0..20 {
            let s = sample(&logits, Sampling::TopP { temperature: 0.01, top_p: 1.0 }, &mut rng);
            assert_eq!(s, 1);
        }
    }

    #[test]
    fn top_p_filters_tail() {
        // with top_p tiny, only the argmax can be drawn
        let logits = vec![1.0, 5.0, 1.2, 0.3];
        let mut rng = Pcg32::seeded(2);
        for _ in 0..50 {
            let s = sample(&logits, Sampling::TopP { temperature: 0.6, top_p: 0.05 }, &mut rng);
            assert_eq!(s, 1);
        }
    }

    #[test]
    fn log_prob_normalized() {
        let logits = vec![0.5f32, -0.2, 1.5, 0.0];
        let total: f64 = (0..4).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
