//! Model/cache/variant configuration, deserialized from artifacts/meta.json
//! (written by python/compile/aot.py — the single source of shape truth).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::window::TierSpec;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub rope_theta: f32,
    pub max_position: usize,
    pub rmsnorm_eps: f32,
}

impl ModelConfig {
    pub fn q_per_kv(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    /// The build-time default — must match python/compile/config.py. Used
    /// by unit tests that run without artifacts.
    pub fn default_build() -> Self {
        ModelConfig {
            vocab: 128,
            d_model: 128,
            n_layers: 4,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 32,
            d_ff: 256,
            rope_theta: 10000.0,
            max_position: 704,
            rmsnorm_eps: 1e-5,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    pub capacity: usize,
    pub residual: usize,
    pub group: usize,
    pub decode_batch: usize,
    pub prefill_buckets: Vec<usize>,
}

impl CacheConfig {
    pub fn default_build() -> Self {
        CacheConfig {
            capacity: 512,
            residual: 128,
            group: 32,
            decode_batch: 8,
            prefill_buckets: vec![128, 512],
        }
    }

    /// Max sequence positions a request can occupy (quantized + residual + 1).
    pub fn max_context(&self) -> usize {
        self.capacity + self.residual
    }
}

#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub layers: Vec<TierSpec>,
    pub key_bits: f64,
    pub avg_bits: f64,
}

#[derive(Clone, Debug)]
pub struct Meta {
    pub model: ModelConfig,
    pub cache: CacheConfig,
    pub variants: Vec<VariantSpec>,
}

impl Meta {
    pub fn load(artifacts_dir: &Path) -> Result<Meta> {
        let path = artifacts_dir.join("meta.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Meta> {
        let j = Json::parse(src)?;
        let m = j.get("model")?;
        let model = ModelConfig {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_q_heads: m.get("n_q_heads")?.as_usize()?,
            n_kv_heads: m.get("n_kv_heads")?.as_usize()?,
            d_head: m.get("d_head")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            rope_theta: m.get("rope_theta")?.as_f64()? as f32,
            max_position: m.get("max_position")?.as_usize()?,
            rmsnorm_eps: m.get("rmsnorm_eps")?.as_f64()? as f32,
        };
        let c = j.get("cache")?;
        let cache = CacheConfig {
            capacity: c.get("capacity")?.as_usize()?,
            residual: c.get("residual")?.as_usize()?,
            group: c.get("group")?.as_usize()?,
            decode_batch: c.get("decode_batch")?.as_usize()?,
            prefill_buckets: c
                .get("prefill_buckets")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
        };
        let mut variants = Vec::new();
        for v in j.get("variants")?.as_arr()? {
            let mut layers = Vec::new();
            for layer in v.get("layers")?.as_arr()? {
                let l = layer.as_arr()?;
                if l.len() != 4 {
                    bail!("bad tier tuple");
                }
                layers.push(TierSpec {
                    n16: l[0].as_usize()?,
                    n4: l[1].as_usize()?,
                    n2: l[2].as_usize()?,
                    v_bits: l[3].as_usize()?,
                });
            }
            variants.push(VariantSpec {
                name: v.get("name")?.as_str()?.to_string(),
                layers,
                key_bits: v.get("key_bits")?.as_f64()?,
                avg_bits: v.get("avg_bits")?.as_f64()?,
            });
        }
        Ok(Meta { model, cache, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant `{name}`"))
    }

    /// Synthetic Meta matching the build defaults (tests without artifacts).
    pub fn default_build() -> Meta {
        let model = ModelConfig::default_build();
        let d = model.d_head;
        let uni = |name: &str, n16: usize, n4: usize, n2: usize, vb: usize| VariantSpec {
            name: name.to_string(),
            layers: vec![TierSpec { n16, n4, n2, v_bits: vb }; model.n_layers],
            key_bits: crate::quant::salience::effective_key_bits(n16, n4, n2),
            avg_bits: (crate::quant::salience::effective_key_bits(n16, n4, n2) + vb as f64) / 2.0,
        };
        let mut variants = vec![
            uni("bf16", d, 0, 0, 16),
            uni("kv4", 0, d, 0, 4),
            uni("kv2", 0, 0, d, 2),
            uni("k4v2", 0, d, 0, 2),
            uni("k2v4", 0, 0, d, 4),
            uni("mix225", 0, 4, 28, 2),
            uni("mix30", 2, 2, 28, 2),
            uni("mix325", 2, 6, 24, 2),
        ];
        let kv4 = TierSpec { n16: 0, n4: d, n2: 0, v_bits: 4 };
        let kv2 = TierSpec { n16: 0, n4: 0, n2: d, v_bits: 2 };
        variants.push(VariantSpec {
            name: "kvtuner".into(),
            layers: vec![kv4, kv2, kv2, kv4],
            key_bits: 3.0,
            avg_bits: 3.0,
        });
        Meta { model, cache: CacheConfig::default_build(), variants }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_build_shaped_meta() {
        let src = r#"{
          "model": {"vocab":128,"d_model":128,"n_layers":2,"n_q_heads":4,
                    "n_kv_heads":2,"d_head":32,"d_ff":256,"rope_theta":10000.0,
                    "max_position":704,"rmsnorm_eps":1e-05},
          "cache": {"capacity":512,"residual":128,"group":32,"decode_batch":8,
                    "prefill_buckets":[128,512]},
          "variants": [{"name":"mix30","layers":[[2,2,28,2],[2,2,28,2]],
                        "key_bits":3.0,"avg_bits":2.5}]
        }"#;
        let meta = Meta::parse(src).unwrap();
        assert_eq!(meta.model.n_layers, 2);
        assert_eq!(meta.cache.max_context(), 640);
        let v = meta.variant("mix30").unwrap();
        assert_eq!(v.layers[0].n2, 28);
        assert!(meta.variant("nope").is_err());
    }

    #[test]
    fn default_build_has_all_variants() {
        let meta = Meta::default_build();
        for name in ["bf16", "kv4", "kv2", "k4v2", "k2v4", "mix225", "mix30", "mix325", "kvtuner"] {
            assert!(meta.variant(name).is_ok(), "{name}");
        }
        assert_eq!(meta.variant("kvtuner").unwrap().layers[1].v_bits, 2);
    }
}
