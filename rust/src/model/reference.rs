//! Pure-Rust MiniReasoner — the f32 oracle mirroring python/compile/model.py.
//!
//! Three uses:
//! * invariant #8 (DESIGN.md): the HLO executables must agree with this
//!   implementation to ~1e-4 (tests/integration.rs);
//! * the *flexible* experiment path: analyses that sweep tier counts or
//!   thresholds beyond the compiled HLO variants (Figs. 6/7, Table 5/6
//!   sweeps) run here, where shapes are not baked into a graph;
//! * the **production prefill path**: [`PrefillRun`] is a chunked,
//!   GEMM-blocked, direct-to-cache prefill pipeline ([`matmul_blocked`] +
//!   [`PrefillScratch`]) that quantizes each layer's K/V straight into
//!   `RequestCache` pool pages as it is produced and projects logits for
//!   the **last position only**. [`RefModel::forward_full`] survives as the
//!   numerical oracle it is property-tested against
//!   (tests/blocked_prefill.rs), mirroring the PR 2 fused-vs-legacy decode
//!   pattern.
//!
//! Numerics deliberately match jax: RMSNorm, half-rotation RoPE, tanh-GELU
//! (jax.nn.gelu approximate=True), softmax with max-subtraction. The
//! chunked prefill reassociates attention reductions ([`dot_lanes`]) — it
//! agrees with the sequential oracle to float-reassociation tolerance, not
//! bit-for-bit.

use anyhow::{bail, Result};

use super::config::ModelConfig;
use super::weights::{ParamIndex, Weights};
use crate::kvcache::cache::{HeadState, RequestCache};

pub struct RefModel<'a> {
    pub mc: ModelConfig,
    pub w: &'a Weights,
    /// Name→flat-position index resolved once (no format!/hash per step).
    pub pidx: ParamIndex,
    /// RoPE inverse-frequency table precomputed once per ModelConfig
    /// (zero `powf` calls on the decode hot path).
    pub rope: RopeTable,
}

/// Precomputed RoPE inverse frequencies: `inv_freq[i] = θ^(−i/half)`.
/// `apply` is bit-identical to [`apply_rope`], which recomputes the powf
/// per channel per call.
#[derive(Clone, Debug)]
pub struct RopeTable {
    pub inv_freq: Vec<f32>,
}

impl RopeTable {
    pub fn new(d_head: usize, theta: f32) -> RopeTable {
        let half = d_head / 2;
        RopeTable {
            inv_freq: (0..half).map(|i| theta.powf(-(i as f32) / half as f32)).collect(),
        }
    }

    /// Half-rotation RoPE in place over one head vector of length
    /// `2 * inv_freq.len()`.
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        let half = self.inv_freq.len();
        debug_assert_eq!(x.len(), 2 * half);
        for i in 0..half {
            let ang = pos as f32 * self.inv_freq[i];
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (x[i], x[i + half]);
            x[i] = a * cos - b * sin;
            x[i + half] = b * cos + a * sin;
        }
    }
}

/// Full-precision K/V/|Q| for one prompt: `k[l]`/`v[l]` are [Hkv, T, dh]
/// row-major, `qabs[l]` is [Hkv, dh] (mean |q| over positions, grouped).
pub struct PrefillOut {
    pub last_logits: Vec<f32>,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub qabs: Vec<Vec<f32>>,
}

/// Per-layer attention context for a reference decode step. The quantized
/// window arrives *already dequantized* (in rotated space); the residual
/// window is raw f32 (unrotated) — exactly the HLO decode semantics.
pub struct LayerCtx<'a> {
    /// [Hkv, tq, dh] dequantized quantized-window keys, rotated space.
    pub kq: &'a [f32],
    /// [Hkv, tq, dh] dequantized values.
    pub vq: &'a [f32],
    pub tq: usize,
    /// [Hkv, tr, dh] residual keys (unrotated, post-RoPE).
    pub kres: &'a [f32],
    pub vres: &'a [f32],
    pub tr: usize,
}

pub struct DecodeOut {
    pub logits: Vec<f32>,
    /// [L][Hkv*dh] post-RoPE key/value of the new token.
    pub knew: Vec<Vec<f32>>,
    pub vnew: Vec<Vec<f32>>,
    /// [L][Hkv*dh] mean |q| over the head group (I_d observation).
    pub qabs: Vec<Vec<f32>>,
}

/// Per-layer attention context for the *fused* decode path: borrows the
/// cache's packed tier buffers (and the head-local residual) directly —
/// nothing is dequantized or copied.
pub struct QuantLayerCtx<'a> {
    /// One [`HeadState`] per kv-head, packed buffers + residual + `idx`.
    pub heads: &'a [HeadState],
    /// Quantized-window length (tokens).
    pub tq: usize,
    /// Residual length (tokens).
    pub tr: usize,
}

/// Reusable decode-step arena: every intermediate of
/// [`RefModel::decode_step_into`] lives here, allocated once per request
/// (or driver) and reused every step — steady-state decode performs zero
/// heap allocations.
pub struct DecodeScratch {
    pub h: Vec<f32>,       // [d_model] residual stream
    pub x: Vec<f32>,       // [d_model] rmsnorm output
    pub q: Vec<f32>,       // [Hq*dh]
    pub k: Vec<f32>,       // [Hkv*dh]
    pub v: Vec<f32>,       // [Hkv*dh]
    pub qrot: Vec<f32>,    // [dh] rotated query head
    pub qperm: Vec<f32>,   // [dh] rotated query permuted into tier order
    pub w4: Vec<f32>,      // [dh] per-group folded u4 weights (q ⊙ s)
    pub w2: Vec<f32>,      // [dh] per-group folded u2 weights
    pub o: Vec<f32>,       // [Hq*dh] attention output
    pub proj: Vec<f32>,    // [d_model]
    pub ff: Vec<f32>,      // [d_ff]
    pub scores: Vec<f32>,  // [max context] attention scores
    pub logits: Vec<f32>,  // [vocab]
    pub knew: Vec<Vec<f32>>, // [L][Hkv*dh]
    pub vnew: Vec<Vec<f32>>,
    pub qabs: Vec<Vec<f32>>,
}

impl DecodeScratch {
    /// `max_scores` must cover the longest attention span this scratch will
    /// see (quantized capacity + residual capacity + 1 for self).
    pub fn new(mc: &ModelConfig, max_scores: usize) -> DecodeScratch {
        let (hq, hkv, dh) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head);
        let per_layer = || (0..mc.n_layers).map(|_| vec![0f32; hkv * dh]).collect();
        DecodeScratch {
            h: vec![0.0; mc.d_model],
            x: vec![0.0; mc.d_model],
            q: vec![0.0; hq * dh],
            k: vec![0.0; hkv * dh],
            v: vec![0.0; hkv * dh],
            qrot: vec![0.0; dh],
            qperm: vec![0.0; dh],
            w4: vec![0.0; dh],
            w2: vec![0.0; dh],
            o: vec![0.0; hq * dh],
            proj: vec![0.0; mc.d_model],
            ff: vec![0.0; mc.d_ff],
            scores: vec![0.0; max_scores],
            logits: vec![0.0; mc.vocab],
            knew: per_layer(),
            vnew: per_layer(),
            qabs: per_layer(),
        }
    }
}

pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// y = x · W for row-major W [n, m], blocked 4 input rows at a time so each
/// `out` element is read/written once per block instead of once per row.
/// The per-element summation order matches the row-at-a-time form.
pub fn matvec(x: &[f32], w: &[f32], n: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(w.len(), n * m);
    let out = &mut out[..m];
    out.fill(0.0);
    let mut i = 0;
    while i + 4 <= n {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        let r0 = &w[i * m..(i + 1) * m];
        let r1 = &w[(i + 1) * m..(i + 2) * m];
        let r2 = &w[(i + 2) * m..(i + 3) * m];
        let r3 = &w[(i + 3) * m..(i + 4) * m];
        for j in 0..m {
            out[j] = out[j] + x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
        }
        i += 4;
    }
    while i < n {
        let xi = x[i];
        let row = &w[i * m..(i + 1) * m];
        for j in 0..m {
            out[j] += xi * row[j];
        }
        i += 1;
    }
}

/// Multi-accumulator dot product: eight independent partial sums break the
/// sequential-add dependency chain of a naive `zip().sum::<f32>()` (which
/// the compiler must keep latency-bound — f32 addition is not
/// reassociable), so the loop vectorizes. Used by the chunked-prefill
/// attention scores and the last-logit projection. Reassociates the
/// reduction: results differ from the sequential sum by float-reassociation
/// noise, covered by the ≤1e-4 oracle tolerance.
#[inline]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        for u in 0..8 {
            acc[u] += xs[u] * ys[u];
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    tail + ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// Y = X · W for row-major X [t, n], W [n, m]: the chunked-prefill GEMM,
/// blocked 4 tokens × 4 weight rows so each weight row is streamed once per
/// 4-token tile (the per-token [`matvec`] streams every weight matrix once
/// *per token* — the dominant prefill cost this tiling removes) and the
/// inner loop carries 16 independent FMA chains. The per-element summation
/// order matches [`matvec`] exactly (ascending 4-row blocks, then the
/// remainder), so a blocked QKV projection is bit-identical to the
/// per-token oracle; remainder tokens fall back to [`matvec`] itself.
pub fn matmul_blocked(x: &[f32], t: usize, w: &[f32], n: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), t * n);
    debug_assert_eq!(w.len(), n * m);
    let out = &mut out[..t * m];
    let mut tok = 0;
    while tok + 4 <= t {
        let (x0, rest) = x[tok * n..(tok + 4) * n].split_at(n);
        let (x1, rest) = rest.split_at(n);
        let (x2, x3) = rest.split_at(n);
        let block = &mut out[tok * m..(tok + 4) * m];
        let (o0, rest) = block.split_at_mut(m);
        let (o1, rest) = rest.split_at_mut(m);
        let (o2, o3) = rest.split_at_mut(m);
        o0.fill(0.0);
        o1.fill(0.0);
        o2.fill(0.0);
        o3.fill(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let r0 = &w[i * m..(i + 1) * m];
            let r1 = &w[(i + 1) * m..(i + 2) * m];
            let r2 = &w[(i + 2) * m..(i + 3) * m];
            let r3 = &w[(i + 3) * m..(i + 4) * m];
            let (a0, a1, a2, a3) = (x0[i], x0[i + 1], x0[i + 2], x0[i + 3]);
            let (b0, b1, b2, b3) = (x1[i], x1[i + 1], x1[i + 2], x1[i + 3]);
            let (c0, c1, c2, c3) = (x2[i], x2[i + 1], x2[i + 2], x2[i + 3]);
            let (d0, d1, d2, d3) = (x3[i], x3[i + 1], x3[i + 2], x3[i + 3]);
            for j in 0..m {
                let (w0, w1, w2, w3) = (r0[j], r1[j], r2[j], r3[j]);
                o0[j] = o0[j] + a0 * w0 + a1 * w1 + a2 * w2 + a3 * w3;
                o1[j] = o1[j] + b0 * w0 + b1 * w1 + b2 * w2 + b3 * w3;
                o2[j] = o2[j] + c0 * w0 + c1 * w1 + c2 * w2 + c3 * w3;
                o3[j] = o3[j] + d0 * w0 + d1 * w1 + d2 * w2 + d3 * w3;
            }
            i += 4;
        }
        while i < n {
            let row = &w[i * m..(i + 1) * m];
            let (a, b, c, d) = (x0[i], x1[i], x2[i], x3[i]);
            for j in 0..m {
                let r = row[j];
                o0[j] += a * r;
                o1[j] += b * r;
                o2[j] += c * r;
                o3[j] += d * r;
            }
            i += 1;
        }
        tok += 4;
    }
    while tok < t {
        matvec(&x[tok * n..(tok + 1) * n], w, n, m, &mut out[tok * m..(tok + 1) * m]);
        tok += 1;
    }
}

/// jax.nn.gelu(approximate=True): 0.5x(1+tanh(√(2/π)(x+0.044715x³))).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Half-rotation RoPE in place over one head vector.
pub fn apply_rope(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = b * cos + a * sin;
    }
}

pub fn softmax_inplace(s: &mut [f32]) {
    let max = s.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0;
    for v in s.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in s.iter_mut() {
        *v *= inv;
    }
}

impl<'a> RefModel<'a> {
    pub fn new(mc: ModelConfig, w: &'a Weights) -> Self {
        let pidx = ParamIndex::new(w, &mc);
        let rope = RopeTable::new(mc.d_head, mc.rope_theta);
        RefModel { mc, w, pidx, rope }
    }

    /// Assemble from prebuilt lookup parts: callers that construct a
    /// transient `RefModel` on a hot path (the engine's per-tick
    /// chunked-prefill advance) cache the [`ParamIndex`]/[`RopeTable`]
    /// once and skip the per-call name resolution `new` performs.
    pub fn with_parts(mc: ModelConfig, w: &'a Weights, pidx: ParamIndex, rope: RopeTable) -> Self {
        RefModel { mc, w, pidx, rope }
    }

    /// Causal full-precision forward; returns logits [T, V] (teacher-forced
    /// scoring) plus per-layer K/V/|Q| (prefill products).
    pub fn forward_full(&self, tokens: &[i32]) -> (Vec<f32>, PrefillOut) {
        let mc = &self.mc;
        let (t, d) = (tokens.len(), mc.d_model);
        let (hq, hkv, dh, qpk) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv());
        let embed = self.w.get("embed");
        let mut h = vec![0f32; t * d];
        for (i, &tok) in tokens.iter().enumerate() {
            h[i * d..(i + 1) * d].copy_from_slice(&embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let mut qabss = Vec::new();
        let mut x = vec![0f32; d];
        let scale = 1.0 / (dh as f32).sqrt();
        for l in 0..mc.n_layers {
            let lw = self.pidx.layers[l];
            let (wq, wk, wv, wo) = (
                self.w.flat[lw.wq].as_slice(),
                self.w.flat[lw.wk].as_slice(),
                self.w.flat[lw.wv].as_slice(),
                self.w.flat[lw.wo].as_slice(),
            );
            let mut q_all = vec![0f32; t * hq * dh];
            let mut k_all = vec![0f32; t * hkv * dh];
            let mut v_all = vec![0f32; t * hkv * dh];
            for tok in 0..t {
                rmsnorm(&h[tok * d..(tok + 1) * d], &self.w.flat[lw.ln1], mc.rmsnorm_eps, &mut x);
                matvec(&x, wq, d, hq * dh, &mut q_all[tok * hq * dh..(tok + 1) * hq * dh]);
                matvec(&x, wk, d, hkv * dh, &mut k_all[tok * hkv * dh..(tok + 1) * hkv * dh]);
                matvec(&x, wv, d, hkv * dh, &mut v_all[tok * hkv * dh..(tok + 1) * hkv * dh]);
                for hh in 0..hq {
                    self.rope.apply(&mut q_all[tok * hq * dh + hh * dh..tok * hq * dh + (hh + 1) * dh], tok);
                }
                for hh in 0..hkv {
                    self.rope.apply(&mut k_all[tok * hkv * dh + hh * dh..tok * hkv * dh + (hh + 1) * dh], tok);
                }
            }
            // attention, causal
            let mut scores = vec![0f32; t];
            for tok in 0..t {
                let mut o = vec![0f32; hq * dh];
                for hh in 0..hq {
                    let kvh = hh / qpk;
                    let q = &q_all[tok * hq * dh + hh * dh..tok * hq * dh + (hh + 1) * dh];
                    for s in 0..=tok {
                        let k = &k_all[s * hkv * dh + kvh * dh..s * hkv * dh + (kvh + 1) * dh];
                        scores[s] = q.iter().zip(k).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    softmax_inplace(&mut scores[..=tok]);
                    for s in 0..=tok {
                        let v = &v_all[s * hkv * dh + kvh * dh..s * hkv * dh + (kvh + 1) * dh];
                        let p = scores[s];
                        for j in 0..dh {
                            o[hh * dh + j] += p * v[j];
                        }
                    }
                }
                let mut proj = vec![0f32; d];
                matvec(&o, wo, hq * dh, d, &mut proj);
                for j in 0..d {
                    h[tok * d + j] += proj[j];
                }
            }
            // MLP
            let (w1, w2) = (self.w.flat[lw.w1].as_slice(), self.w.flat[lw.w2].as_slice());
            let mut ff = vec![0f32; mc.d_ff];
            let mut proj = vec![0f32; d];
            for tok in 0..t {
                rmsnorm(&h[tok * d..(tok + 1) * d], &self.w.flat[lw.ln2], mc.rmsnorm_eps, &mut x);
                matvec(&x, w1, d, mc.d_ff, &mut ff);
                for f in ff.iter_mut() {
                    *f = gelu(*f);
                }
                matvec(&ff, w2, mc.d_ff, d, &mut proj);
                for j in 0..d {
                    h[tok * d + j] += proj[j];
                }
            }
            // stash K/V in [Hkv, T, dh] layout + grouped |Q| means
            let mut kl = vec![0f32; hkv * t * dh];
            let mut vl = vec![0f32; hkv * t * dh];
            for s in 0..t {
                for hh in 0..hkv {
                    kl[hh * t * dh + s * dh..hh * t * dh + (s + 1) * dh]
                        .copy_from_slice(&k_all[s * hkv * dh + hh * dh..s * hkv * dh + (hh + 1) * dh]);
                    vl[hh * t * dh + s * dh..hh * t * dh + (s + 1) * dh]
                        .copy_from_slice(&v_all[s * hkv * dh + hh * dh..s * hkv * dh + (hh + 1) * dh]);
                }
            }
            let mut qa = vec![0f32; hkv * dh];
            for s in 0..t {
                for hh in 0..hq {
                    let kvh = hh / qpk;
                    for j in 0..dh {
                        qa[kvh * dh + j] += q_all[s * hq * dh + hh * dh + j].abs();
                    }
                }
            }
            for v in qa.iter_mut() {
                *v /= (t * qpk) as f32;
            }
            ks.push(kl);
            vs.push(vl);
            qabss.push(qa);
        }
        // final norm + logits
        let mut logits = vec![0f32; t * mc.vocab];
        for tok in 0..t {
            rmsnorm(&h[tok * d..(tok + 1) * d], self.w.get("ln_f"), mc.rmsnorm_eps, &mut x);
            for v in 0..mc.vocab {
                logits[tok * mc.vocab + v] =
                    x.iter().zip(&embed[v * d..(v + 1) * d]).map(|(a, b)| a * b).sum();
            }
        }
        let last = logits[(t - 1) * mc.vocab..t * mc.vocab].to_vec();
        (
            logits,
            PrefillOut { last_logits: last, k: ks, v: vs, qabs: qabss },
        )
    }

    /// Single-token decode over (dequantized quantized window + residual +
    /// self), mirroring the HLO decode graph. `rot` is row-major [dh, dh].
    pub fn decode_step(&self, token: i32, pos: usize, ctx: &[LayerCtx], rot: &[f32]) -> DecodeOut {
        let mc = &self.mc;
        let d = mc.d_model;
        let (hq, hkv, dh, qpk) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv());
        let embed = self.w.get("embed");
        let mut h = embed[token as usize * d..(token as usize + 1) * d].to_vec();
        let mut x = vec![0f32; d];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut knews = Vec::new();
        let mut vnews = Vec::new();
        let mut qabss = Vec::new();
        for l in 0..mc.n_layers {
            let c = &ctx[l];
            let lw = self.pidx.layers[l];
            rmsnorm(&h, &self.w.flat[lw.ln1], mc.rmsnorm_eps, &mut x);
            let mut q = vec![0f32; hq * dh];
            let mut k = vec![0f32; hkv * dh];
            let mut v = vec![0f32; hkv * dh];
            matvec(&x, &self.w.flat[lw.wq], d, hq * dh, &mut q);
            matvec(&x, &self.w.flat[lw.wk], d, hkv * dh, &mut k);
            matvec(&x, &self.w.flat[lw.wv], d, hkv * dh, &mut v);
            for hh in 0..hq {
                self.rope.apply(&mut q[hh * dh..(hh + 1) * dh], pos);
            }
            for hh in 0..hkv {
                self.rope.apply(&mut k[hh * dh..(hh + 1) * dh], pos);
            }
            let mut qa = vec![0f32; hkv * dh];
            for hh in 0..hq {
                for j in 0..dh {
                    qa[(hh / qpk) * dh + j] += q[hh * dh + j].abs();
                }
            }
            for a in qa.iter_mut() {
                *a /= qpk as f32;
            }
            let mut o = vec![0f32; hq * dh];
            let n_scores = c.tq + c.tr + 1;
            let mut s = vec![0f32; n_scores];
            let mut qrot = vec![0f32; dh];
            for hh in 0..hq {
                let kvh = hh / qpk;
                let qh = &q[hh * dh..(hh + 1) * dh];
                crate::quant::rotation::rotate_vec(qh, rot, &mut qrot);
                for t in 0..c.tq {
                    let kk = &c.kq[kvh * c.tq * dh + t * dh..kvh * c.tq * dh + (t + 1) * dh];
                    s[t] = qrot.iter().zip(kk).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                for t in 0..c.tr {
                    let kk = &c.kres[kvh * c.tr * dh + t * dh..kvh * c.tr * dh + (t + 1) * dh];
                    s[c.tq + t] = qh.iter().zip(kk).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                let kk = &k[kvh * dh..(kvh + 1) * dh];
                s[c.tq + c.tr] = qh.iter().zip(kk).map(|(a, b)| a * b).sum::<f32>() * scale;
                softmax_inplace(&mut s);
                let oh = &mut o[hh * dh..(hh + 1) * dh];
                for t in 0..c.tq {
                    let vv = &c.vq[kvh * c.tq * dh + t * dh..kvh * c.tq * dh + (t + 1) * dh];
                    let p = s[t];
                    for j in 0..dh {
                        oh[j] += p * vv[j];
                    }
                }
                for t in 0..c.tr {
                    let vv = &c.vres[kvh * c.tr * dh + t * dh..kvh * c.tr * dh + (t + 1) * dh];
                    let p = s[c.tq + t];
                    for j in 0..dh {
                        oh[j] += p * vv[j];
                    }
                }
                let p = s[c.tq + c.tr];
                for j in 0..dh {
                    oh[j] += p * v[kvh * dh + j];
                }
            }
            let mut proj = vec![0f32; d];
            matvec(&o, &self.w.flat[lw.wo], hq * dh, d, &mut proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            rmsnorm(&h, &self.w.flat[lw.ln2], mc.rmsnorm_eps, &mut x);
            let mut ff = vec![0f32; mc.d_ff];
            matvec(&x, &self.w.flat[lw.w1], d, mc.d_ff, &mut ff);
            for f in ff.iter_mut() {
                *f = gelu(*f);
            }
            matvec(&ff, &self.w.flat[lw.w2], mc.d_ff, d, &mut proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            knews.push(k);
            vnews.push(v);
            qabss.push(qa);
        }
        rmsnorm(&h, self.w.get("ln_f"), mc.rmsnorm_eps, &mut x);
        let mut logits = vec![0f32; mc.vocab];
        for vtok in 0..mc.vocab {
            logits[vtok] = x.iter().zip(&embed[vtok * d..(vtok + 1) * d]).map(|(a, b)| a * b).sum();
        }
        DecodeOut { logits, knew: knews, vnew: vnews, qabs: qabss }
    }

    /// Fused single-token decode: attention scores and outputs are computed
    /// **directly over the cache's packed u2/u4 buffers** via the affine
    /// decomposition (quant::packing module docs) — no dequantized f32
    /// window is ever materialized — and every intermediate lands in
    /// `scratch`, so the steady-state step performs zero heap allocations
    /// and zero `powf` calls. Storage is page-streamed: `scores_into` /
    /// `values_accumulate_into` walk the head's pool-leased page table one
    /// group-page at a time (kvcache::pool), which costs the same as the
    /// old contiguous layout — a page is exactly a scale group, so the
    /// per-group fold already landed on page boundaries. Semantics match [`RefModel::decode_step`]
    /// over the dequantize-then-attend oracle to float-reassociation
    /// tolerance (≤1e-4 logits; enforced by tests/fused_decode.rs across
    /// the full method roster). Outputs: `scratch.logits` /
    /// `scratch.knew` / `scratch.vnew` / `scratch.qabs`.
    pub fn decode_step_into(&self, token: i32, cache: &RequestCache, scratch: &mut DecodeScratch) {
        let mc = &self.mc;
        let d = mc.d_model;
        let (hq, hkv, dh, qpk) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv());
        let embed = &self.w.flat[self.pidx.embed];
        let (tq, tr) = (cache.qlen, cache.rlen());
        let pos = cache.pos;
        let rot = &cache.rot;
        let DecodeScratch {
            h, x, q, k, v, qrot, qperm, w4, w2, o, proj, ff, scores, logits, knew, vnew, qabs,
        } = scratch;
        debug_assert!(scores.len() >= tq + tr + 1, "scratch undersized for context");
        h.copy_from_slice(&embed[token as usize * d..(token as usize + 1) * d]);
        for l in 0..mc.n_layers {
            let lw = self.pidx.layers[l];
            let ctx = QuantLayerCtx { heads: &cache.heads[l], tq, tr };
            rmsnorm(h, &self.w.flat[lw.ln1], mc.rmsnorm_eps, x);
            matvec(x, &self.w.flat[lw.wq], d, hq * dh, q);
            matvec(x, &self.w.flat[lw.wk], d, hkv * dh, k);
            matvec(x, &self.w.flat[lw.wv], d, hkv * dh, v);
            for hh in 0..hq {
                self.rope.apply(&mut q[hh * dh..(hh + 1) * dh], pos);
            }
            for hh in 0..hkv {
                self.rope.apply(&mut k[hh * dh..(hh + 1) * dh], pos);
            }
            let qa = &mut qabs[l];
            qa.fill(0.0);
            for hh in 0..hq {
                for j in 0..dh {
                    qa[(hh / qpk) * dh + j] += q[hh * dh + j].abs();
                }
            }
            for a in qa.iter_mut() {
                *a /= qpk as f32;
            }
            o.fill(0.0);
            self.attn_head_range(&ctx, rot, q, k, v, 0, hq, qrot, qperm, w4, w2, scores, o);
            matvec(o, &self.w.flat[lw.wo], hq * dh, d, proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            rmsnorm(h, &self.w.flat[lw.ln2], mc.rmsnorm_eps, x);
            matvec(x, &self.w.flat[lw.w1], d, mc.d_ff, ff);
            for f in ff.iter_mut() {
                *f = gelu(*f);
            }
            matvec(ff, &self.w.flat[lw.w2], mc.d_ff, d, proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            knew[l].copy_from_slice(k);
            vnew[l].copy_from_slice(v);
        }
        rmsnorm(h, &self.w.flat[self.pidx.ln_f], mc.rmsnorm_eps, x);
        for (vtok, lg) in logits.iter_mut().enumerate() {
            *lg = x.iter().zip(&embed[vtok * d..(vtok + 1) * d]).map(|(a, b)| a * b).sum();
        }
    }

    /// Fused attention for one contiguous range `[h0, h1)` of query heads —
    /// the worker-pool head-split unit (threading-model boundary (c)), and
    /// the body of [`RefModel::decode_step_into`]'s head loop when called
    /// with the full range. Query heads are fully independent: each reads
    /// the shared post-RoPE `q`/`k`/`v` and the layer's cache heads, and
    /// writes only `o` (this range's `(h1-h0)*dh` output slice), so any
    /// partition of `0..Hq` into ranges produces bit-identical outputs to
    /// the sequential loop. `qrot`/`qperm`/`w4`/`w2`/`scores` are the
    /// calling worker's arena lanes.
    #[allow(clippy::too_many_arguments)]
    pub fn attn_head_range(
        &self,
        ctx: &QuantLayerCtx,
        rot: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
        h0: usize,
        h1: usize,
        qrot: &mut [f32],
        qperm: &mut [f32],
        w4: &mut [f32],
        w2: &mut [f32],
        scores: &mut [f32],
        o: &mut [f32],
    ) {
        let mc = &self.mc;
        let (dh, qpk) = (mc.d_head, mc.q_per_kv());
        let (tq, tr) = (ctx.tq, ctx.tr);
        let scale = 1.0 / (dh as f32).sqrt();
        debug_assert!(scores.len() >= tq + tr + 1, "scratch undersized for context");
        debug_assert_eq!(o.len(), (h1 - h0) * dh);
        let s = &mut scores[..tq + tr + 1];
        for hh in h0..h1 {
            let kvh = hh / qpk;
            let head = &ctx.heads[kvh];
            let qh = &q[hh * dh..(hh + 1) * dh];
            if tq > 0 {
                // score assembly is channel-permutation-aware: align the
                // (rotated) query to tier order once, then stream the
                // packed tiers.
                crate::quant::rotation::rotate_vec(qh, rot, qrot);
                for (dst, &src) in qperm.iter_mut().zip(&head.idx) {
                    *dst = qrot[src as usize];
                }
                head.scores_into(qperm, tq, scale, w4, w2, &mut s[..tq]);
            }
            let kres = head.res.keys();
            for t in 0..tr {
                let kk = &kres[t * dh..(t + 1) * dh];
                s[tq + t] = qh.iter().zip(kk).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let kk = &k[kvh * dh..(kvh + 1) * dh];
            s[tq + tr] = qh.iter().zip(kk).map(|(a, b)| a * b).sum::<f32>() * scale;
            softmax_inplace(s);
            let oh = &mut o[(hh - h0) * dh..(hh - h0 + 1) * dh];
            if tq > 0 {
                head.values_accumulate_into(&s[..tq], oh);
            }
            let vres = head.res.values();
            for t in 0..tr {
                let p = s[tq + t];
                let vv = &vres[t * dh..(t + 1) * dh];
                for j in 0..dh {
                    oh[j] += p * vv[j];
                }
            }
            let p = s[tq + tr];
            for j in 0..dh {
                oh[j] += p * v[kvh * dh + j];
            }
        }
    }

    /// [`RefModel::decode_step_into`] with each layer's query-head loop
    /// split across `pool` (threading-model boundary (c)): contiguous head
    /// ranges ([`crate::util::workers::split_ranges`] — a pure function of
    /// `(Hq, workers)`), each range writing a disjoint slice of the
    /// attention output from its own worker arena, reassembled at a
    /// per-layer barrier in range order. Bit-identical to the
    /// single-threaded path at every worker count because heads are
    /// independent and nothing is reduced across ranges (gated by
    /// tests/parallel.rs); at `pool.size() == 1` it *is* the
    /// single-threaded path.
    pub fn decode_step_into_mt(
        &self,
        token: i32,
        cache: &RequestCache,
        scratch: &mut DecodeScratch,
        pool: &mut crate::util::workers::WorkerPool,
    ) {
        if pool.size() == 1 {
            return self.decode_step_into(token, cache, scratch);
        }
        let mc = &self.mc;
        let d = mc.d_model;
        let (hq, hkv, dh, qpk) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv());
        let embed = &self.w.flat[self.pidx.embed];
        let (tq, tr) = (cache.qlen, cache.rlen());
        let pos = cache.pos;
        let rot = &cache.rot[..];
        let DecodeScratch {
            h, x, q, k, v, o, proj, ff, logits, knew, vnew, qabs, ..
        } = scratch;
        h.copy_from_slice(&embed[token as usize * d..(token as usize + 1) * d]);
        let ranges = crate::util::workers::split_ranges(hq, pool.size());
        for l in 0..mc.n_layers {
            let lw = self.pidx.layers[l];
            rmsnorm(h, &self.w.flat[lw.ln1], mc.rmsnorm_eps, x);
            matvec(x, &self.w.flat[lw.wq], d, hq * dh, q);
            matvec(x, &self.w.flat[lw.wk], d, hkv * dh, k);
            matvec(x, &self.w.flat[lw.wv], d, hkv * dh, v);
            for hh in 0..hq {
                self.rope.apply(&mut q[hh * dh..(hh + 1) * dh], pos);
            }
            for hh in 0..hkv {
                self.rope.apply(&mut k[hh * dh..(hh + 1) * dh], pos);
            }
            let qa = &mut qabs[l];
            qa.fill(0.0);
            for hh in 0..hq {
                for j in 0..dh {
                    qa[(hh / qpk) * dh + j] += q[hh * dh + j].abs();
                }
            }
            for a in qa.iter_mut() {
                *a /= qpk as f32;
            }
            o.fill(0.0);
            {
                // fan the head ranges out: each job gets the disjoint
                // output slice its range owns plus its worker's arena lanes
                let (q, k, v) = (&q[..], &k[..], &v[..]);
                let heads = &cache.heads[l][..];
                let mut rest: &mut [f32] = o;
                let mut jobs = Vec::with_capacity(ranges.len());
                for &(h0, h1) in &ranges {
                    let (chunk, tail) = rest.split_at_mut((h1 - h0) * dh);
                    rest = tail;
                    jobs.push(move |ws: &mut crate::util::workers::WorkerScratch| {
                        let ds = &mut ws.decode;
                        let ctx = QuantLayerCtx { heads, tq, tr };
                        self.attn_head_range(
                            &ctx,
                            rot,
                            q,
                            k,
                            v,
                            h0,
                            h1,
                            &mut ds.qrot,
                            &mut ds.qperm,
                            &mut ds.w4,
                            &mut ds.w2,
                            &mut ds.scores,
                            chunk,
                        );
                    });
                }
                // per-layer barrier: run() blocks until every range lands
                pool.run(jobs);
            }
            matvec(o, &self.w.flat[lw.wo], hq * dh, d, proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            rmsnorm(h, &self.w.flat[lw.ln2], mc.rmsnorm_eps, x);
            matvec(x, &self.w.flat[lw.w1], d, mc.d_ff, ff);
            for f in ff.iter_mut() {
                *f = gelu(*f);
            }
            matvec(ff, &self.w.flat[lw.w2], mc.d_ff, d, proj);
            for j in 0..d {
                h[j] += proj[j];
            }
            knew[l].copy_from_slice(k);
            vnew[l].copy_from_slice(v);
        }
        rmsnorm(h, &self.w.flat[self.pidx.ln_f], mc.rmsnorm_eps, x);
        for (vtok, lg) in logits.iter_mut().enumerate() {
            *lg = x.iter().zip(&embed[vtok * d..(vtok + 1) * d]).map(|(a, b)| a * b).sum();
        }
    }
}

/// Reusable chunked-prefill arena: every intermediate of a [`PrefillRun`]
/// lives here, allocated once per run and reused for every chunk of every
/// layer — the steady-state chunk performs **zero heap allocations**
/// (asserted by tests/blocked_prefill.rs with the counting allocator).
///
/// The only full-prompt activations are the residual stream `h` and ONE
/// layer's K/V — the legacy path's `[L]`-layer `PrefillOut` stash, its
/// `[Hkv, T, dh]` re-stash copy at admission, and the `T × vocab` logits
/// matrix all disappear, which is where the ≥2× peak-resident-bytes
/// reduction of benches/prefill.rs comes from.
pub struct PrefillScratch {
    /// [t, d_model] residual stream.
    h: Vec<f32>,
    /// [chunk, d_model] rmsnorm output tile.
    x: Vec<f32>,
    /// [chunk, Hq*dh] query tile.
    q: Vec<f32>,
    /// [t, Hkv*dh] CURRENT layer keys (post-RoPE), reused layer to layer.
    k: Vec<f32>,
    /// [t, Hkv*dh] current layer values.
    v: Vec<f32>,
    /// [chunk, Hq*dh] attention output tile.
    o: Vec<f32>,
    /// [chunk, d_model] projection tile.
    proj: Vec<f32>,
    /// [chunk, d_ff] MLP tile.
    ff: Vec<f32>,
    /// [t] attention scores for one (token, head).
    scores: Vec<f32>,
    /// [L][Hkv*dh] running |q| sums, normalized at each layer's close.
    qabs: Vec<Vec<f32>>,
    /// [t, dh] per-head gather buffers feeding the direct-to-page
    /// quantization sink (`RequestCache::store_prefill_layer`).
    kg: Vec<f32>,
    vg: Vec<f32>,
    /// [vocab] logits for the LAST position only.
    logits: Vec<f32>,
}

impl PrefillScratch {
    pub fn new(mc: &ModelConfig, t: usize, chunk: usize) -> PrefillScratch {
        let (hq, hkv, dh) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head);
        PrefillScratch {
            h: vec![0.0; t * mc.d_model],
            x: vec![0.0; chunk * mc.d_model],
            q: vec![0.0; chunk * hq * dh],
            k: vec![0.0; t * hkv * dh],
            v: vec![0.0; t * hkv * dh],
            o: vec![0.0; chunk * hq * dh],
            proj: vec![0.0; chunk * mc.d_model],
            ff: vec![0.0; chunk * mc.d_ff],
            scores: vec![0.0; t],
            qabs: (0..mc.n_layers).map(|_| vec![0f32; hkv * dh]).collect(),
            kg: vec![0.0; t * dh],
            vg: vec![0.0; t * dh],
            logits: vec![0.0; mc.vocab],
        }
    }

    /// Host bytes this arena pins while the prefill runs — the chunked
    /// path's peak f32 working set (quantized pages are accounted
    /// separately by the cache's own byte model).
    pub fn resident_bytes(&self) -> usize {
        4 * (self.h.len()
            + self.x.len()
            + self.q.len()
            + self.k.len()
            + self.v.len()
            + self.o.len()
            + self.proj.len()
            + self.ff.len()
            + self.scores.len()
            + self.qabs.iter().map(Vec::len).sum::<usize>()
            + self.kg.len()
            + self.vg.len()
            + self.logits.len())
    }
}

/// Resumable chunked GEMM-blocked prefill — the production prefill path.
///
/// The prompt is processed **layer-streamed, chunk-tiled**: for each layer,
/// group-aligned token tiles run rmsnorm → blocked QKV ([`matmul_blocked`])
/// → RoPE → streaming causal attention (over the layer's own f32 K/V, so
/// logits match the [`RefModel::forward_full`] oracle to reassociation
/// tolerance for *every* quantization method) → blocked output + MLP
/// projections; when a layer's last tile completes, its K/V quantize
/// **directly into the cache's pool pages**
/// ([`RequestCache::store_prefill_layer`] leases one page per group as it
/// stores) and the f32 buffer is recycled for the next layer. After the
/// final layer, the vocab projection runs for the **last position only**
/// (the full `T × vocab` logits of the legacy path were always discarded by
/// production callers).
///
/// The unit of work is one (layer, chunk) tile: [`PrefillRun::advance`]
/// processes up to `max_chunks` units and returns, so a serving tick can
/// interleave a long prompt's prefill with live decode steps
/// (`coordinator::router::Server` budgets units per tick). One chunk-unit
/// at steady state allocates nothing.
pub struct PrefillRun {
    t: usize,
    chunk: usize,
    /// Resume seam: the first token this run computes. Zero for a plain
    /// run; a partial prefix hit sets it to the matched (group-aligned)
    /// token count and every layer reconstructs rows `[0, seam)` from the
    /// installed shared pages ([`RequestCache::dequant_prefix_into`])
    /// before its first tile runs.
    seam: usize,
    layer: usize,
    /// Tokens completed in the current layer.
    tok: usize,
    started: bool,
    done: bool,
    chunks_done: usize,
    scratch: PrefillScratch,
}

impl PrefillRun {
    /// `chunk` should be a multiple of the cache's quantization group G so
    /// tile boundaries line up with page boundaries (correctness does not
    /// depend on it: quantization happens at layer close over the full
    /// group-aligned window).
    pub fn new(mc: &ModelConfig, t: usize, chunk: usize) -> PrefillRun {
        assert!(t > 0, "empty prompt");
        assert!(chunk > 0, "chunk must be positive");
        PrefillRun {
            t,
            chunk,
            seam: 0,
            layer: 0,
            tok: 0,
            started: false,
            done: false,
            chunks_done: 0,
            scratch: PrefillScratch::new(mc, t, chunk),
        }
    }

    /// A run resuming from a partial prefix hit: the cache already holds
    /// the matched prefix (`RequestCache::install_prefix`, frozen-plan
    /// mode), so only tokens `[seam, t)` are computed — per layer, rows
    /// `[0, seam)` of the K/V planes are reconstructed from the shared
    /// pages before the first tile, the streaming attention then sees the
    /// full causal context, and the layer close quantizes just the tail
    /// under the adopted plan (`RequestCache::store_prefill_layer_from`).
    /// `seam` must be group-aligned and a strict prefix (the last token is
    /// always recomputed so the final logits can project).
    pub fn new_resumed(mc: &ModelConfig, t: usize, chunk: usize, seam: usize) -> PrefillRun {
        assert!(chunk > 0, "chunk must be positive");
        assert!(seam > 0 && seam < t, "seam {seam} must be a strict prefix of {t}");
        PrefillRun {
            t,
            chunk,
            seam,
            layer: 0,
            tok: seam,
            started: false,
            done: false,
            chunks_done: 0,
            scratch: PrefillScratch::new(mc, t, chunk),
        }
    }

    /// A run whose whole prompt was served from a shared prefix entry
    /// (a `kvcache::radix::RadixTree` full hit): no chunk will ever execute — the
    /// cache adopted the registered pages/residual and `last_logits` is the
    /// entry's snapshot, so `advance` reports done immediately and
    /// `total_chunks` tells the caller how many (layer, chunk) units of
    /// compute were skipped. The arena is minimal (one-token scratch): a
    /// hit must not pin a prompt-sized f32 working set it will never touch.
    pub fn new_shared(mc: &ModelConfig, t: usize, chunk: usize, last_logits: &[f32]) -> PrefillRun {
        assert!(t > 0, "empty prompt");
        assert!(chunk > 0, "chunk must be positive");
        let mut scratch = PrefillScratch::new(mc, 1, 1);
        scratch.logits.copy_from_slice(last_logits);
        PrefillRun {
            t,
            chunk,
            seam: 0,
            layer: mc.n_layers,
            tok: 0,
            started: true,
            done: true,
            chunks_done: 0,
            scratch,
        }
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// (layer, chunk) units processed so far.
    pub fn chunks_done(&self) -> usize {
        self.chunks_done
    }

    /// Chunk units per layer (the last may be short). A resumed run only
    /// tiles its tail — the matched prefix's units are the skipped work.
    pub fn chunks_per_layer(&self) -> usize {
        (self.t - self.seam).div_ceil(self.chunk)
    }

    /// Total (layer, chunk) units this run will process.
    pub fn total_chunks(&self, n_layers: usize) -> usize {
        self.chunks_per_layer() * n_layers
    }

    /// Peak f32 working-set bytes of this run's arena.
    pub fn resident_bytes(&self) -> usize {
        self.scratch.resident_bytes()
    }

    /// Last-position logits — valid once [`PrefillRun::is_done`].
    pub fn last_logits(&self) -> &[f32] {
        debug_assert!(self.done, "prefill not complete");
        &self.scratch.logits
    }

    /// Serialize the resumable state: progress counters plus the
    /// *persistent* scratch planes — the residual stream `h`, the current
    /// layer's K/V rows, the per-layer |q| accumulators, and the logits.
    /// The per-tile planes (`x`/`q`/`o`/`proj`/`ff`/`scores`/gathers) are
    /// written and fully consumed inside one chunk unit, and a snapshot
    /// only ever happens between units (the tick-boundary quiesce), so
    /// they reconstruct as fresh zeroed tiles. Shared-hit runs
    /// ([`PrefillRun::new_shared`]) carry only their logits.
    pub fn write_snap<W: std::io::Write>(
        &self,
        w: &mut crate::util::snapshot::SnapWriter<W>,
        mc: &ModelConfig,
    ) -> crate::util::snapshot::SnapResult<()> {
        w.usize(self.t)?;
        w.usize(self.chunk)?;
        w.usize(self.seam)?;
        w.usize(self.layer)?;
        w.usize(self.tok)?;
        w.bool(self.started)?;
        w.bool(self.done)?;
        w.usize(self.chunks_done)?;
        let shared = self.scratch.h.len() != self.t * mc.d_model;
        w.bool(shared)?;
        if !shared {
            w.slice_f32(&self.scratch.h)?;
            w.slice_f32(&self.scratch.k)?;
            w.slice_f32(&self.scratch.v)?;
            for a in &self.scratch.qabs {
                w.slice_f32(a)?;
            }
        }
        w.slice_f32(&self.scratch.logits)
    }

    /// Rebuild a run from a snapshot (fresh transient tiles, restored
    /// persistent planes). The next [`PrefillRun::advance`] continues at
    /// exactly the interrupted (layer, chunk) unit.
    pub fn read_snap<R: std::io::Read>(
        r: &mut crate::util::snapshot::SnapReader<R>,
        mc: &ModelConfig,
    ) -> crate::util::snapshot::SnapResult<PrefillRun> {
        use crate::util::snapshot::corrupt;
        let t = r.usize("prefill run t")?;
        let chunk = r.usize("prefill run chunk")?;
        if t == 0 || chunk == 0 {
            return Err(corrupt(format!("prefill run t={t}, chunk={chunk} (both must be > 0)")));
        }
        let seam = r.usize("prefill run seam")?;
        if seam >= t {
            return Err(corrupt(format!("prefill run seam {seam} not a strict prefix of {t}")));
        }
        let layer = r.usize("prefill run layer")?;
        let tok = r.usize("prefill run tok")?;
        let started = r.bool("prefill run started")?;
        let done = r.bool("prefill run done")?;
        let chunks_done = r.usize("prefill run chunks_done")?;
        if layer > mc.n_layers || tok > t || tok < seam {
            return Err(corrupt(format!(
                "prefill run cursor (layer {layer}/{}, tok {tok}) outside seam {seam} .. {t}",
                mc.n_layers
            )));
        }
        let shared = r.bool("prefill run shared flag")?;
        let expect = |name: &str, got: usize, want: usize| {
            if got == want {
                Ok(())
            } else {
                Err(corrupt(format!("prefill run {name}: {got} elements (geometry says {want})")))
            }
        };
        if shared {
            let logits = r.vec_f32("prefill run logits")?;
            expect("logits", logits.len(), mc.vocab)?;
            let mut run = PrefillRun::new_shared(mc, t, chunk, &logits);
            run.chunks_done = chunks_done;
            Ok(run)
        } else {
            let mut run = PrefillRun::new(mc, t, chunk);
            run.seam = seam;
            run.layer = layer;
            run.tok = tok;
            run.started = started;
            run.done = done;
            run.chunks_done = chunks_done;
            let h = r.vec_f32("prefill run h")?;
            expect("h", h.len(), t * mc.d_model)?;
            run.scratch.h = h;
            let k = r.vec_f32("prefill run k")?;
            expect("k", k.len(), t * mc.n_kv_heads * mc.d_head)?;
            run.scratch.k = k;
            let v = r.vec_f32("prefill run v")?;
            expect("v", v.len(), t * mc.n_kv_heads * mc.d_head)?;
            run.scratch.v = v;
            for l in 0..mc.n_layers {
                let a = r.vec_f32("prefill run qabs")?;
                expect("qabs", a.len(), mc.n_kv_heads * mc.d_head)?;
                run.scratch.qabs[l] = a;
            }
            let logits = r.vec_f32("prefill run logits")?;
            expect("logits", logits.len(), mc.vocab)?;
            run.scratch.logits = logits;
            Ok(run)
        }
    }

    /// Process up to `max_chunks` (layer, chunk) units, quantizing each
    /// completed layer straight into `cache` pool pages. Returns `true`
    /// when the whole prefill (including the last-logit projection and the
    /// cache's `finish_prefill`) is complete. The first call validates the
    /// prompt against cache capacity and current pool occupancy
    /// (`RequestCache::begin_prefill`) before any page is leased; a pool
    /// that dries up mid-run (pages taken by concurrent decode flushes)
    /// surfaces as an error from the layer store — the caller drops the
    /// cache and every already-leased page returns to the pool.
    pub fn advance(
        &mut self,
        model: &RefModel<'_>,
        tokens: &[i32],
        cache: &mut RequestCache,
        max_chunks: usize,
    ) -> Result<bool> {
        if self.done {
            return Ok(true);
        }
        if tokens.len() != self.t {
            bail!("prefill run sized for {} tokens, got {}", self.t, tokens.len());
        }
        if !self.started {
            cache.begin_prefill_from(self.t, self.seam)?;
            let d = model.mc.d_model;
            let embed = &model.w.flat[model.pidx.embed];
            // the residual stream for rows `[0, seam)` is never read (every
            // tile starts at the seam), so filling all rows uniformly is
            // harmless and keeps the plain/resumed paths identical
            for (row, &tokid) in self.scratch.h.chunks_exact_mut(d).zip(tokens) {
                row.copy_from_slice(&embed[tokid as usize * d..(tokid as usize + 1) * d]);
            }
            self.started = true;
        }
        let mut budget = max_chunks;
        while budget > 0 && !self.done {
            if self.seam > 0 && self.tok == self.seam {
                // first tile of a layer: rebuild the matched prefix's K/V
                // rows from the installed shared pages so the streaming
                // attention sees the full causal context
                cache.dequant_prefix_into(
                    self.layer,
                    self.seam,
                    &mut self.scratch.k,
                    &mut self.scratch.v,
                );
            }
            self.chunk_step(model);
            self.chunks_done += 1;
            budget -= 1;
            self.tok = (self.tok + self.chunk).min(self.t);
            if self.tok == self.t {
                self.close_layer(model, cache)?;
                self.layer += 1;
                self.tok = self.seam;
                if self.layer == model.mc.n_layers {
                    self.project_last(model);
                    cache.finish_prefill(self.t);
                    self.done = true;
                }
            }
        }
        Ok(self.done)
    }

    /// One (layer, chunk) tile: the zero-alloc steady-state unit.
    fn chunk_step(&mut self, model: &RefModel<'_>) {
        let mc = &model.mc;
        let (d, dff) = (mc.d_model, mc.d_ff);
        let (hq, hkv, dh, qpk) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv());
        let (hqd, hkvd) = (hq * dh, hkv * dh);
        let scale = 1.0 / (dh as f32).sqrt();
        let t0 = self.tok;
        let t1 = (t0 + self.chunk).min(self.t);
        let cl = t1 - t0;
        let lw = model.pidx.layers[self.layer];
        let PrefillScratch { h, x, q, k, v, o, proj, ff, scores, qabs, .. } = &mut self.scratch;
        // --- blocked QKV: one streaming pass over each weight per tile ---
        for i in 0..cl {
            rmsnorm(
                &h[(t0 + i) * d..(t0 + i + 1) * d],
                &model.w.flat[lw.ln1],
                mc.rmsnorm_eps,
                &mut x[i * d..(i + 1) * d],
            );
        }
        matmul_blocked(&x[..cl * d], cl, &model.w.flat[lw.wq], d, hqd, &mut q[..cl * hqd]);
        let kdst = &mut k[t0 * hkvd..t1 * hkvd];
        matmul_blocked(&x[..cl * d], cl, &model.w.flat[lw.wk], d, hkvd, kdst);
        let vdst = &mut v[t0 * hkvd..t1 * hkvd];
        matmul_blocked(&x[..cl * d], cl, &model.w.flat[lw.wv], d, hkvd, vdst);
        for i in 0..cl {
            for hh in 0..hq {
                model.rope.apply(&mut q[i * hqd + hh * dh..i * hqd + (hh + 1) * dh], t0 + i);
            }
            let krow = (t0 + i) * hkvd;
            for hh in 0..hkv {
                model.rope.apply(&mut k[krow + hh * dh..krow + (hh + 1) * dh], t0 + i);
            }
        }
        // --- I_d accumulation (post-RoPE |q|, forward_full's order) ------
        let qa = &mut qabs[self.layer];
        for i in 0..cl {
            for hh in 0..hq {
                let base = (hh / qpk) * dh;
                let qrow = &q[i * hqd + hh * dh..i * hqd + (hh + 1) * dh];
                for (j, qv) in qrow.iter().enumerate() {
                    qa[base + j] += qv.abs();
                }
            }
        }
        // --- streaming causal attention over the layer's f32 K/V ---------
        o[..cl * hqd].fill(0.0);
        for i in 0..cl {
            let span = t0 + i + 1;
            for hh in 0..hq {
                let kvh = hh / qpk;
                let qh = &q[i * hqd + hh * dh..i * hqd + (hh + 1) * dh];
                let s = &mut scores[..span];
                for (sc, krow) in s.iter_mut().zip(k.chunks_exact(hkvd)) {
                    *sc = dot_lanes(qh, &krow[kvh * dh..(kvh + 1) * dh]) * scale;
                }
                softmax_inplace(s);
                let oh = &mut o[i * hqd + hh * dh..i * hqd + (hh + 1) * dh];
                for (p, vrow) in s.iter().zip(v.chunks_exact(hkvd)) {
                    let vv = &vrow[kvh * dh..(kvh + 1) * dh];
                    for j in 0..dh {
                        oh[j] += p * vv[j];
                    }
                }
            }
        }
        matmul_blocked(&o[..cl * hqd], cl, &model.w.flat[lw.wo], hqd, d, &mut proj[..cl * d]);
        for i in 0..cl {
            let hrow = &mut h[(t0 + i) * d..(t0 + i + 1) * d];
            for (hv, pv) in hrow.iter_mut().zip(&proj[i * d..(i + 1) * d]) {
                *hv += pv;
            }
        }
        // --- blocked MLP -------------------------------------------------
        for i in 0..cl {
            rmsnorm(
                &h[(t0 + i) * d..(t0 + i + 1) * d],
                &model.w.flat[lw.ln2],
                mc.rmsnorm_eps,
                &mut x[i * d..(i + 1) * d],
            );
        }
        matmul_blocked(&x[..cl * d], cl, &model.w.flat[lw.w1], d, dff, &mut ff[..cl * dff]);
        for f in ff[..cl * dff].iter_mut() {
            *f = gelu(*f);
        }
        matmul_blocked(&ff[..cl * dff], cl, &model.w.flat[lw.w2], dff, d, &mut proj[..cl * d]);
        for i in 0..cl {
            let hrow = &mut h[(t0 + i) * d..(t0 + i + 1) * d];
            for (hv, pv) in hrow.iter_mut().zip(&proj[i * d..(i + 1) * d]) {
                *hv += pv;
            }
        }
    }

    /// Layer close: normalize the |q| accumulator and quantize the layer's
    /// K/V straight into the cache (pages lease one group at a time inside
    /// the store; the residual tail stays f32).
    fn close_layer(&mut self, model: &RefModel<'_>, cache: &mut RequestCache) -> Result<()> {
        let l = self.layer;
        // a resumed run accumulated |q| over the tail's queries only
        let denom = ((self.t - self.seam) * model.mc.q_per_kv()) as f32;
        for a in self.scratch.qabs[l].iter_mut() {
            *a /= denom;
        }
        let PrefillScratch { k, v, qabs, kg, vg, .. } = &mut self.scratch;
        cache.store_prefill_layer_from(l, k, v, &qabs[l], self.t, self.seam, kg, vg)
    }

    /// Final norm + vocab projection for the LAST position only — the
    /// legacy `T × vocab` logits matrix (discarded by every production
    /// caller) is gone. Full teacher-forced logits remain available from
    /// the [`RefModel::forward_full`] oracle.
    fn project_last(&mut self, model: &RefModel<'_>) {
        let mc = &model.mc;
        let d = mc.d_model;
        let PrefillScratch { h, x, logits, .. } = &mut self.scratch;
        let x = &mut x[..d];
        rmsnorm(
            &h[(self.t - 1) * d..self.t * d],
            &model.w.flat[model.pidx.ln_f],
            mc.rmsnorm_eps,
            x,
        );
        let embed = &model.w.flat[model.pidx.embed];
        for (vtok, lg) in logits.iter_mut().enumerate() {
            *lg = dot_lanes(x, &embed[vtok * d..(vtok + 1) * d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::quant::rotation;
    use crate::util::rng::Pcg32;

    fn tiny_mc() -> ModelConfig {
        ModelConfig { n_layers: 2, ..ModelConfig::default_build() }
    }

    #[test]
    fn decode_matches_forward_when_cache_residual_only() {
        // Internal consistency: decoding token t with the first t tokens'
        // K/V in the "residual" slot must equal the causal forward at t.
        let mc = tiny_mc();
        let w = Weights::random(&mc, 3);
        let model = RefModel::new(mc.clone(), &w);
        let mut rng = Pcg32::seeded(7);
        let toks: Vec<i32> = (0..12).map(|_| rng.range(1, 127) as i32).collect();
        let (logits_full, pre) = model.forward_full(&toks);
        let t = toks.len() - 1;
        // K/V for positions 0..t as residual context
        let dh = mc.d_head;
        let hkv = mc.n_kv_heads;
        let mut kres = Vec::new();
        let mut vres = Vec::new();
        for l in 0..mc.n_layers {
            let mut kl = vec![0f32; hkv * t * dh];
            let mut vl = vec![0f32; hkv * t * dh];
            for hh in 0..hkv {
                let full_t = toks.len();
                kl[hh * t * dh..(hh * t + t) * dh]
                    .copy_from_slice(&pre.k[l][hh * full_t * dh..(hh * full_t + t) * dh]);
                vl[hh * t * dh..(hh * t + t) * dh]
                    .copy_from_slice(&pre.v[l][hh * full_t * dh..(hh * full_t + t) * dh]);
            }
            kres.push(kl);
            vres.push(vl);
        }
        let rot = rotation::identity(dh);
        let ctx: Vec<LayerCtx> = (0..mc.n_layers)
            .map(|l| LayerCtx {
                kq: &[],
                vq: &[],
                tq: 0,
                kres: &kres[l],
                vres: &vres[l],
                tr: t,
            })
            .collect();
        let out = model.decode_step(toks[t], t, &ctx, &rot);
        let want = &logits_full[t * mc.vocab..(t + 1) * mc.vocab];
        let err = out
            .logits
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-3, "decode/forward mismatch {err}");
    }

    #[test]
    fn quantized_context_equals_residual_context_at_full_precision() {
        // Putting the same K/V through the "quantized" slot (dequantized
        // identity) must give identical logits to the residual slot.
        let mc = tiny_mc();
        let w = Weights::random(&mc, 4);
        let model = RefModel::new(mc.clone(), &w);
        let mut rng = Pcg32::seeded(8);
        let toks: Vec<i32> = (0..10).map(|_| rng.range(1, 127) as i32).collect();
        let (_, pre) = model.forward_full(&toks);
        let t = toks.len() - 1;
        let dh = mc.d_head;
        let hkv = mc.n_kv_heads;
        let full_t = toks.len();
        let slice = |m: &Vec<f32>| -> Vec<f32> {
            let mut out = vec![0f32; hkv * t * dh];
            for hh in 0..hkv {
                out[hh * t * dh..(hh * t + t) * dh]
                    .copy_from_slice(&m[hh * full_t * dh..(hh * full_t + t) * dh]);
            }
            out
        };
        let rot = rotation::identity(dh);
        let ks: Vec<Vec<f32>> = (0..mc.n_layers).map(|l| slice(&pre.k[l])).collect();
        let vs: Vec<Vec<f32>> = (0..mc.n_layers).map(|l| slice(&pre.v[l])).collect();
        let ctx_q: Vec<LayerCtx> = (0..mc.n_layers)
            .map(|l| LayerCtx { kq: &ks[l], vq: &vs[l], tq: t, kres: &[], vres: &[], tr: 0 })
            .collect();
        let ctx_r: Vec<LayerCtx> = (0..mc.n_layers)
            .map(|l| LayerCtx { kq: &[], vq: &[], tq: 0, kres: &ks[l], vres: &vs[l], tr: t })
            .collect();
        let a = model.decode_step(toks[t], t, &ctx_q, &rot);
        let b = model.decode_step(toks[t], t, &ctx_r, &rot);
        let err = a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-4, "{err}");
    }

    #[test]
    fn gelu_matches_jax_values() {
        // jax.nn.gelu(1.0) ≈ 0.841192, gelu(-2.0) ≈ -0.0454023 (tanh approx)
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-2.0) + 0.0454023).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        apply_rope(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
    }

    #[test]
    fn rope_table_matches_per_call_powf() {
        let table = RopeTable::new(32, 10000.0);
        for pos in [0usize, 1, 17, 500] {
            let mut a: Vec<f32> = (0..32).map(|i| (i as f32 * 0.11).cos()).collect();
            let mut b = a.clone();
            apply_rope(&mut a, pos, 10000.0);
            table.apply(&mut b, pos);
            assert_eq!(a, b, "pos {pos}");
        }
    }

    #[test]
    fn blocked_matvec_handles_remainder_rows() {
        // n not a multiple of the 4-row block, and n < 4
        let mut rng = Pcg32::seeded(9);
        for (n, m) in [(7usize, 5usize), (3, 4), (4, 3), (13, 8), (1, 2)] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..n * m).map(|_| rng.normal()).collect();
            let mut got = vec![0f32; m];
            matvec(&x, &w, n, m, &mut got);
            for j in 0..m {
                let want: f32 = (0..n).map(|i| x[i] * w[i * m + j]).sum();
                assert!((got[j] - want).abs() < 1e-5, "n={n} m={m} j={j}");
            }
        }
    }

    #[test]
    fn dot_lanes_matches_sequential_sum() {
        let mut rng = Pcg32::seeded(12);
        for n in [1usize, 7, 8, 15, 32, 33, 128] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_lanes(&a, &b);
            assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn matmul_blocked_is_bit_identical_to_matvec() {
        // remainder tokens AND remainder rows, plus the aligned fast path
        let mut rng = Pcg32::seeded(13);
        for (t, n, m) in [(1usize, 5usize, 3usize), (3, 8, 4), (4, 7, 5), (9, 13, 6), (8, 16, 32)] {
            let x: Vec<f32> = (0..t * n).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..n * m).map(|_| rng.normal()).collect();
            let mut got = vec![0f32; t * m];
            matmul_blocked(&x, t, &w, n, m, &mut got);
            let mut want = vec![0f32; m];
            for tok in 0..t {
                matvec(&x[tok * n..(tok + 1) * n], &w, n, m, &mut want);
                assert_eq!(&got[tok * m..(tok + 1) * m], &want[..], "t={t} n={n} m={m} tok={tok}");
            }
        }
    }

    #[test]
    fn chunked_prefill_matches_forward_full_last_logits() {
        // The production chunked path vs the oracle, including an unaligned
        // prompt length; full 17-method sweep lives in tests/blocked_prefill.rs.
        use crate::kvcache::cache::RequestCache;
        use crate::model::config::CacheConfig;
        use crate::quant::methods::Method;
        use crate::quant::window::TierSpec;
        let mc = tiny_mc();
        let w = Weights::random(&mc, 21);
        let model = RefModel::new(mc.clone(), &w);
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let mut rng = Pcg32::seeded(22);
        for t in [37usize, 70] {
            let toks: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();
            let mut cache = RequestCache::new(&mc, &cc, &[spec; 2], Method::mixkvq("mix30"), 32);
            let mut run = PrefillRun::new(&mc, t, cc.group);
            while !run.advance(&model, &toks, &mut cache, 1).unwrap() {}
            assert_eq!(run.chunks_done(), run.total_chunks(mc.n_layers));
            let (_, pre) = model.forward_full(&toks);
            let err = run
                .last_logits()
                .iter()
                .zip(&pre.last_logits)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err <= 1e-4, "t={t}: chunked/oracle logits diverge by {err}");
            assert_eq!(cache.pos, t);
            assert_eq!(cache.qlen + cache.rlen(), t);
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0, -100.0];
        softmax_inplace(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }
}
