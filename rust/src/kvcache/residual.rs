//! Full-precision residual buffer X_R (Fig. 4 / App. D.1).
//!
//! Newly generated K/V stay here in f32 until `limit` tokens accumulate;
//! then the whole block is drained into the quantized cache (lazy update —
//! amortizes channel selection and bit-packing over R steps, and keeps
//! volatile recent salience statistics out of the quantized window).

/// One head's residual buffer: row-major [capacity, d], `len` valid rows.
#[derive(Clone, Debug)]
pub struct ResidualBuffer {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub capacity: usize,
    pub d: usize,
}

impl ResidualBuffer {
    pub fn new(capacity: usize, d: usize) -> Self {
        ResidualBuffer {
            k: vec![0.0; capacity * d],
            v: vec![0.0; capacity * d],
            len: 0,
            capacity,
            d,
        }
    }

    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert!(self.len < self.capacity, "residual overflow");
        assert_eq!(k.len(), self.d);
        let off = self.len * self.d;
        self.k[off..off + self.d].copy_from_slice(k);
        self.v[off..off + self.d].copy_from_slice(v);
        self.len += 1;
    }

    /// Bulk-load `t` tokens (prefill leftover), row-major [t, d].
    pub fn extend(&mut self, k: &[f32], v: &[f32], t: usize) {
        assert!(self.len + t <= self.capacity);
        let off = self.len * self.d;
        self.k[off..off + t * self.d].copy_from_slice(&k[..t * self.d]);
        self.v[off..off + t * self.d].copy_from_slice(&v[..t * self.d]);
        self.len += t;
    }

    /// Drain the first `t` tokens for quantization, shifting the remainder
    /// down (t is the runtime R knob; remainder stays full-precision).
    pub fn drain(&mut self, t: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(t <= self.len);
        let k: Vec<f32> = self.k[..t * self.d].to_vec();
        let v: Vec<f32> = self.v[..t * self.d].to_vec();
        self.k.copy_within(t * self.d..self.len * self.d, 0);
        self.v.copy_within(t * self.d..self.len * self.d, 0);
        self.len -= t;
        (k, v)
    }

    pub fn keys(&self) -> &[f32] {
        &self.k[..self.len * self.d]
    }

    pub fn values(&self) -> &[f32] {
        &self.v[..self.len * self.d]
    }

    /// Storage bytes if these f32 rows were held as BF16 on device (the
    /// accountant's convention: residual is 2 bytes/elem, like the paper's
    /// BF16 buffer).
    pub fn bytes(&self) -> usize {
        2 * 2 * self.len * self.d
    }

    /// Serialize the valid rows (the zeroed tail past `len` reconstructs as
    /// zeros; capacity and d are geometry, rebuilt from config on restore).
    pub fn write_snap<W: std::io::Write>(
        &self,
        w: &mut crate::util::snapshot::SnapWriter<W>,
    ) -> crate::util::snapshot::SnapResult<()> {
        w.usize(self.len)?;
        w.slice_f32(self.keys())?;
        w.slice_f32(self.values())
    }

    /// Overlay snapshotted rows onto this (freshly constructed) buffer.
    pub fn read_snap<R: std::io::Read>(
        &mut self,
        r: &mut crate::util::snapshot::SnapReader<R>,
    ) -> crate::util::snapshot::SnapResult<()> {
        use crate::util::snapshot::corrupt;
        let len = r.usize("residual len")?;
        if len > self.capacity {
            return Err(corrupt(format!(
                "residual len {len} exceeds capacity {}",
                self.capacity
            )));
        }
        let k = r.vec_f32("residual keys")?;
        let v = r.vec_f32("residual values")?;
        if k.len() != len * self.d || v.len() != len * self.d {
            return Err(corrupt(format!(
                "residual rows {}x{} do not match len {len}",
                k.len() / self.d.max(1),
                self.d
            )));
        }
        self.len = 0;
        self.extend(&k, &v, len);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_drain_preserves_order_and_tail() {
        let mut rb = ResidualBuffer::new(8, 2);
        for i in 0..5 {
            rb.push(&[i as f32, 0.0], &[0.0, i as f32]);
        }
        assert_eq!(rb.len, 5);
        let (k, _v) = rb.drain(4);
        assert_eq!(k, vec![0.0, 0.0, 1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        assert_eq!(rb.len, 1);
        // invariant #5: the undrained tail is bit-exact
        assert_eq!(rb.keys(), &[4.0, 0.0]);
    }

    #[test]
    fn extend_bulk() {
        let mut rb = ResidualBuffer::new(4, 2);
        rb.extend(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2);
        assert_eq!(rb.len, 2);
        assert_eq!(rb.values(), &[5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    #[should_panic(expected = "residual overflow")]
    fn overflow_panics() {
        let mut rb = ResidualBuffer::new(1, 2);
        rb.push(&[0.0, 0.0], &[0.0, 0.0]);
        rb.push(&[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn snapshot_round_trips_valid_rows_only() {
        use crate::util::snapshot::{SnapReader, SnapWriter};
        let mut rb = ResidualBuffer::new(4, 2);
        rb.push(&[1.0, 2.0], &[3.0, 4.0]);
        rb.push(&[5.0, 6.0], &[7.0, 8.0]);
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        rb.write_snap(&mut w).unwrap();
        w.finish().unwrap();
        let mut rb2 = ResidualBuffer::new(4, 2);
        let mut r = SnapReader::new(&buf[..]).unwrap();
        rb2.read_snap(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(rb2.len, 2);
        assert_eq!(rb2.keys(), rb.keys());
        assert_eq!(rb2.values(), rb.values());
        // a snapshot claiming more rows than this geometry holds is corrupt
        let mut tiny = ResidualBuffer::new(1, 2);
        let mut r = SnapReader::new(&buf[..]).unwrap();
        let err = tiny.read_snap(&mut r).unwrap_err();
        assert!(err.to_string().contains("exceeds capacity"), "{err}");
    }

    #[test]
    fn bytes_accounting() {
        let mut rb = ResidualBuffer::new(8, 4);
        rb.push(&[0.0; 4], &[0.0; 4]);
        rb.push(&[0.0; 4], &[0.0; 4]);
        assert_eq!(rb.bytes(), 2 * 2 * 2 * 4);
    }
}
