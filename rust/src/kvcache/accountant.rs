//! Exact KV-memory accounting — the substance behind Fig. 5's "memory
//! usage" axis. No hardware is involved: cache bytes are arithmetic over
//! the storage layout (packed codes + scales/zeros + BF16 outlier columns +
//! residual), so the numbers are exact rather than sampled.

use crate::kvcache::cache::RequestCache;
use crate::model::config::{CacheConfig, ModelConfig};
use crate::quant::window::TierSpec;

/// Static per-token byte cost of a tier layout (amortized; excludes the
/// per-request constant `idx` array).
pub fn bytes_per_token(spec: &TierSpec, d: usize, group: usize) -> f64 {
    // BF16 scales/zeros (deployment layout; matches HeadState::bytes_used)
    let key = 2.0 * spec.n16 as f64
        + spec.n4 as f64 / 2.0
        + spec.n2 as f64 / 4.0
        + 2.0 * 2.0 * (spec.n4 + spec.n2) as f64 / group as f64;
    let val = if spec.v_bits == 16 {
        2.0 * d as f64
    } else {
        d as f64 * spec.v_bits as f64 / 8.0 + 2.0 * 2.0 * (d as f64 / group as f64)
    };
    key + val
}

pub fn fp16_bytes_per_token(d: usize) -> f64 {
    2.0 * 2.0 * d as f64 // K + V at 2 bytes each
}

/// Effective bits/element implied by the byte layout (includes scale/zero
/// overhead — this is why the paper reports e.g. "2.7 bits" rather than 2.5).
pub fn effective_bits(spec: &TierSpec, d: usize, group: usize) -> f64 {
    bytes_per_token(spec, d, group) * 8.0 / (2 * d) as f64
}

/// Fleet-level accountant: tracks live bytes across requests against a
/// budget. With the paged pool (kvcache::pool) the scheduler admits on
/// **occupancy** — leased pages, observed via [`MemoryAccountant::observe`]
/// — and [`MemoryAccountant::worst_case_request_bytes`] survives only as
/// the reject-at-submit upper bound (a request whose worst case exceeds the
/// whole budget can never be served and must not camp the queue head).
pub struct MemoryAccountant {
    pub budget_bytes: usize,
    pub live_bytes: usize,
    pub peak_bytes: usize,
}

impl MemoryAccountant {
    pub fn new(budget_bytes: usize) -> Self {
        MemoryAccountant { budget_bytes, live_bytes: 0, peak_bytes: 0 }
    }

    /// Record the currently observed occupancy (leased pages × page bytes
    /// + residuals) — the paged-admission replacement for the old
    /// reserve/release bookkeeping, sampled once per scheduling tick.
    pub fn observe(&mut self, live_bytes: usize) {
        self.live_bytes = live_bytes;
        self.peak_bytes = self.peak_bytes.max(live_bytes);
    }

    pub fn try_reserve(&mut self, bytes: usize) -> bool {
        if self.live_bytes + bytes > self.budget_bytes {
            return false;
        }
        self.live_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
        true
    }

    pub fn adjust(&mut self, old: usize, new: usize) {
        self.live_bytes = self.live_bytes - old + new;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
    }

    pub fn release(&mut self, bytes: usize) {
        debug_assert!(bytes <= self.live_bytes);
        self.live_bytes -= bytes;
    }

    /// Worst-case bytes a request can reach under a layout (capacity C
    /// quantized + full residual) — the admission-control bound.
    pub fn worst_case_request_bytes(
        mc: &ModelConfig,
        cc: &CacheConfig,
        specs: &[TierSpec],
    ) -> usize {
        let mut total = 0.0;
        for spec in specs {
            let per_tok = bytes_per_token(spec, mc.d_head, cc.group);
            let quant = per_tok * cc.capacity as f64;
            let resid = fp16_bytes_per_token(mc.d_head) * cc.residual as f64;
            total += (quant + resid + 4.0 * mc.d_head as f64) * mc.n_kv_heads as f64;
        }
        total.ceil() as usize
    }
}

/// Compression report for one live request (drives the Fig. 5 rows).
pub struct CompressionReport {
    pub used_bytes: usize,
    pub fp16_bytes: usize,
    pub ratio: f64,
}

pub fn report(cache: &RequestCache) -> CompressionReport {
    let used = cache.bytes_used();
    let fp16 = cache.bytes_fp16_equiv();
    CompressionReport { used_bytes: used, fp16_bytes: fp16, ratio: fp16 as f64 / used.max(1) as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_token_bytes_ordering() {
        let d = 32;
        let g = 32;
        let bf16 = TierSpec { n16: d, n4: 0, n2: 0, v_bits: 16 };
        let kv4 = TierSpec { n16: 0, n4: d, n2: 0, v_bits: 4 };
        let kv2 = TierSpec { n16: 0, n4: 0, n2: d, v_bits: 2 };
        let mix = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let b = |s| bytes_per_token(&s, d, g);
        assert!(b(kv2) < b(mix) && b(mix) < b(kv4) && b(kv4) < b(bf16));
        assert_eq!(b(bf16), fp16_bytes_per_token(d));
    }

    #[test]
    fn effective_bits_includes_scale_overhead() {
        let d = 32;
        let kv2 = TierSpec { n16: 0, n4: 0, n2: d, v_bits: 2 };
        let eb = effective_bits(&kv2, d, 32);
        // 2-bit codes + grouped scales: 3.0 effective (paper reports C2.7
        // at G=128; at G=32 the overhead is 4x larger per group)
        assert!(eb > 2.0 && eb <= 3.05, "{eb}");
    }

    #[test]
    fn accountant_budget_enforced() {
        let mut a = MemoryAccountant::new(100);
        assert!(a.try_reserve(60));
        assert!(!a.try_reserve(50));
        assert!(a.try_reserve(40));
        assert_eq!(a.live_bytes, 100);
        a.release(60);
        assert_eq!(a.live_bytes, 40);
        assert_eq!(a.peak_bytes, 100);
        a.adjust(40, 70);
        assert_eq!(a.live_bytes, 70);
    }

    #[test]
    fn observe_tracks_peak_occupancy() {
        let mut a = MemoryAccountant::new(100);
        a.observe(30);
        a.observe(80);
        a.observe(10);
        assert_eq!(a.live_bytes, 10);
        assert_eq!(a.peak_bytes, 80);
    }

    #[test]
    fn worst_case_bound_is_sane() {
        let mc = ModelConfig::default_build();
        let cc = CacheConfig::default_build();
        let mix = vec![TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }; mc.n_layers];
        let bf16 = vec![TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }; mc.n_layers];
        let wc_mix = MemoryAccountant::worst_case_request_bytes(&mc, &cc, &mix);
        let wc_bf = MemoryAccountant::worst_case_request_bytes(&mc, &cc, &bf16);
        // mixed precision must admit ~2.5-4x more requests per byte budget
        let gain = wc_bf as f64 / wc_mix as f64;
        assert!(gain > 2.2 && gain < 5.0, "{gain}");
    }
}
