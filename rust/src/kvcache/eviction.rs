//! Extension: sink + sliding-window eviction over the *quantized* window.
//!
//! The paper positions MixKVQ as orthogonal to eviction ("it can be
//! combined with ... active pages managed by retrieval systems", §2); this
//! module provides the combination for the simplest eviction family the
//! paper cites (StreamingLLM / attention sinks, Xiao et al. 2024): when the
//! quantized window is full, drop the oldest non-sink group-aligned block
//! so decoding can continue indefinitely at bounded memory.
//!
//! With paged storage an eviction is a **page-table splice**: the evicted
//! groups' leases are drained from the table and their pages return to the
//! shared pool immediately (kvcache::pool), so a compaction costs O(evicted
//! pages) pointer operations — no byte shifting, no scale re-indexing, and
//! the freed pages are leasable by other requests in the same tick.
//!
//! Positions are NOT renumbered (RoPE already baked into stored keys);
//! like StreamingLLM-with-cache this changes attention structure relative
//! to a full cache — `ext1` in the experiment harness measures that cost.

use crate::kvcache::cache::{HeadState, RequestCache};

/// What to do when the quantized window cannot absorb another flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Finish the request (the default serving behaviour).
    Stop,
    /// Evict the oldest `evict` tokens beyond `sink` protected initial
    /// tokens; both must be group-aligned.
    SlidingWindow { sink: usize, evict: usize },
}

impl HeadState {
    /// Drop quantized tokens `[sink, sink+evict)`: splice their pages out
    /// of the page table, returning the leases to the pool. Caller updates
    /// the request-level qlen.
    pub fn evict_block(&mut self, sink: usize, evict: usize, qlen: usize) {
        let g = self.group;
        assert!(sink % g == 0 && evict % g == 0, "eviction must be group-aligned");
        assert!(sink + evict <= qlen);
        debug_assert!(qlen <= self.pages_leased() * g);
        let (gs, ge) = (sink / g, evict / g);
        // drain drops each PageLease, which returns its page to the pool
        drop(self.pages.drain(gs..gs + ge));
    }
}

impl RequestCache {
    /// Apply a sliding-window eviction so that at least `needed` more
    /// quantized tokens fit. Returns tokens evicted.
    ///
    /// Shared prefix pages may be evicted like any others: the splice drops
    /// only THIS request's reference — the page returns to the pool when its
    /// last holder (a co-tenant or the prefix tree) lets go. The shared
    /// region stays a window prefix across rounds (the evicted interior
    /// splices out and the survivors compact), so the request-level
    /// `shared_prefix_tokens` scalar shrinks by exactly the overlap.
    pub fn evict_for(&mut self, policy: CachePolicy, needed: usize) -> usize {
        let CachePolicy::SlidingWindow { sink, evict } = policy else {
            return 0;
        };
        let cap = self.capacity();
        let mut total = 0;
        while self.qlen + needed > cap && self.qlen >= sink + evict {
            for row in 0..self.heads.len() {
                for h in 0..self.heads[row].len() {
                    let qlen = self.qlen;
                    self.heads[row][h].evict_block(sink, evict, qlen);
                }
            }
            let overlap = self.shared_prefix_tokens.saturating_sub(sink).min(evict);
            self.shared_prefix_tokens -= overlap;
            self.qlen -= evict;
            total += evict;
        }
        total
    }

    pub fn capacity(&self) -> usize {
        self.heads[0][0].capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{CacheConfig, ModelConfig};
    use crate::quant::methods::Method;
    use crate::quant::window::TierSpec;
    use crate::util::rng::Pcg32;

    fn cache_with(t: usize, method: Method) -> (ModelConfig, RequestCache) {
        let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let mut cache = RequestCache::new(&mc, &cc, &[spec], method, 32);
        let mut rng = Pcg32::seeded(91);
        let n = mc.n_kv_heads * t * mc.d_head;
        let k = vec![(0..n).map(|_| rng.normal()).collect::<Vec<f32>>()];
        let v = vec![(0..n).map(|_| rng.normal()).collect::<Vec<f32>>()];
        let qa = vec![(0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.1).collect()];
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        (mc, cache)
    }

    #[test]
    fn eviction_preserves_sink_and_tail() {
        let (_, mut cache) = cache_with(256, Method::mixkvq("mix30"));
        let qlen0 = cache.qlen; // = ceil((256-32)/32)*32 = 224
        let before_sink = cache.heads[0][0].dequant_keys(qlen0);
        let d = cache.heads[0][0].d;
        let evicted = cache.evict_for(
            CachePolicy::SlidingWindow { sink: 32, evict: 32 },
            cache.capacity() - cache.qlen + 32, // force one eviction round
        );
        assert_eq!(evicted, 32);
        assert_eq!(cache.qlen, qlen0 - 32);
        let after = cache.heads[0][0].dequant_keys(cache.qlen);
        // sink rows identical
        assert_eq!(&after[..32 * d], &before_sink[..32 * d]);
        // tail rows = old rows shifted by 32
        assert_eq!(&after[32 * d..], &before_sink[64 * d..qlen0 * d]);
    }

    #[test]
    fn stop_policy_evicts_nothing() {
        let (_, mut cache) = cache_with(256, Method::kivi("kv2"));
        let q0 = cache.qlen;
        assert_eq!(cache.evict_for(CachePolicy::Stop, 512), 0);
        assert_eq!(cache.qlen, q0);
    }

    #[test]
    fn repeated_eviction_bounds_window() {
        let (_, mut cache) = cache_with(512, Method::mixkvq("mix225"));
        // 512-token prompt at R=32: qlen = ceil((512-32)/32)*32 = 480
        assert_eq!(cache.qlen, 480);
        let policy = CachePolicy::SlidingWindow { sink: 32, evict: 64 };
        let evicted = cache.evict_for(policy, 512); // impossible to satisfy fully
        // evicts until qlen < sink + evict = 96 (sink always kept)
        assert_eq!(cache.qlen, 32);
        assert_eq!(evicted, 480 - 32);
        // window remains group-aligned and dequantizable
        let _ = cache.heads[0][0].dequant_keys(cache.qlen);
    }

    #[test]
    fn values_evicted_consistently_with_keys() {
        let (_, mut cache) = cache_with(256, Method::kivi("kv4"));
        let q0 = cache.qlen;
        let v_before = cache.heads[0][1].dequant_values(q0);
        let d = cache.heads[0][1].d;
        let needed = cache.capacity() - q0 + 32; // force exactly one round
        cache.evict_for(CachePolicy::SlidingWindow { sink: 0, evict: 32 }, needed);
        assert_eq!(cache.qlen, q0 - 32);
        let v_after = cache.heads[0][1].dequant_values(cache.qlen);
        assert_eq!(&v_after[..(q0 - 32) * d], &v_before[32 * d..q0 * d]);
    }

    #[test]
    fn eviction_returns_pages_to_pool() {
        let (mc, mut cache) = cache_with(256, Method::mixkvq("mix30"));
        let q0 = cache.qlen; // 224 → 7 pages per head
        let leased0 = cache.pool().leased();
        assert_eq!(leased0, (q0 / 32) * mc.n_kv_heads);
        let evicted = cache.evict_for(
            CachePolicy::SlidingWindow { sink: 32, evict: 64 },
            cache.capacity() - cache.qlen + 64,
        );
        assert_eq!(evicted, 64);
        assert_eq!(
            cache.pool().leased(),
            leased0 - (64 / 32) * mc.n_kv_heads,
            "evicted blocks must free their pages immediately"
        );
    }
}
